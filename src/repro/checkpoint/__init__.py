from repro.checkpoint.checkpoint import (latest_step_dir, load_metadata,
                                         restore, save)

__all__ = ["latest_step_dir", "load_metadata", "restore", "save"]
