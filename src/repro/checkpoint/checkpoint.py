"""Sharded pytree checkpointing (npz shards + JSON manifest).

Design goals (the Nimrod/G fault-tolerance contract):

* atomic: writes go to ``<dir>.tmp`` then ``os.replace`` -> a crash never
  leaves a half checkpoint visible;
* resharding restore: arrays are saved as full logical tensors (assembled
  host-side), so a job that died on a 16x16 mesh can resume on 8x8 —
  restore applies whatever shardings the new mesh dictates;
* integrity: every shard file carries a crc32 recorded in the manifest;
* self-describing: the manifest stores the flattened key paths, shapes,
  dtypes, and user metadata (step, config name, data position).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "manifest.json"
_SHARD_BYTES = 512 * 1024 * 1024


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def save(ckpt_dir: str, tree: Any, metadata: Optional[Dict] = None) -> str:
    """Save a pytree of arrays. Returns the final directory path."""
    tmp = ckpt_dir + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    entries = []
    shard_idx, shard_bytes, shard_data = 0, 0, {}

    def flush():
        nonlocal shard_idx, shard_bytes, shard_data
        if not shard_data:
            return None
        fn = f"shard_{shard_idx:05d}.npz"
        np.savez(os.path.join(tmp, fn), **shard_data)
        with open(os.path.join(tmp, fn), "rb") as f:
            crc = zlib.crc32(f.read())
        shard_idx += 1
        shard_bytes = 0
        shard_data = {}
        return fn, crc

    crcs = {}
    for path, leaf in flat:
        key = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        shard_data[key] = arr
        shard_bytes += arr.nbytes
        entries.append({"key": key, "shape": list(arr.shape),
                        "dtype": str(arr.dtype), "shard": shard_idx})
        if shard_bytes >= _SHARD_BYTES:
            fn, crc = flush()
            crcs[fn] = crc
    r = flush()
    if r:
        crcs[r[0]] = r[1]

    manifest = {"entries": entries, "crcs": crcs,
                "metadata": metadata or {}}
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(ckpt_dir):
        shutil.rmtree(ckpt_dir)
    os.replace(tmp, ckpt_dir)
    return ckpt_dir


def load_metadata(ckpt_dir: str) -> Dict:
    with open(os.path.join(ckpt_dir, MANIFEST)) as f:
        return json.load(f)["metadata"]


def restore(ckpt_dir: str, target: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``target`` (arrays or SDS).

    ``shardings``: optional matching pytree of NamedShardings — arrays are
    placed with jax.device_put per leaf (resharding restore).
    """
    with open(os.path.join(ckpt_dir, MANIFEST)) as f:
        manifest = json.load(f)
    for fn, crc in manifest["crcs"].items():
        with open(os.path.join(ckpt_dir, fn), "rb") as f:
            if zlib.crc32(f.read()) != crc:
                raise IOError(f"checkpoint shard {fn} failed crc32 check")

    by_shard: Dict[int, list] = {}
    for e in manifest["entries"]:
        by_shard.setdefault(e["shard"], []).append(e["key"])
    data: Dict[str, np.ndarray] = {}
    for si, keys in by_shard.items():
        with np.load(os.path.join(ckpt_dir, f"shard_{si:05d}.npz")) as z:
            for k in keys:
                data[k] = z[k]

    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = treedef.flatten_up_to(shardings)
    out = []
    for i, (path, leaf) in enumerate(flat):
        key = _path_str(path)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"target {want_shape}")
        dt = leaf.dtype
        a = jnp.asarray(arr, dt)
        if shard_leaves is not None:
            a = jax.device_put(a, shard_leaves[i])
        out.append(a)
    return treedef.unflatten(out)


def latest_step_dir(root: str) -> Optional[str]:
    if not os.path.isdir(root):
        return None
    steps = [d for d in os.listdir(root) if d.startswith("step_")]
    if not steps:
        return None
    return os.path.join(root, max(steps, key=lambda s: int(s.split("_")[1])))
