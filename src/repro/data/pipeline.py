"""Deterministic synthetic LM data pipeline.

Every batch is a pure function of (seed, step, shard) so replayed steps are
bit-identical — the property the Nimrod/G journal relies on for exact
restart after failure, and the property elastic re-sharding relies on when
a job restarts with a different mesh shape.

The stream is a mixture of structured sources (Zipfian unigrams, repeated
n-gram motifs, copy tasks) so losses actually *decrease* during the
end-to-end examples rather than sitting at log(V).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.2
    motif_len: int = 8
    n_motifs: int = 64
    input_kind: str = "tokens"     # tokens | embeddings
    d_model: int = 0               # for embeddings stubs


def _zipf_probs(v: int, alpha: float) -> np.ndarray:
    r = np.arange(1, v + 1, dtype=np.float64)
    p = r ** (-alpha)
    return p / p.sum()


class SyntheticLM:
    """Stateless batch generator: ``batch(step, shard, n_shards)``."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self._probs = _zipf_probs(cfg.vocab_size, cfg.zipf_alpha)
        self._motifs = rng.integers(
            0, cfg.vocab_size, size=(cfg.n_motifs, cfg.motif_len))
        if cfg.input_kind == "embeddings":
            assert cfg.d_model > 0
            # frozen random codebook projecting token ids -> embeddings
            self._codebook = (rng.standard_normal(
                (min(cfg.vocab_size, 4096), cfg.d_model)) / np.sqrt(cfg.d_model)
            ).astype(np.float32)

    def _tokens(self, rng: np.random.Generator, b: int) -> np.ndarray:
        c = self.cfg
        toks = rng.choice(c.vocab_size, size=(b, c.seq_len + 1),
                          p=self._probs)
        # stamp motifs: learnable local structure
        n_stamp = max(1, c.seq_len // (4 * c.motif_len))
        for i in range(b):
            ids = rng.integers(0, c.n_motifs, size=n_stamp)
            pos = rng.integers(0, c.seq_len + 1 - c.motif_len, size=n_stamp)
            for m, p in zip(ids, pos):
                toks[i, p:p + c.motif_len] = self._motifs[m]
        return toks.astype(np.int32)

    def batch(self, step: int, shard: int = 0, n_shards: int = 1
              ) -> Dict[str, np.ndarray]:
        c = self.cfg
        assert c.global_batch % n_shards == 0
        b = c.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, shard]))
        toks = self._tokens(rng, b)
        out: Dict[str, np.ndarray] = {"labels": toks[:, 1:]}
        if c.input_kind == "tokens":
            out["tokens"] = toks[:, :-1]
        else:
            idx = toks[:, :-1] % self._codebook.shape[0]
            out["embeds"] = self._codebook[idx]
        return out

    def iterate(self, start_step: int = 0, shard: int = 0, n_shards: int = 1
                ) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, shard, n_shards)
            step += 1
