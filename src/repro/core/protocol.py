"""Wire protocol for the sharded grid (the paper's premise made literal).

Nimrod/G's broker, per-domain trade servers, directory/GIS and GridBank
are *independently owned, geographically distributed components* — so
every cross-domain interaction here is a typed, versioned message:
quote solicitation, sealed bids, contract award (reserve/cancel),
reservation transfer (secondary market), GIS register/heartbeat/query,
and GridBank settlement.

Messages are frozen dataclasses registered by ``kind``.  ``encode``
lowers one to a plain dict stamped with the protocol version; ``parse``
raises :class:`ProtocolError` on an unknown kind, a missing/unknown/
malformed ``v``, or fields that don't fit.  The invariant the whole
layer rests on::

    dumps(parse(json.loads(dumps(msg)))) == dumps(msg)

i.e. every message round-trips byte-identically through
``persistence.stable_dumps`` — canonical JSON with exact float reprs —
so journals, transports and replays all agree on the bytes.
"""
from __future__ import annotations

import dataclasses
import json
import math
import typing
from typing import Any, Dict, List, Optional, Tuple, Type

from repro.core.persistence import stable_dumps

PROTOCOL_VERSION = 1

# kind -> message class; the round-trip test walks this registry, so a
# message type that forgets to register cannot ship untested
MESSAGE_TYPES: Dict[str, Type["Message"]] = {}


class ProtocolError(ValueError):
    """Malformed, unknown, or version-incompatible wire message."""


def message(kind: str):
    """Class decorator: freeze, register, and stamp the wire kind."""
    def wrap(cls):
        cls = dataclasses.dataclass(frozen=True)(cls)
        cls.wire_kind = kind
        if kind in MESSAGE_TYPES:
            raise ValueError(f"duplicate message kind {kind!r}")
        MESSAGE_TYPES[kind] = cls
        return cls
    return wrap


class Message:
    """Base for wire messages (dataclass mixin; subclasses set fields)."""
    wire_kind = ""


def _lower(v: Any) -> Any:
    """Dataclass/tuple values lower to JSON-able structures.  Non-finite
    floats are JSON-illegal; encode them as tagged strings so inf ETAs
    (a drained site's rejoin time) survive the wire."""
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return {f.name: _lower(getattr(v, f.name))
                for f in dataclasses.fields(v)}
    if isinstance(v, (list, tuple)):
        return [_lower(x) for x in v]
    if isinstance(v, dict):
        return {k: _lower(x) for k, x in v.items()}
    if isinstance(v, float) and not math.isfinite(v):
        return {"__f": repr(v)}
    return v


def encode(msg: Message) -> Dict[str, Any]:
    """Lower a message to its wire dict: ``{"v": 1, "type": kind, ...}``."""
    if type(msg) is not MESSAGE_TYPES.get(msg.wire_kind):
        raise ProtocolError(f"not a registered message: {msg!r}")
    d = {"v": PROTOCOL_VERSION, "type": msg.wire_kind}
    for f in dataclasses.fields(msg):
        d[f.name] = _lower(getattr(msg, f.name))
    return d


def dumps(msg: Message) -> str:
    """Canonical wire bytes (sans framing) for one message."""
    return stable_dumps(encode(msg))


_NONFIN = {"inf": math.inf, "-inf": -math.inf, "nan": math.nan}


def _raise(v: Any, hint: Any) -> Any:
    """Raise a wire value back toward the annotated field type."""
    if isinstance(v, dict) and set(v) == {"__f"}:
        try:
            return _NONFIN[v["__f"]]
        except KeyError:
            raise ProtocolError(f"bad non-finite float tag {v!r}")
    origin = typing.get_origin(hint)
    if origin is typing.Union:                  # Optional[X] and friends
        if v is None:
            return None
        args = [a for a in typing.get_args(hint) if a is not type(None)]
        return _raise(v, args[0]) if len(args) == 1 else v
    if origin in (tuple, list) and isinstance(v, list):
        args = typing.get_args(hint)
        inner = args[0] if args else Any
        seq = [_raise(x, inner) for x in v]
        return tuple(seq) if origin is tuple else seq
    if origin is dict and isinstance(v, dict):
        args = typing.get_args(hint)
        inner = args[1] if len(args) == 2 else Any
        return {k: _raise(x, inner) for k, x in v.items()}
    if hint is float and isinstance(v, int) and not isinstance(v, bool):
        # JSON can't tell 2.0 from 2 — but the byte-identity invariant
        # needs it to: keep what the wire carried
        return v
    if dataclasses.is_dataclass(hint) and isinstance(v, dict):
        hints = typing.get_type_hints(hint)
        kw = {}
        for f in dataclasses.fields(hint):
            if f.name in v:
                kw[f.name] = _raise(v[f.name], hints.get(f.name, Any))
        try:
            return hint(**kw)
        except TypeError as e:
            raise ProtocolError(f"bad {hint.__name__} payload: {e}")
    return v


def parse(d: Dict[str, Any]) -> Message:
    """Raise a wire dict back to its typed message.

    Rejects — with a clear error — a payload that is not a dict, lacks
    ``v``/``type``, carries an unknown or non-integer version, an
    unknown kind, unexpected fields, or misses required ones."""
    if not isinstance(d, dict):
        raise ProtocolError(f"wire message must be a dict, got "
                            f"{type(d).__name__}")
    if "v" not in d:
        raise ProtocolError("wire message missing protocol version 'v'")
    v = d["v"]
    if not isinstance(v, int) or isinstance(v, bool):
        raise ProtocolError(f"protocol version must be an int, got {v!r}")
    if v != PROTOCOL_VERSION:
        raise ProtocolError(f"unsupported protocol version {v} "
                            f"(this build speaks {PROTOCOL_VERSION})")
    kind = d.get("type")
    cls = MESSAGE_TYPES.get(kind)
    if cls is None:
        raise ProtocolError(f"unknown message type {kind!r}")
    hints = typing.get_type_hints(cls)
    kw = {}
    for f in dataclasses.fields(cls):
        if f.name in d:
            kw[f.name] = _raise(d[f.name], hints.get(f.name, Any))
        elif (f.default is dataclasses.MISSING
              and f.default_factory is dataclasses.MISSING):
            raise ProtocolError(f"{kind}: missing required field "
                                f"{f.name!r}")
    extra = set(d) - {"v", "type"} - {f.name for f in dataclasses.fields(cls)}
    if extra:
        raise ProtocolError(f"{kind}: unexpected fields {sorted(extra)}")
    try:
        return cls(**kw)
    except (TypeError, ValueError) as e:
        raise ProtocolError(f"{kind}: bad payload: {e}")


def loads(s: str) -> Message:
    try:
        d = json.loads(s)
    except json.JSONDecodeError as e:
        raise ProtocolError(f"undecodable wire bytes: {e}")
    return parse(d)


# ---------------------------------------------------------------------------
# wire structs (payload fragments shared by several messages)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WireBid:
    """One sealed bid as it crosses the wire (mirrors ``economy.Bid``)."""
    resource: str
    chip_hour_price: float
    available_slots: int
    est_rate: float
    valid_until: float
    resale_rid: int = 0


@dataclasses.dataclass(frozen=True)
class WireReservation:
    """An awarded reservation (mirrors ``economy.Reservation``)."""
    resource: str
    user: str
    start: float
    end: float
    locked_price: float
    reservation_id: int = 0


@dataclasses.dataclass(frozen=True)
class WireSpec:
    """Static resource attributes mirrored to brokers at sync time."""
    name: str
    site: str
    department: str = ""
    chips: int = 8
    peak_flops_per_chip: float = 197e12
    perf_factor: float = 1.0
    slots: int = 1
    base_price: float = 1.0
    peak_multiplier: float = 2.0
    mtbf_hours: float = 400.0
    mttr_hours: float = 1.0
    closed: bool = False
    authorized_users: Tuple[str, ...] = ()
    stage_bw: float = 1e9


@dataclasses.dataclass(frozen=True)
class WireGISEntry:
    """One GIS answer row (mirrors ``gis.GISEntry`` over the wire)."""
    name: str
    site: str
    department: str
    enterprise: str
    chips: int
    advertised_price: float
    last_heartbeat: float
    suspected: bool


# ---------------------------------------------------------------------------
# quote solicitation and sealed bids
# ---------------------------------------------------------------------------

@message("quote_request")
class QuoteRequest(Message):
    """Spot quote for one resource (``TradeServer.quote``); ``forward``
    asks for the posted no-demand-premium schedule instead."""
    resource: str
    t: float
    user: str = ""
    forward: bool = False


@message("price_reply")
class PriceReply(Message):
    price: float
    book_version: int = 0


@message("solicit_request")
class SolicitRequest(Message):
    """Open-market tender.  The broker's ``est_job_seconds`` callable
    cannot cross a process boundary, so the proxy evaluates it against
    its spec mirror and ships the per-resource estimates."""
    t: float
    user: str
    est_seconds: Dict[str, float]
    default_est: float = 3600.0


@message("bids_reply")
class BidsReply(Message):
    bids: Tuple[WireBid, ...]
    book_version: int = 0


# -- contract award ----------------------------------------------------

@message("reserve_request")
class ReserveRequest(Message):
    """Award one price-locked advance reservation.  ``request_id`` makes
    the award idempotent across crash/replay: a domain that already
    journaled this id returns the recorded reservation instead of
    double-booking the window."""
    request_id: str
    resource: str
    user: str
    start: float
    end: float
    t: float
    locked_price: Optional[float] = None


@message("reserve_reply")
class ReserveReply(Message):
    ok: bool
    reservation: Optional[WireReservation] = None
    error: str = ""
    book_version: int = 0


@message("cancel_request")
class CancelRequest(Message):
    reservation_id: int


@message("find_request")
class FindRequest(Message):
    """Locate one reservation by federation-unique id (the secondary
    market's locate path over the wire).  Answered with ReserveReply:
    ``ok=False`` when the id is not on this domain's book."""
    reservation_id: int


@message("ok_reply")
class OkReply(Message):
    ok: bool
    book_version: int = 0


# -- reservation transfer (secondary market) ---------------------------

@message("transfer_request")
class TransferRequest(Message):
    """Resale fill: the reservation changes hands, not shape."""
    reservation_id: int
    buyer: str
    t: float


@message("transfer_reply")
class TransferReply(Message):
    ok: bool
    reservation: Optional[WireReservation] = None
    error: str = ""
    book_version: int = 0


# -- book reads ---------------------------------------------------------

@message("book_request")
class BookRequest(Message):
    """One routed book read: ``op`` picks the TradeServer method."""
    op: str                     # reserved_price|reserved_price_list|...
    resource: str
    user: str
    t: float
    # honored_price extras
    sealed_price: float = 0.0
    sealed_at: float = 0.0
    # reservable_slots window
    start: float = 0.0
    end: float = 0.0


@message("book_reply")
class BookReply(Message):
    prices: Tuple[float, ...] = ()
    price: Optional[float] = None
    slots: int = 0
    book_version: int = 0


@message("status_request")
class StatusRequest(Message):
    """Domain ground truth for one resource (liveness + occupancy)."""
    resource: str


@message("status_reply")
class StatusReply(Message):
    up: bool
    running: int
    queued: int = 0
    version: int = 0


@message("sync_request")
class SyncRequest(Message):
    """Connect-time mirror fetch: the domain's spec slice and stamps."""
    user: str = ""


@message("sync_reply")
class SyncReply(Message):
    site: str
    specs: Tuple[WireSpec, ...]
    bid_validity: float
    book_version: int = 0
    membership_version: int = 0
    # where the domain's reservation-id counter stands: the broker-side
    # proxy mirrors it so federation restriding reproduces the direct
    # arithmetic exactly (including after a crash-replay)
    next_rid: int = 1
    rid_step: int = 1


@message("restride_request")
class RestrideRequest(Message):
    """Federation rid striding made explicit: the coordinator assigns
    each domain its residue class so reservation ids stay unique
    grid-wide (``TradeFederation._restride`` over the wire)."""
    next_rid: int
    rid_step: int


# ---------------------------------------------------------------------------
# GIS: register / heartbeat / query
# ---------------------------------------------------------------------------

@message("gis_register")
class GISRegister(Message):
    spec: WireSpec
    t: float


@message("gis_deregister")
class GISDeregister(Message):
    name: str
    t: float


@message("gis_heartbeat")
class GISHeartbeat(Message):
    """One liveness beat; ``advertised_price`` rides along exactly as
    the in-process GIS refreshes it from ``price_fn``."""
    name: str
    t: float
    advertised_price: float = 0.0


@message("gis_pump")
class GISPump(Message):
    """Pump every live resource's heartbeat at ``t`` (domain-local)."""
    t: float


@message("gis_query")
class GISQuery(Message):
    t: float
    user: str = ""
    level: str = "global"
    within: Optional[str] = None
    min_chips: int = 0
    max_price: float = math.inf
    include_suspected: bool = False


@message("gis_query_reply")
class GISQueryReply(Message):
    entries: Tuple[WireGISEntry, ...]
    version: int = 0


# ---------------------------------------------------------------------------
# GridBank settlement
# ---------------------------------------------------------------------------

@message("settle_request")
class SettleRequest(Message):
    """One bank entry, pushed to the owning domain's ledger.
    ``settlement_id`` is the exactly-once key: a replayed or retried
    settlement must never double-book revenue."""
    settlement_id: str
    t: float
    user: str
    owner: str
    resource: str
    amount: float
    kind: str = "settle"


@message("settle_reply")
class SettleReply(Message):
    ok: bool
    duplicate: bool = False
    error: str = ""


@message("revenue_request")
class RevenueRequest(Message):
    """Audit read: the domain's recorded revenue ledger, for exact
    (bit-for-bit) reconciliation against the broker-side GridBank."""
    owner: str = ""


@message("revenue_reply")
class RevenueReply(Message):
    # (settlement_id, user, resource, amount, kind, t) rows, in journal
    # order — reconciliation compares these exactly, never a float sum
    entries: Tuple[Tuple[str, str, str, float, str, float], ...]


@message("error_reply")
class ErrorReply(Message):
    """Remote exception surfaced to the caller.  ``admission=True``
    re-raises as ``AdmissionError`` so broker code that negotiates
    against a local server keeps its except clauses unchanged."""
    error: str
    admission: bool = False


@message("shutdown_request")
class ShutdownRequest(Message):
    """Orderly domain shutdown (flush journal, close listener)."""
    reason: str = ""


def example_messages() -> List[Message]:
    """One well-formed instance of every registered type — the seed
    corpus for round-trip tests (hypothesis fuzzes beyond these)."""
    spec = WireSpec(name="anl-000", site="ANL")
    return [
        QuoteRequest(resource="anl-000", t=120.0, user="u0"),
        PriceReply(price=1.25, book_version=3),
        SolicitRequest(t=60.0, user="u0", est_seconds={"anl-000": 1800.0}),
        BidsReply(bids=(WireBid("anl-000", 1.5, 1, 2.0, 3660.0),)),
        ReserveRequest(request_id="u0:c1:0", resource="anl-000", user="u0",
                       start=0.0, end=3600.0, t=0.0, locked_price=1.1),
        ReserveReply(ok=True, reservation=WireReservation(
            "anl-000", "u0", 0.0, 3600.0, 1.1, 7)),
        CancelRequest(reservation_id=7),
        FindRequest(reservation_id=7),
        OkReply(ok=True),
        TransferRequest(reservation_id=7, buyer="u1", t=10.0),
        TransferReply(ok=True, reservation=WireReservation(
            "anl-000", "u1", 0.0, 3600.0, 1.1, 7)),
        BookRequest(op="reserved_price", resource="anl-000", user="u0",
                    t=5.0),
        BookReply(prices=(1.1,), price=1.1, slots=1),
        StatusRequest(resource="anl-000"),
        StatusReply(up=True, running=1, queued=0, version=4),
        SyncRequest(user="u0"),
        SyncReply(site="ANL", specs=(spec,), bid_validity=3600.0),
        RestrideRequest(next_rid=11, rid_step=4),
        GISRegister(spec=spec, t=0.0),
        GISDeregister(name="anl-000", t=9.0),
        GISHeartbeat(name="anl-000", t=300.0, advertised_price=1.2),
        GISPump(t=300.0),
        GISQuery(t=600.0, user="u0", max_price=math.inf),
        GISQueryReply(entries=(WireGISEntry(
            "anl-000", "ANL", "ANL/d0", "ANL", 8, 1.2, 300.0, False),)),
        SettleRequest(settlement_id="u0:j00001:1", t=1800.0, user="u0",
                      owner="ANL", resource="anl-000", amount=2.5),
        SettleReply(ok=True),
        RevenueRequest(owner="ANL"),
        RevenueReply(entries=(("u0:j00001:1", "u0", "anl-000", 2.5,
                               "settle", 1800.0),)),
        ErrorReply(error="window full", admission=True),
        ShutdownRequest(reason="test"),
    ]
