"""Deterministic discrete-event simulator (virtual clock).

The paper evaluated on the live GUSTO testbed but explicitly planned a
simulated model for studying the economy ("we plan to build a simulated
model for investigation purposes").  This is that model: resource
failures, repairs, exogenous load and price movement all unfold in virtual
time from seeded RNG streams, so every scheduling experiment is exactly
reproducible (and unit-testable).
"""
from __future__ import annotations

import heapq
import itertools
import math
import random
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.resources import ResourceDirectory, ResourceSpec


class Timer:
    """Cancellable handle for one scheduled event.

    Cancellation is lazy: the heap entry stays where it is and is
    discarded unfired when it reaches the top — O(1) to cancel, no heap
    surgery.  A cancelled entry neither advances the clock nor counts
    against the event budget, and it can never distort the final-clock
    clamp at the ``run(until=...)`` boundary."""
    __slots__ = ("cancelled",)

    def __init__(self):
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True


class RepeatingTimer:
    """Handle for an ``every()`` chain: cancelling it stops the series —
    both the firing currently in the heap and every rescheduling after."""
    __slots__ = ("cancelled", "_current")

    def __init__(self):
        self.cancelled = False
        self._current: Optional[Timer] = None

    def cancel(self) -> None:
        self.cancelled = True
        if self._current is not None:
            self._current.cancel()


class Simulator:
    def __init__(self, start: float = 0.0):
        self._t = start
        self._heap: List[Tuple[float, int, Callable[[], None], Timer]] = []
        self._seq = itertools.count()
        self.stopped = False
        self.events = 0              # events actually fired, ever

    @property
    def now(self) -> float:
        return self._t

    def at(self, t: float, fn: Callable[[], None]) -> Timer:
        if t < self._t - 1e-9:
            raise ValueError(f"scheduling into the past: {t} < {self._t}")
        handle = Timer()
        heapq.heappush(self._heap, (t, next(self._seq), fn, handle))
        return handle

    def after(self, delay: float, fn: Callable[[], None]) -> Timer:
        return self.at(self._t + max(0.0, delay), fn)

    def every(self, interval: float, fn: Callable[[], None], *,
              start_delay: Optional[float] = None,
              until: float = math.inf) -> RepeatingTimer:
        """Recurring event (e.g. an auction clearing round): run ``fn``
        every ``interval`` seconds until ``until``, until ``fn`` returns
        a truthy "stop" value, or until the returned handle is
        cancelled.  The first firing is after ``start_delay`` (defaults
        to ``interval``)."""
        if interval <= 0:
            raise ValueError(f"interval must be positive, got {interval}")
        handle = RepeatingTimer()

        def fire():
            if handle.cancelled or self._t > until or self.stopped:
                return
            stop = fn()
            if not stop and not handle.cancelled \
                    and self._t + interval <= until:
                handle._current = self.after(interval, fire)

        handle._current = self.after(
            interval if start_delay is None else start_delay, fire)
        return handle

    def _drop_cancelled_head(self) -> None:
        while self._heap and self._heap[0][3].cancelled:
            heapq.heappop(self._heap)

    def run(self, until: float = math.inf, max_events: int = 10_000_000
            ) -> None:
        n = 0
        while not self.stopped:
            self._drop_cancelled_head()
            if not self._heap:
                break
            t, _, fn, _h = self._heap[0]
            if t > until:
                break
            heapq.heappop(self._heap)
            self._t = t
            fn()
            n += 1
            self.events += 1
            if n >= max_events:
                raise RuntimeError("simulator event budget exceeded "
                                   "(runaway loop?)")
        if not self.stopped:
            self._drop_cancelled_head()
            self._t = max(self._t, min(until, self._t if not self._heap
                                       else self._heap[0][0]))

    def stop(self) -> None:
        self.stopped = True

    def pending_events(self) -> int:
        """Live (non-cancelled) entries still in the heap."""
        return sum(1 for e in self._heap if not e[3].cancelled)


class FailureProcess:
    """Alternating up/down renewal process per resource (MTBF/MTTR),
    deterministic per (seed, resource)."""

    def __init__(self, sim: Simulator, directory: ResourceDirectory,
                 seed: int = 0,
                 on_down: Optional[Callable[[str], None]] = None,
                 on_up: Optional[Callable[[str], None]] = None,
                 tracer=None):
        self.sim = sim
        self.directory = directory
        self.seed = seed
        self.on_down = on_down or (lambda r: None)
        self.on_up = on_up or (lambda r: None)
        self.tracer = tracer            # optional telemetry.Tracer

    def install(self, name: str) -> None:
        spec = self.directory.spec(name)
        if not math.isfinite(spec.mtbf_hours) or spec.mtbf_hours <= 0:
            return
        rng = random.Random(f"{self.seed}|{name}")
        self._schedule_failure(name, spec, rng)

    def _schedule_failure(self, name: str, spec: ResourceSpec,
                          rng: random.Random) -> None:
        dt = rng.expovariate(1.0 / (spec.mtbf_hours * 3600.0))

        def fail():
            st = self.directory.status(name)
            repair = rng.expovariate(1.0 / max(spec.mttr_hours * 3600.0, 1.0))
            if st.up and not st.departed:
                st.up = False
                # publish the scheduled repair time: information services
                # answer "ETA back up" from this, not from omniscience
                st.next_transition = self.sim.now + repair
                self.on_down(name)
                if self.tracer is not None:
                    self.tracer.instant(
                        self.sim.now, f"site:{spec.site}", "churn",
                        "resource_down", resource=name,
                        eta=st.next_transition)

            def fix():
                # a departed site owns its machines' fate: the renewal
                # process keeps ticking but must not resurrect them
                if not st.departed:
                    st.up = True
                    st.next_transition = math.inf
                    self.on_up(name)
                    if self.tracer is not None:
                        self.tracer.instant(
                            self.sim.now, f"site:{spec.site}", "churn",
                            "resource_up", resource=name)
                self._schedule_failure(name, spec, rng)

            self.sim.after(repair, fix)

        self.sim.after(dt, fail)


class ChurnProcess:
    """Site-level membership churn: whole administrative domains join
    and leave the grid mid-run (the abstract's "resources ... may span
    many administrative domains" is a statement about *time* too — a
    global testbed's membership is never fixed).

    Alternating leave/rejoin renewal process per site, deterministic per
    (seed, site) exactly like ``FailureProcess`` per resource.  The
    mechanics of departure (deregistering from the GIS, failing over
    in-flight jobs, refunding contracts) belong to the driver:

    * ``on_leave(site, rejoin_at) -> bool`` — return False to VETO the
      departure (e.g. it would empty the grid); the process then just
      re-draws a later departure time.  ``rejoin_at`` is the already
      scheduled return time, for publishing as the resources' ETA.
    * ``on_join(site)`` — the site is back.
    """

    def __init__(self, sim: Simulator, directory: ResourceDirectory,
                 seed: int = 0, *,
                 mean_uptime_hours: float = 8.0,
                 mean_downtime_hours: float = 2.0,
                 on_leave: Optional[Callable[[str, float], bool]] = None,
                 on_join: Optional[Callable[[str], None]] = None):
        if mean_uptime_hours <= 0 or mean_downtime_hours <= 0:
            raise ValueError("churn means must be positive")
        self.sim = sim
        self.directory = directory
        self.seed = seed
        self.mean_uptime = mean_uptime_hours * 3600.0
        self.mean_downtime = mean_downtime_hours * 3600.0
        self.on_leave = on_leave or (lambda s, eta: True)
        self.on_join = on_join or (lambda s: None)
        self.events: List[Tuple[float, str, str]] = []   # (t, kind, site)

    def install(self, site: str) -> None:
        rng = random.Random(f"{self.seed}|churn|{site}")
        self._schedule_leave(site, rng)

    def _schedule_leave(self, site: str, rng: random.Random) -> None:
        dt = rng.expovariate(1.0 / self.mean_uptime)

        def leave():
            downtime = rng.expovariate(1.0 / self.mean_downtime)
            rejoin_at = self.sim.now + downtime
            if not self.on_leave(site, rejoin_at):
                # vetoed (e.g. last site standing): stay, try later
                self._schedule_leave(site, rng)
                return
            self.events.append((self.sim.now, "leave", site))

            def join():
                self.events.append((self.sim.now, "join", site))
                self.on_join(site)
                self._schedule_leave(site, rng)

            self.sim.after(downtime, join)

        self.sim.after(dt, leave)


def duration_model(spec: ResourceSpec, est_seconds_base: float,
                   stage_in_bytes: int, stage_out_bytes: int,
                   *, load: float = 0.0, noise_sigma: float = 0.15,
                   seed: Tuple = ()) -> Tuple[float, float, float]:
    """Returns (stage_in_s, exec_s, stage_out_s) — deterministic in seed.

    Closed clusters pay a 2x staging penalty (the paper's proxy mediates
    all I/O through the master node)."""
    rng = random.Random("|".join(str(s) for s in seed) if seed else 0)
    noise = math.exp(rng.gauss(0.0, noise_sigma)) if noise_sigma else 1.0
    penalty = 2.0 if spec.closed else 1.0
    s_in = penalty * stage_in_bytes / spec.stage_bw
    s_out = penalty * stage_out_bytes / spec.stage_bw
    ex = est_seconds_base / max(spec.perf_factor, 1e-6)
    ex = ex / max(1.0 - load, 0.05) * noise
    return s_in, ex, s_out
