"""Deterministic discrete-event simulator (virtual clock).

The paper evaluated on the live GUSTO testbed but explicitly planned a
simulated model for studying the economy ("we plan to build a simulated
model for investigation purposes").  This is that model: resource
failures, repairs, exogenous load and price movement all unfold in virtual
time from seeded RNG streams, so every scheduling experiment is exactly
reproducible (and unit-testable).
"""
from __future__ import annotations

import heapq
import itertools
import math
import random
import time as _time
from bisect import insort
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.resources import ResourceDirectory, ResourceSpec


class Timer:
    """Cancellable handle for one scheduled event.

    Cancellation is lazy: the queue entry stays where it is and is
    discarded unfired when its turn comes — O(1) to cancel, no queue
    surgery.  A cancelled entry neither advances the clock nor counts
    against the event budget, and it can never distort the final-clock
    clamp at the ``run(until=...)`` boundary.  The back-reference lets
    the simulator keep an exact dead-entry tally (and compact the
    calendar when the dead dominate) without ever scanning."""
    __slots__ = ("cancelled", "_q")

    def __init__(self, q=None):
        self.cancelled = False
        self._q = q

    def cancel(self) -> None:
        if self.cancelled:
            return
        self.cancelled = True
        q = self._q
        if q is not None:        # still stored somewhere in the queue
            q._note_cancel()


class RepeatingTimer:
    """Handle for an ``every()`` chain: cancelling it stops the series —
    both the firing currently in the heap and every rescheduling after."""
    __slots__ = ("cancelled", "_current")

    def __init__(self):
        self.cancelled = False
        self._current: Optional[Timer] = None

    def cancel(self) -> None:
        self.cancelled = True
        if self._current is not None:
            self._current.cancel()


class Simulator:
    """Virtual clock over an array-backed calendar queue.

    The event set here is dominated by dense periodic load — broker
    ticks, GIS heartbeat pumps, auction clearing rounds — plus a band
    of job-completion timers a few thousand seconds out.  A single
    binary heap pays O(log n) per op and lets lazily-cancelled timers
    pile up; the calendar queue instead bins events into fixed-width
    time buckets (a page of ``wheel_buckets`` buckets, advanced as the
    clock crosses it), with a small overflow heap for far-future events
    (failure renewals at MTBF scale).  Scheduling is an append +
    occupancy bump — O(1) — and each bucket is sorted once when the
    clock reaches it, so total ordering cost is O(sum k_i log k_i) over
    bucket sizes instead of O(n log n) over the whole horizon.  Event
    order is EXACTLY the heap's: the global (t, seq) lexicographic
    order, seq allocated at schedule time — byte-identical schedules.

    Exact-dead-count bookkeeping (``Timer._q``) replaces the old "dead
    until popped" regime: when cancelled entries outnumber live ones
    the whole calendar compacts in one pass, so churny runs (straggler
    duplicate cancels, site evictions) keep the queue at O(live)."""

    def __init__(self, start: float = 0.0, *, bucket_width: float = 60.0,
                 wheel_buckets: int = 1024):
        if bucket_width <= 0:
            raise ValueError("bucket_width must be positive")
        if wheel_buckets < 1:
            raise ValueError("wheel_buckets must be >= 1")
        self._t = start
        self._seq = itertools.count()
        self.stopped = False
        self.events = 0              # events actually fired, ever
        # -- calendar state --
        self._width = float(bucket_width)
        self._inv_w = 1.0 / self._width
        self._nb = int(wheel_buckets)
        self._base = start           # time origin of bucket index 0
        self._page = 0               # absolute bucket index of slot 0
        self._buckets: List[List[tuple]] = [[] for _ in range(self._nb)]
        self._slot = 0               # wheel slot the drain has reached
        self._cur: Optional[List[tuple]] = None   # detached, sorted
        self._cur_i = 0
        self._overflow: List[tuple] = []          # heapq, beyond page
        self._size = 0               # stored entries (live + dead)
        self._dead = 0               # stored entries already cancelled

    @property
    def now(self) -> float:
        return self._t

    # -- scheduling ----------------------------------------------------
    def at(self, t: float, fn: Callable[[], None]) -> Timer:
        if t < self._t - 1e-9:
            raise ValueError(f"scheduling into the past: {t} < {self._t}")
        handle = Timer(self)
        entry = (t, next(self._seq), fn, handle)
        s = int((t - self._base) * self._inv_w) - self._page \
            if math.isfinite(t) else self._nb
        if s >= self._nb:
            heapq.heappush(self._overflow, entry)
        elif s <= self._slot and self._cur is not None:
            # the target bucket is the one being drained (or an epsilon
            # behind it): splice into the not-yet-fired tail — the
            # (t, seq) key lands it exactly where the heap would
            insort(self._cur, entry, lo=self._cur_i)
        else:
            if s < self._slot:
                s = self._slot       # drained buckets never re-checked
            self._buckets[s].append(entry)
        self._size += 1
        return handle

    def after(self, delay: float, fn: Callable[[], None]) -> Timer:
        return self.at(self._t + max(0.0, delay), fn)

    def every(self, interval: float, fn: Callable[[], None], *,
              start_delay: Optional[float] = None,
              until: float = math.inf) -> RepeatingTimer:
        """Recurring event (e.g. an auction clearing round): run ``fn``
        every ``interval`` seconds until ``until``, until ``fn`` returns
        a truthy "stop" value, or until the returned handle is
        cancelled.  The first firing is after ``start_delay`` (defaults
        to ``interval``)."""
        if interval <= 0:
            raise ValueError(f"interval must be positive, got {interval}")
        handle = RepeatingTimer()

        def fire():
            if handle.cancelled or self._t > until or self.stopped:
                return
            stop = fn()
            if not stop and not handle.cancelled \
                    and self._t + interval <= until:
                handle._current = self.after(interval, fire)

        handle._current = self.after(
            interval if start_delay is None else start_delay, fire)
        return handle

    # -- drain machinery -----------------------------------------------
    def _next_slot(self) -> int:
        """First wheel slot at or after the drain point with entries,
        or -1.  Dense periodic load (ticks, heartbeats) occupies
        adjacent buckets, so this probe almost always hits in a step
        or two; long gaps cost one pass over empty list slots."""
        b = self._buckets
        for s in range(self._slot, self._nb):
            if b[s]:
                return s
        return -1

    def _advance_page(self) -> bool:
        """Wheel exhausted: move the page to the overflow head's bucket
        and pull every overflow entry inside the new page in."""
        if not self._overflow:
            return False
        head_t = self._overflow[0][0]
        if not math.isfinite(head_t):
            # pathological all-infinite tail: drain it as one bucket
            self._cur = sorted(self._overflow)
            self._cur_i = 0
            self._overflow = []
            return True
        self._page = int((head_t - self._base) * self._inv_w)
        self._slot = 0
        end_t = self._base + (self._page + self._nb) * self._width
        buckets, page, inv_w = self._buckets, self._page, self._inv_w
        of = self._overflow
        while of and of[0][0] < end_t:
            entry = heapq.heappop(of)
            s = int((entry[0] - self._base) * inv_w) - page
            if s < 0:
                s = 0
            buckets[s].append(entry)
        return True

    def _peek(self) -> Optional[tuple]:
        """Next live entry in exact (t, seq) order, without consuming
        it.  Cancelled entries encountered on the way are dropped here
        (they never advance the clock or count against the budget)."""
        while True:
            cur = self._cur
            if cur is not None:
                i, n = self._cur_i, len(cur)
                while i < n:
                    entry = cur[i]
                    h = entry[3]
                    if not h.cancelled:
                        self._cur_i = i
                        return entry
                    h._q = None
                    self._size -= 1
                    self._dead -= 1
                    i += 1
                self._cur_i = i
                self._cur = None
                self._slot += 1
            s = self._next_slot()
            if s < 0:
                if not self._advance_page():
                    return None
                continue
            self._slot = s
            lst = self._buckets[s]
            self._buckets[s] = []
            lst.sort()
            self._cur = lst
            self._cur_i = 0

    def _consume(self, entry: tuple) -> None:
        self._cur_i += 1
        self._size -= 1
        entry[3]._q = None           # fired: a late cancel() is a no-op

    # -- cancellation bookkeeping --------------------------------------
    def _note_cancel(self) -> None:
        self._dead += 1
        if self._dead * 2 > self._size and self._size > 64:
            self._compact()

    def _compact(self) -> None:
        """Rebuild every store minus the cancelled entries — runs when
        the dead outnumber the live, so each stored entry is copied
        O(1) amortized times over its lifetime and a churny run's queue
        stays O(live) instead of O(ever scheduled)."""
        live = lambda e: not e[3].cancelled          # noqa: E731
        n = 0
        if self._cur is not None:
            self._cur = [e for e in self._cur[self._cur_i:] if live(e)]
            self._cur_i = 0
            n += len(self._cur)
        for s in range(self._slot, self._nb):
            if self._buckets[s]:
                b = [e for e in self._buckets[s] if live(e)]
                self._buckets[s] = b
                n += len(b)
        of = [e for e in self._overflow if live(e)]
        heapq.heapify(of)
        self._overflow = of
        self._size = n + len(of)
        self._dead = 0

    # -- the loop ------------------------------------------------------
    def run(self, until: float = math.inf, max_events: int = 10_000_000
            ) -> None:
        n = 0
        while not self.stopped:
            entry = self._peek()
            if entry is None:
                break
            t = entry[0]
            if t > until:
                break
            self._consume(entry)
            self._t = t
            entry[2]()
            n += 1
            self.events += 1
            if n >= max_events:
                raise RuntimeError("simulator event budget exceeded "
                                   "(runaway loop?)")
        if not self.stopped:
            entry = self._peek()
            self._t = max(self._t, min(until, self._t if entry is None
                                       else entry[0]))

    def stop(self) -> None:
        self.stopped = True

    def pending_events(self) -> int:
        """Live (non-cancelled) entries still scheduled.  The dead
        tally is exact (``Timer._q``), so this is O(1)."""
        return self._size - self._dead


class WallClockSimulator(Simulator):
    """Deployment mode: the same calendar queue, but events fire at
    their virtual deadline in *real* time.

    The paper's system is not a simulation — brokers, trade servers and
    the GIS run as long-lived services.  This clock is the bridge: any
    driver written against ``Simulator`` (heartbeat pumps, clearing
    rounds, broker ticks) deploys unchanged by swapping the clock.
    ``time_scale`` is sim-seconds per wall-second (3600 = an hour of
    market time per second — demo speed; 1.0 = true real time).  Event
    *order* is identical to the virtual clock's (same (t, seq) heap
    order); only the pacing differs, so a wall-clock run exercises
    exactly the code paths a simulated one validated."""

    def __init__(self, start: float = 0.0, *, time_scale: float = 1.0,
                 sleep: Callable[[float], None] = _time.sleep,
                 wall: Callable[[], float] = _time.monotonic, **kw):
        if time_scale <= 0:
            raise ValueError("time_scale must be positive")
        super().__init__(start, **kw)
        self.time_scale = time_scale
        self._sleep = sleep
        self._wall = wall

    def run(self, until: float = math.inf, max_events: int = 10_000_000
            ) -> None:
        anchor_wall = self._wall()
        anchor_sim = self._t
        n = 0
        while not self.stopped:
            entry = self._peek()
            if entry is None:
                break
            t = entry[0]
            if t > until:
                break
            # sleep off the real-time gap to the deadline; a late event
            # (callback overran) fires immediately — no catch-up skips,
            # the schedule just runs behind like any real service would
            lag = (t - anchor_sim) / self.time_scale \
                - (self._wall() - anchor_wall)
            if lag > 0:
                self._sleep(lag)
            self._consume(entry)
            self._t = t
            entry[2]()
            n += 1
            self.events += 1
            if n >= max_events:
                raise RuntimeError("simulator event budget exceeded "
                                   "(runaway loop?)")
        if not self.stopped:
            entry = self._peek()
            self._t = max(self._t, min(until, self._t if entry is None
                                       else entry[0]))


class ConservativeClock:
    """Conservative distributed-simulation clock: per-link lookahead and
    lower-bound time stamps (LBTS), for sharding one deterministic
    simulation across domain processes.

    Each *link* is a message source (a domain process, the broker).
    ``lookahead(link)`` is the promise "no message from this link will
    ever carry a timestamp earlier than its clock + lookahead" — in this
    grid, a domain's lookahead is its minimum network/handling latency
    (heartbeat interval for the GIS link, dispatch latency for brokers).
    A shard may safely simulate up to ``lbts(exclude=itself)``: the
    earliest instant any *other* link could still inject an event.
    All-links-blocked deadlock is the classic conservative failure mode;
    ``grant`` detects a stalled horizon so drivers can exchange null
    messages (advance their clocks with nothing to say)."""

    def __init__(self):
        self._clock: Dict[str, float] = {}
        self._lookahead: Dict[str, float] = {}

    def add_link(self, name: str, lookahead: float,
                 start: float = 0.0) -> None:
        if lookahead < 0:
            raise ValueError("lookahead must be >= 0")
        if name in self._clock:
            raise ValueError(f"link {name!r} already registered")
        self._clock[name] = start
        self._lookahead[name] = lookahead

    def remove_link(self, name: str) -> None:
        self._clock.pop(name, None)
        self._lookahead.pop(name, None)

    def links(self) -> List[str]:
        return sorted(self._clock)

    def advance(self, name: str, t: float) -> None:
        """Link ``name`` promises it will send nothing stamped < t +
        lookahead.  Clocks only move forward — a regressing promise
        would un-commit events other shards already fired."""
        cur = self._clock[name]
        if t < cur - 1e-9:
            raise ValueError(
                f"link {name!r} clock moving backwards: {t} < {cur}")
        self._clock[name] = max(cur, t)

    def lbts(self, exclude: Optional[str] = None) -> float:
        """Lower bound on the timestamp of any future message from the
        considered links (all of them, or all but ``exclude``)."""
        bounds = [self._clock[n] + self._lookahead[n]
                  for n in self._clock if n != exclude]
        return min(bounds) if bounds else math.inf

    def grant(self, name: str) -> float:
        """The horizon shard ``name`` may simulate to right now.  Equal
        to its own clock means the shard is blocked — the driver should
        have the laggard links send null messages."""
        return self.lbts(exclude=name)

    def blocked(self, name: str) -> bool:
        return self.grant(name) <= self._clock.get(name, 0.0) + 1e-12


class FailureProcess:
    """Alternating up/down renewal process per resource (MTBF/MTTR),
    deterministic per (seed, resource)."""

    def __init__(self, sim: Simulator, directory: ResourceDirectory,
                 seed: int = 0,
                 on_down: Optional[Callable[[str], None]] = None,
                 on_up: Optional[Callable[[str], None]] = None,
                 tracer=None):
        self.sim = sim
        self.directory = directory
        self.seed = seed
        self.on_down = on_down or (lambda r: None)
        self.on_up = on_up or (lambda r: None)
        self.tracer = tracer            # optional telemetry.Tracer

    def install(self, name: str) -> None:
        spec = self.directory.spec(name)
        if not math.isfinite(spec.mtbf_hours) or spec.mtbf_hours <= 0:
            return
        rng = random.Random(f"{self.seed}|{name}")
        self._schedule_failure(name, spec, rng)

    def _schedule_failure(self, name: str, spec: ResourceSpec,
                          rng: random.Random) -> None:
        dt = rng.expovariate(1.0 / (spec.mtbf_hours * 3600.0))

        def fail():
            st = self.directory.status(name)
            repair = rng.expovariate(1.0 / max(spec.mttr_hours * 3600.0, 1.0))
            if st.up and not st.departed:
                st.set_up(False)
                # publish the scheduled repair time: information services
                # answer "ETA back up" from this, not from omniscience
                st.next_transition = self.sim.now + repair
                self.on_down(name)
                if self.tracer is not None:
                    self.tracer.instant(
                        self.sim.now, f"site:{spec.site}", "churn",
                        "resource_down", resource=name,
                        eta=st.next_transition)

            def fix():
                # a departed site owns its machines' fate: the renewal
                # process keeps ticking but must not resurrect them
                if not st.departed:
                    st.set_up(True)
                    st.next_transition = math.inf
                    self.on_up(name)
                    if self.tracer is not None:
                        self.tracer.instant(
                            self.sim.now, f"site:{spec.site}", "churn",
                            "resource_up", resource=name)
                self._schedule_failure(name, spec, rng)

            self.sim.after(repair, fix)

        self.sim.after(dt, fail)


class ChurnProcess:
    """Site-level membership churn: whole administrative domains join
    and leave the grid mid-run (the abstract's "resources ... may span
    many administrative domains" is a statement about *time* too — a
    global testbed's membership is never fixed).

    Alternating leave/rejoin renewal process per site, deterministic per
    (seed, site) exactly like ``FailureProcess`` per resource.  The
    mechanics of departure (deregistering from the GIS, failing over
    in-flight jobs, refunding contracts) belong to the driver:

    * ``on_leave(site, rejoin_at) -> bool`` — return False to VETO the
      departure (e.g. it would empty the grid); the process then just
      re-draws a later departure time.  ``rejoin_at`` is the already
      scheduled return time, for publishing as the resources' ETA.
    * ``on_join(site)`` — the site is back.
    """

    def __init__(self, sim: Simulator, directory: ResourceDirectory,
                 seed: int = 0, *,
                 mean_uptime_hours: float = 8.0,
                 mean_downtime_hours: float = 2.0,
                 on_leave: Optional[Callable[[str, float], bool]] = None,
                 on_join: Optional[Callable[[str], None]] = None):
        if mean_uptime_hours <= 0 or mean_downtime_hours <= 0:
            raise ValueError("churn means must be positive")
        self.sim = sim
        self.directory = directory
        self.seed = seed
        self.mean_uptime = mean_uptime_hours * 3600.0
        self.mean_downtime = mean_downtime_hours * 3600.0
        self.on_leave = on_leave or (lambda s, eta: True)
        self.on_join = on_join or (lambda s: None)
        self.events: List[Tuple[float, str, str]] = []   # (t, kind, site)

    def install(self, site: str) -> None:
        rng = random.Random(f"{self.seed}|churn|{site}")
        self._schedule_leave(site, rng)

    def _schedule_leave(self, site: str, rng: random.Random) -> None:
        dt = rng.expovariate(1.0 / self.mean_uptime)

        def leave():
            downtime = rng.expovariate(1.0 / self.mean_downtime)
            rejoin_at = self.sim.now + downtime
            if not self.on_leave(site, rejoin_at):
                # vetoed (e.g. last site standing): stay, try later
                self._schedule_leave(site, rng)
                return
            self.events.append((self.sim.now, "leave", site))

            def join():
                self.events.append((self.sim.now, "join", site))
                self.on_join(site)
                self._schedule_leave(site, rng)

            self.sim.after(downtime, join)

        self.sim.after(dt, leave)


def duration_model(spec: ResourceSpec, est_seconds_base: float,
                   stage_in_bytes: int, stage_out_bytes: int,
                   *, load: float = 0.0, noise_sigma: float = 0.15,
                   seed: Tuple = ()) -> Tuple[float, float, float]:
    """Returns (stage_in_s, exec_s, stage_out_s) — deterministic in seed.

    Closed clusters pay a 2x staging penalty (the paper's proxy mediates
    all I/O through the master node)."""
    rng = random.Random("|".join(str(s) for s in seed) if seed else 0)
    noise = math.exp(rng.gauss(0.0, noise_sigma)) if noise_sigma else 1.0
    penalty = 2.0 if spec.closed else 1.0
    s_in = penalty * stage_in_bytes / spec.stage_bw
    s_out = penalty * stage_out_bytes / spec.stage_bw
    ex = est_seconds_base / max(spec.perf_factor, 1e-6)
    ex = ex / max(1.0 - load, 0.05) * noise
    return s_in, ex, s_out
