"""Job model: one parameter point of the experiment = one grid job."""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, Optional, Tuple

from repro.core.plan import TaskStep


class JobStatus(str, enum.Enum):
    PENDING = "pending"        # created, not yet assigned
    STAGED = "staged"          # assigned to a resource, staging in
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"          # last attempt failed (will requeue or give up)
    KILLED = "killed"          # duplicate lost the straggler race


@dataclasses.dataclass(frozen=True)
class JobSpec:
    job_id: str
    experiment: str
    point: Dict[str, Any]                  # parameter values
    steps: Tuple[TaskStep, ...]            # substituted task steps
    est_seconds_base: float = 3600.0       # runtime on a perf_factor=1 slice
    stage_in_bytes: int = 10_000_000
    stage_out_bytes: int = 1_000_000
    payload: Any = None                    # LocalExecutor: callable to run


@dataclasses.dataclass
class Job:
    spec: JobSpec
    status: JobStatus = JobStatus.PENDING
    resource: Optional[str] = None
    attempt: int = 0
    submitted_at: float = 0.0
    started_at: float = 0.0
    finished_at: float = 0.0
    committed_cost: float = 0.0
    quoted_price: float = 0.0              # chip-hour price locked at dispatch
    slot_held: bool = False                # executor truth: slot acquired
    acquired_at: float = 0.0               # when the slot was granted
    actual_cost: float = 0.0
    result: Any = None
    duplicate_of: Optional[str] = None     # straggler backup provenance
    duplicates: Tuple[str, ...] = ()

    @property
    def job_id(self) -> str:
        return self.spec.job_id

    def runtime(self) -> float:
        if self.finished_at and self.started_at:
            return self.finished_at - self.started_at
        return 0.0
