"""Live experiment monitor: streaming health, watchdogs, steering.

Nimrod/G's broker does not just schedule — the paper's architecture has
it "monitoring and steering" the experiment against its deadline and
budget while the run is in flight.  PR 7 built the record side of that
story (the ``Tracer``); this module builds the *online* side on top of
the tracer's subscriber bus:

* **Live health rollups.**  ``ExperimentMonitor`` subscribes to the
  whole event stream and folds it into per-broker health (budget
  burn-rate vs. remaining work, deadline risk from the attempt funnel)
  and per-site health (membership churn, machine failures, suspicion
  counts, breach refunds) — readable at any sim time via
  ``broker_health()`` / ``site_health()`` / ``dashboard()``.

* **Online invariant watchdogs.**  The accounting identities the repo
  already checks *post-hoc* (``GridBank.reconcile``, the resale
  round-trip audit) are enforced *at event time*: money conservation
  (each broker ledger vs. the bank's record of that user, bit-for-bit),
  slot accounting (``acquires == releases + running`` plus a census of
  actually-held slots from the executors' in-flight token registries),
  and attempt-span balance (no double begin, no end without begin).  A
  violation raises ``InvariantViolation`` at the sim time it happens —
  not at run end — carrying a causal context window: the last K events
  on every involved track.

* **Steering.**  The monitor can adjust a broker's deadline/budget or
  drain a site, scheduled on the *sim clock* (``at=``), so a steered
  run is an ordinary deterministic run: every action is recorded as a
  ``steer`` trace instant and two same-seed steered runs are
  byte-identical.

The monitor only observes and steers through public market APIs: it
draws no RNG and never mutates market state from the observation path,
so attaching it leaves same-seed runs byte-identical (the golden
hashes pin this).  It subscribes with raw delivery and keeps every
per-event handler to O(1) dict work, but its watchdog arithmetic is
real work on top of the bus — bench_telemetry gates the record+deliver
path and asserts the monitor's cleanliness on the untimed correctness
pair.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.telemetry import TraceEvent

HOUR = 3600.0


def _fmt_event(ev: TraceEvent) -> str:
    args = ""
    if ev.args:
        args = " " + " ".join(f"{k}={ev.args[k]!r}"
                              for k in sorted(ev.args))
    span = f" span={ev.span}" if ev.span else ""
    return (f"seq={ev.seq} t={ev.t:.1f} {ev.track} "
            f"{ev.cat}/{ev.name} ph={ev.ph}{span}{args}")


class InvariantViolation(Exception):
    """An online watchdog caught the books out of balance — raised at
    the sim time of the offending event, with the last-K events on
    every involved track attached as the causal context window."""

    def __init__(self, t: float, invariant: str, track: str, detail: str,
                 context: List[TraceEvent]):
        self.t = t
        self.invariant = invariant
        self.track = track
        self.detail = detail
        self.context = context
        lines = [f"[t={t:.1f}s] {invariant} violated on {track}: {detail}"]
        if context:
            tracks = sorted({e.track for e in context})
            lines.append(f"  causal context ({len(context)} events on "
                         f"{len(tracks)} track(s)):")
            lines.extend(f"    {_fmt_event(e)}" for e in context)
        super().__init__("\n".join(lines))


@dataclasses.dataclass(frozen=True)
class BrokerHealth:
    """Point-in-time health snapshot for one broker, rolled up from the
    live stream plus the engine's own books."""
    user: str
    strategy: str
    t: float
    jobs: int
    done: int
    remaining: int
    finished: bool
    spent: float
    committed: float
    budget: float
    burn_frac: float                 # spent / budget
    progress_frac: float             # done / jobs
    projected_spend: float           # spent scaled to full completion
    budget_risk: str                 # ok | at_risk | over
    deadline: float
    time_left_h: float
    needed_rate_h: float             # jobs/h needed to make the deadline
    observed_rate_h: float           # jobs/h achieved so far
    deadline_risk: str               # ok | at_risk | critical | done
    requeues: int
    outcomes: Dict[str, int]         # attempt-funnel outcome counts

    def row(self) -> str:
        outs = " ".join(f"{k}:{v}" for k, v in sorted(self.outcomes.items()))
        return (f"{self.user:10s} {self.done:4d}/{self.jobs:<4d} "
                f"spent={self.spent:9.2f}/{self.budget:<9.2f} "
                f"burn={self.burn_frac:5.1%} "
                f"deadline={self.deadline_risk:8s} "
                f"budget={self.budget_risk:7s} "
                f"rate={self.observed_rate_h:6.1f}/h "
                f"need={self.needed_rate_h:6.1f}/h  [{outs}]")


@dataclasses.dataclass(frozen=True)
class SiteHealth:
    """Point-in-time reliability snapshot for one administrative
    domain, tallied from churn/gis instants on its track."""
    site: str
    resources: int
    leaves: int
    joins: int
    evictions: int                   # eviction instants (batches)
    evicted_jobs: int
    machine_downs: int
    machine_ups: int
    suspects: int                    # dispatch-burn suspicions on its boxes
    refunds_gd: float                # breach rebates the domain paid back
    reliability: float               # heuristic in (0, 1]: 1 = no incidents

    def row(self) -> str:
        return (f"{self.site:10s} res={self.resources:3d} "
                f"leave/join={self.leaves}/{self.joins} "
                f"down/up={self.machine_downs}/{self.machine_ups} "
                f"evicted={self.evicted_jobs:3d} "
                f"suspects={self.suspects:3d} "
                f"refunds={self.refunds_gd:8.2f}G$ "
                f"reliability={self.reliability:.3f}")


@dataclasses.dataclass(frozen=True)
class SteeringAction:
    """Audit record of one applied steering action (also emitted as a
    ``steer`` trace instant, so steered runs replay byte-identically)."""
    t: float
    kind: str                        # steer_broker | drain_site
    target: str
    detail: Dict[str, Any]


class ExperimentMonitor:
    """Online monitor over one :class:`~repro.core.marketplace.Marketplace`
    run.  Requires the market to have been built with a tracer.

    ``on_violation="raise"`` (default) makes a watchdog raise
    :class:`InvariantViolation` straight out of the recording site — the
    run dies at the sim time of the violation.  ``"record"`` appends to
    :attr:`violations` instead (for scanning runs expected to be dirty).
    """

    def __init__(self, market, *, watchdogs: bool = True,
                 context_window: int = 32,
                 on_violation: str = "raise"):
        if market.tracer is None:
            raise ValueError(
                "ExperimentMonitor needs a traced market: build it with "
                "standard_market(..., tracer=Tracer())")
        if on_violation not in ("raise", "record"):
            raise ValueError(f"on_violation must be 'raise' or 'record', "
                             f"got {on_violation!r}")
        self.market = market
        self.tracer = market.tracer
        self.watchdogs = watchdogs
        self.on_violation = on_violation
        self.violations: List[InvariantViolation] = []
        self.steering_log: List[SteeringAction] = []
        self.events_seen = 0
        self._k = context_window
        self._last_t = 0.0
        # stream-derived state ("broker:<user>"-track keys on the hot
        # path; sliced down to user names only in the health snapshots)
        self._open_attempts: set = set()
        self._open_jobs: set = set()
        self._funnel: Dict[str, Dict[str, int]] = {}
        self._requeues: Dict[str, int] = {}
        self._first_dispatch: Dict[str, float] = {}
        self._finished: set = set()
        self._site: Dict[str, Dict[str, int]] = {}
        self._engines: Dict[str, Any] = {}
        self._executors: List[Any] = []
        self._audit_tick = 0
        # one small handler per category — no per-event dispatch
        # cascade, and categories the monitor has no use for (sched,
        # auction, resale span traffic) never reach it at all.  Raw
        # delivery: the handlers index the tuple directly and skip the
        # NamedTuple constructor, the dominant bus cost per event
        self._subs = [
            self.tracer.subscribe(cat, fn, raw=True) for cat, fn in (
                ("job", self._on_job), ("metric", self._on_metric),
                ("bank", self._on_bank), ("churn", self._on_churn),
                ("gis", self._on_gis), ("market", self._on_market))]

    def close(self) -> None:
        """Detach from the stream (idempotent)."""
        for sub in self._subs:
            sub.cancel()

    # -- stream consumption --------------------------------------------
    # These run once per trace event on the traced hot path, under the
    # bench_telemetry 5% overhead gate: tuple indexing instead of
    # NamedTuple attribute access, no per-event allocation, and every
    # per-resource/per-user check is O(1) dict work.  Causal context is
    # NOT accumulated here — it is reconstructed from the tracer's ring
    # buffers only when a violation actually fires.
    def _on_job(self, ev: tuple) -> None:
        self.events_seen += 1
        self._last_t = ev[1]
        name = ev[4]
        if name == "attempt":
            sid = ev[6]
            if ev[5] == "b":
                open_a = self._open_attempts
                if sid in open_a:
                    if self.watchdogs:
                        self._violate(ev, "attempt_span_balance",
                                      f"attempt span {sid!r} began twice")
                else:
                    open_a.add(sid)
                fd = self._first_dispatch
                if ev[2] not in fd:
                    fd[ev[2]] = ev[1]
            else:
                open_a = self._open_attempts
                if sid in open_a:
                    open_a.remove(sid)
                elif self.watchdogs:
                    self._violate(ev, "attempt_span_balance",
                                  f"attempt span {sid!r} ended without "
                                  f"a begin")
                args = ev[7]
                out = args["outcome"]
                funnel = self._funnel.get(ev[2])
                if funnel is None:
                    funnel = self._funnel[ev[2]] = {}
                funnel[out] = funnel.get(out, 0) + 1
                if self.watchdogs:
                    if "cost" in args:
                        self._check_money(ev, ev[2][7:])
                    res = args.get("resource")
                    if res:
                        self._check_slots(ev, res)
        elif name == "job":
            sid = ev[6]
            if ev[5] == "b":
                open_j = self._open_jobs
                if sid in open_j:
                    if self.watchdogs:
                        self._violate(ev, "attempt_span_balance",
                                      f"job span {sid!r} began twice")
                else:
                    open_j.add(sid)
            elif ev[5] == "e":
                open_j = self._open_jobs
                if sid in open_j:
                    open_j.remove(sid)
                elif self.watchdogs:
                    self._violate(ev, "attempt_span_balance",
                                  f"job span {sid!r} ended without a begin")
                if self.watchdogs and ev[7] and "cost" in ev[7]:
                    self._check_money(ev, ev[2][7:])
        elif name == "requeue":
            self._requeues[ev[2]] = self._requeues.get(ev[2], 0) + 1

    def _on_metric(self, ev: tuple) -> None:
        self.events_seen += 1
        # registry snapshots are the bulk of the stream and carry no
        # causal information; the per-watch-tick price sample doubles as
        # the deep-audit heartbeat (every 4th tick, like the registry
        # snapshot cadence — the per-event checks are the exact-time
        # detectors, the audit is the safety net behind them)
        if ev[4] == "price.mean_quote" and self.watchdogs:
            self._last_t = ev[1]
            self._audit_tick += 1
            if self._audit_tick % 4 == 1:
                self._audit(ev)

    def _on_bank(self, ev: tuple) -> None:
        self.events_seen += 1
        self._last_t = ev[1]
        # exceptional money movement (kill/refund/idle/resale/fee):
        # ledger and bank were both updated before the instant, so the
        # per-user identity must hold right here
        if self.watchdogs:
            self._check_money(ev, ev[7]["user"])

    def _on_gis(self, ev: tuple) -> None:
        self.events_seen += 1
        self._last_t = ev[1]
        if ev[4] == "suspect":
            args = ev[7]
            res = args.get("resource") if args else None
            if res is not None and res in self.market.directory:
                site = self.market.directory.spec(res).site
                self._site_tally(site)["suspects"] += 1

    def _on_market(self, ev: tuple) -> None:
        self.events_seen += 1
        self._last_t = ev[1]
        if ev[4] == "broker_finish":
            user = ev[7]["user"]
            self._finished.add(user)
            if self.watchdogs:
                self._check_money(ev, user)

    def _on_churn(self, ev: tuple) -> None:
        self.events_seen += 1
        self._last_t = ev[1]
        name = ev[4]
        args = ev[7] or {}
        site = args.get("site")
        if site is None:
            res = args.get("resource")
            if res is not None and res in self.market.directory:
                site = self.market.directory.spec(res).site
            elif ev[2].startswith("site:"):
                site = ev[2][5:]
            else:
                return
        tally = self._site_tally(site)
        if name == "site_leave":
            tally["leaves"] += 1
        elif name == "site_join":
            tally["joins"] += 1
        elif name == "eviction":
            tally["evictions"] += 1
            tally["evicted_jobs"] += int(args.get("jobs", 0))
        elif name == "resource_down":
            tally["downs"] += 1
        elif name == "resource_up":
            tally["ups"] += 1

    def _site_tally(self, site: str) -> Dict[str, int]:
        tally = self._site.get(site)
        if tally is None:
            tally = self._site[site] = {
                "leaves": 0, "joins": 0, "evictions": 0,
                "evicted_jobs": 0, "downs": 0, "ups": 0, "suspects": 0}
        return tally

    # -- watchdogs ------------------------------------------------------
    def _engine(self, user: str):
        eng = self._engines.get(user)
        if eng is None:
            for u, e in zip(self.market.users, self.market.engines):
                self._engines[u.name] = e
            eng = self._engines.get(user)
        return eng

    def _check_money(self, ev: tuple, user: str) -> None:
        """Per-user money conservation, incrementally: every settlement
        path updates the broker ledger and then the bank with the same
        ``+=``, *before* emitting the event that lands here — so the two
        books must agree bit-for-bit at every such event."""
        eng = self._engine(user)
        if eng is None:
            return
        settled = eng.ledger.settled
        recorded = self.market.bank.user_spend(user)
        if settled != recorded:
            self._violate(
                ev, "money_conservation",
                f"user {user!r}: broker ledger settled {settled!r} != "
                f"bank record {recorded!r} "
                f"(delta {settled - recorded!r}); per-kind totals: "
                f"{self.market.bank.kind_breakdown(user)}",
                extra_tracks=(f"broker:{user}",))

    def _held_index(self) -> List[Dict[str, int]]:
        """The executors' independent held-slot books (refreshed if
        brokers were added since the last look)."""
        if len(self._executors) != len(self.market.engines):
            self._executors = [
                held for eng in self.market.engines
                for held in (getattr(eng.dispatcher.executor,
                                     "_held", None),)
                if held is not None]
        return self._executors

    def _check_slots(self, ev: tuple, resource: str) -> None:
        """Slot accounting for one resource: the counter identity
        ``acquires == releases + running`` catches a release that
        clamped at zero, and the census — ``running`` vs. the executors'
        own count of slots held there (``_held``, maintained at the
        acquire/release sites) — catches a double release that freed a
        slot out from under a running job.  Both checks are O(1)."""
        directory = self.market.directory
        if resource not in directory:
            return
        st = directory.status(resource)
        run = st.running
        if st.acquires != st.releases + run:
            self._violate(
                ev, "slot_accounting",
                f"resource {resource!r}: acquires={st.acquires} != "
                f"releases={st.releases} + running={run}",
                extra_tracks=(f"site:{directory.spec(resource).site}",))
            return
        held = 0
        for book in self._held_index():
            h = book.get(resource)
            if h:
                held += h
        if held != run:
            self._violate(
                ev, "slot_accounting",
                f"resource {resource!r}: status says running={run} but "
                f"the executors hold {held} slot(s) there (double "
                f"release or phantom occupancy)",
                extra_tracks=(f"site:{directory.spec(resource).site}",))

    def _audit(self, ev: tuple) -> None:
        """Deep audit on the watch-tick heartbeat: the two-sided grand
        total, every broker ledger, and a full slot census across every
        registered resource in one pass over the in-flight tokens."""
        bank = self.market.bank
        spend = bank.total_spend()
        revenue = bank.total_revenue()
        if abs(spend - revenue) > 1e-9 * max(1.0, abs(spend)):
            self._violate(
                ev, "money_conservation",
                f"grand totals diverged: user spend {spend!r} != owner "
                f"revenue {revenue!r}; per-kind totals: "
                f"{bank.kind_breakdown()}")
        for u, eng in zip(self.market.users, self.market.engines):
            if eng.ledger.settled != bank.user_spend(u.name):
                self._check_money(ev, u.name)      # build the full message
        directory = self.market.directory
        held: Dict[str, int] = {}
        for book in self._held_index():
            for res, h in book.items():
                if h:
                    held[res] = held.get(res, 0) + h
        for name in directory.all_names():
            st = directory.status(name)
            if st.acquires != st.releases + st.running \
                    or held.get(name, 0) != st.running:
                self._check_slots(ev, name)        # build the full message

    def _context(self, tracks: set) -> List[TraceEvent]:
        """Last-K events per involved track, reconstructed from the
        tracer's ring buffers (violation path only — the hot path never
        accumulates context).  The offending event is already in its
        ring when the watchdog fires, so it closes its own window."""
        matching = [raw
                    for ring in self.tracer._rings.values()
                    for raw in ring if raw[2] in tracks]
        matching.sort()                            # tuples lead with seq
        picked: List[tuple] = []
        counts: Dict[str, int] = {}
        for raw in reversed(matching):
            n = counts.get(raw[2], 0)
            if n < self._k:
                counts[raw[2]] = n + 1
                picked.append(raw)
        picked.reverse()
        return [TraceEvent._make(raw) for raw in picked]

    def _violate(self, ev: tuple, invariant: str, detail: str,
                 extra_tracks: Tuple[str, ...] = ()) -> None:
        tracks = {ev[2]}
        tracks.update(extra_tracks)
        v = InvariantViolation(t=ev[1], invariant=invariant, track=ev[2],
                               detail=detail,
                               context=self._context(tracks))
        self.violations.append(v)
        if self.on_violation == "raise":
            raise v

    def assert_clean(self) -> None:
        """Raise the first recorded violation, if any (useful after a
        run in ``on_violation="record"`` mode; a no-op in raise mode)."""
        if self.violations:
            raise self.violations[0]

    # -- health rollups -------------------------------------------------
    def broker_health(self, user: Optional[str] = None):
        """Health snapshot(s): one :class:`BrokerHealth` for ``user``,
        or a name-sorted list for every broker."""
        if user is not None:
            return self._broker_health(user)
        return [self._broker_health(u.name)
                for u in sorted(self.market.users, key=lambda u: u.name)]

    def _broker_health(self, user: str) -> BrokerHealth:
        eng = self._engine(user)
        if eng is None:
            raise KeyError(f"no broker for user {user!r}")
        t = self._last_t
        jobs = eng.report.n_jobs
        done = eng.report.n_done
        remaining = jobs - done
        spent = eng.ledger.settled
        committed = eng.ledger.committed
        budget = eng.ledger.budget
        burn = spent / budget if budget else math.inf
        progress = done / jobs if jobs else 1.0
        projected = spent * jobs / done if done else 0.0
        if spent > budget or spent + committed > budget:
            budget_risk = "over"
        elif done == 0:
            budget_risk = "ok"
        elif projected <= budget:
            budget_risk = "ok"
        elif projected <= 1.25 * budget:
            budget_risk = "at_risk"
        else:
            budget_risk = "over"
        deadline = eng.req.deadline
        time_left = deadline - t
        t0 = self._first_dispatch.get(f"broker:{user}", t)
        elapsed = max(t - t0, 1e-9)
        observed = done / elapsed * HOUR
        needed = (remaining / max(time_left, 1e-9) * HOUR
                  if remaining else 0.0)
        if remaining == 0:
            deadline_risk = "done"
        elif time_left <= 0:
            deadline_risk = "critical"
        elif done == 0:
            deadline_risk = "at_risk"   # no completions — cannot extrapolate
        elif needed <= observed:
            deadline_risk = "ok"
        elif needed <= 2.0 * observed:
            deadline_risk = "at_risk"
        else:
            deadline_risk = "critical"
        return BrokerHealth(
            user=user, strategy=eng.req.strategy, t=t, jobs=jobs,
            done=done, remaining=remaining,
            finished=user in self._finished or eng.finished,
            spent=spent, committed=committed, budget=budget,
            burn_frac=burn, progress_frac=progress,
            projected_spend=projected, budget_risk=budget_risk,
            deadline=deadline, time_left_h=time_left / HOUR,
            needed_rate_h=needed, observed_rate_h=observed,
            deadline_risk=deadline_risk,
            requeues=self._requeues.get(f"broker:{user}", 0),
            outcomes=dict(sorted(
                self._funnel.get(f"broker:{user}", {}).items())))

    def site_health(self) -> List[SiteHealth]:
        """Name-sorted reliability snapshot for every domain that has
        appeared in the stream or the directory."""
        directory = self.market.directory
        sites = set(directory.sites())
        sites.update(self._site)
        out = []
        for site in sorted(sites):
            tally = self._site_tally(site)
            incidents = (tally["leaves"] + tally["downs"]
                         + 0.25 * tally["suspects"])
            out.append(SiteHealth(
                site=site,
                resources=len(directory.site_resources(site)),
                leaves=tally["leaves"], joins=tally["joins"],
                evictions=tally["evictions"],
                evicted_jobs=tally["evicted_jobs"],
                machine_downs=tally["downs"], machine_ups=tally["ups"],
                suspects=tally["suspects"],
                refunds_gd=0.0 - self.market.bank.owner_kind_total(
                    site, "refund") + 0.0,
                reliability=1.0 / (1.0 + incidents)))
        return out

    def dashboard(self) -> str:
        """Human-readable rollup of the whole experiment right now."""
        lines = [f"=== experiment monitor @ t={self._last_t:.1f}s  "
                 f"({self.events_seen} events, "
                 f"{len(self.violations)} violation(s), "
                 f"{len(self.steering_log)} steering action(s)) ===",
                 "-- brokers --"]
        lines.extend(h.row() for h in self.broker_health())
        lines.append("-- sites --")
        lines.extend(s.row() for s in self.site_health())
        return "\n".join(lines)

    # -- steering -------------------------------------------------------
    # Steering runs on the sim clock: pass ``at=`` before market.run()
    # and the action fires deterministically at that virtual time (the
    # engine/marketplace emit ``steer`` instants, so the steered stream
    # is part of the same byte-reproducible trace).  With ``at=None``
    # the action applies immediately — only meaningful mid-run (e.g.
    # from another timer).
    def _schedule(self, at: Optional[float],
                  fn: Callable[[], None]) -> None:
        if at is None:
            fn()
        else:
            self.market.sim.at(at, fn)

    def steer_broker(self, user: str, *, deadline: Optional[float] = None,
                     budget: Optional[float] = None,
                     at: Optional[float] = None) -> None:
        """Adjust a broker's deadline and/or budget at sim time ``at``
        (the paper's §6 mid-experiment control: the user "may enter new
        deadline and budget" and the broker re-plans against them)."""
        if deadline is None and budget is None:
            return

        def apply() -> None:
            eng = self._engine(user)
            if eng is None or eng.finished:
                return
            t = self.market.sim.now
            eng.steer(deadline=deadline, budget=budget)
            self.steering_log.append(SteeringAction(
                t=t, kind="steer_broker", target=user,
                detail={"deadline": deadline, "budget": budget}))

        self._schedule(at, apply)

    def adjust_deadline(self, user: str, deadline: float, *,
                        at: Optional[float] = None) -> None:
        self.steer_broker(user, deadline=deadline, at=at)

    def adjust_budget(self, user: str, budget: float, *,
                      at: Optional[float] = None) -> None:
        self.steer_broker(user, budget=budget, at=at)

    def drain_site(self, site: str, *, at: Optional[float] = None) -> None:
        """Force ``site`` out of the grid at sim time ``at`` and keep it
        out: in-flight work fails over, contracts are voided with breach
        rebates, and nothing schedules a rejoin."""

        def apply() -> None:
            t = self.market.sim.now
            applied = self.market.drain_site(site)
            self.tracer.instant(t, f"site:{site}", "steer", "drain_site",
                                site=site, applied=applied)
            self.steering_log.append(SteeringAction(
                t=t, kind="drain_site", target=site,
                detail={"applied": applied}))

        self._schedule(at, apply)
