"""The parametric engine (paper §2): persistent job-control agent.

Owns the experiment: expands the declarative plan into the job farm,
tracks every job's lifecycle, journals every transition for exact restart,
asks the schedule advisor where to run things, hands dispatches to the
dispatcher, enforces the deadline/budget economy, requeues failures and
races duplicates against stragglers.

Runs against either the virtual-time grid (``run_simulated``) or a real
thread-pool grid executing genuine payloads (``run_local``).
"""
from __future__ import annotations

import bisect
import collections
import dataclasses
import math
import time as _time
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core import plan as plan_mod
from repro.core.dispatcher import (DispatchCallbacks, Dispatcher,
                                   is_resource_fault)
from repro.core.economy import BudgetLedger, TradeServer, UserRequirements
from repro.core.gis import GISClient, GridInformationService
from repro.core.jobs import Job, JobSpec, JobStatus
from repro.core.persistence import Journal, load_events
from repro.core.quotes import QuoteBoard
from repro.core.resources import ResourceDirectory
from repro.core.scheduler import (ResourceView, ScheduleAdvisor,
                                  SchedulerConfig, cost_per_job)
from repro.core.simulator import FailureProcess, Simulator

HOUR = 3600.0


@dataclasses.dataclass
class ExperimentReport:
    experiment: str
    strategy: str
    deadline: float
    budget: float
    n_jobs: int
    n_done: int = 0
    n_failed_final: int = 0
    completion_time: float = math.inf
    total_cost: float = 0.0
    met_deadline: bool = False
    within_budget: bool = False
    resources_used: Set[str] = dataclasses.field(default_factory=set)
    peak_allocation: int = 0
    duplicates_launched: int = 0
    requeues: int = 0
    slot_races_lost: int = 0         # dispatches that lost a slot race
    resource_losses: int = 0         # dispatches burned on dead/departed
    contracts_won: int = 0           # negotiated (auction/tender) contracts
    timeline: List[Tuple[float, int, int, float]] = dataclasses.field(
        default_factory=list)        # (t, allocated, done, spent)
    stall_reason: Optional[str] = None

    def summary(self) -> str:
        return (f"[{self.experiment}] {self.strategy}: "
                f"{self.n_done}/{self.n_jobs} jobs, "
                f"t={self.completion_time / HOUR:.2f}h "
                f"(deadline {self.deadline / HOUR:.1f}h, "
                f"met={self.met_deadline}), "
                f"cost={self.total_cost:.1f}G$ "
                f"(budget {self.budget:.0f}, within={self.within_budget}), "
                f"peak_resources={self.peak_allocation}, "
                f"dups={self.duplicates_launched} requeues={self.requeues}")


class NimrodG:
    """Engine + scheduler + dispatcher wiring for one experiment."""

    def __init__(self, experiment: str, jobs: Sequence[JobSpec],
                 requirements: UserRequirements,
                 directory: ResourceDirectory, trade: TradeServer,
                 dispatcher: Dispatcher,
                 sim: Optional[Simulator] = None,
                 journal: Optional[Journal] = None,
                 sched_cfg: SchedulerConfig = SchedulerConfig(),
                 seed: int = 0, stop_sim_when_done: bool = True,
                 auction=None, bank=None, secondary=None,
                 gis: Optional[GridInformationService] = None,
                 gis_ttl: float = 600.0, history=None, tracer=None,
                 domain: str = ""):
        self.experiment = experiment
        self.req = requirements
        self.directory = directory
        self.trade = trade
        self.dispatcher = dispatcher
        self.sim = sim
        self.journal = journal
        self.cfg = sched_cfg
        self.seed = seed
        # negotiated-economy hooks: an AuctionBroker bidding for this
        # engine (strategy="auction"), the grid-wide revenue bank, and
        # the resale book (rival brokers' listed reservations are one
        # more price source the dispatch path drains before paying spot)
        self.auction = auction
        self.bank = bank
        self.secondary = secondary
        self.history = history
        # discovery layer: with a GIS the broker plans against a cached,
        # TTL-stale snapshot (and pays for its staleness in burned
        # dispatches); without one it reads the directory — the legacy
        # omniscient single-user path
        self.gis_client = (GISClient(gis, requirements.user, ttl=gis_ttl)
                          if gis is not None else None)
        # a marketplace run shares one clock among many engines: only the
        # driver may stop it, not the first engine to finish
        self.stop_sim_when_done = stop_sim_when_done

        self.advisor = ScheduleAdvisor(sched_cfg, requirements)
        # strategies see the same economy hooks the engine trades
        # through (all None on the bare single-user path)
        self.advisor.bind_market(secondary=secondary, bank=bank,
                                 history=history,
                                 gis_client=self.gis_client)
        self.ledger = BudgetLedger(budget=requirements.budget)
        self.jobs: Dict[str, Job] = {
            s.job_id: Job(spec=s) for s in jobs}
        self.attempts: Dict[str, List[Job]] = collections.defaultdict(list)
        self.views: Dict[str, ResourceView] = {}
        self.allocated: Set[str] = set()
        self.report = ExperimentReport(
            experiment=experiment, strategy=requirements.strategy,
            deadline=requirements.deadline, budget=requirements.budget,
            n_jobs=len(self.jobs))
        self._events: collections.deque = collections.deque()
        self._finished = False
        self._dup_counter = 0

        # ---- incremental job-state indices (the O(active-work) tick) --
        # Every index is a pure function of primary-job (status, attempt)
        # and is re-derived through _reindex() after each transition; the
        # scans they replace (_pending_jobs/_remaining/stall detection /
        # straggler walk) were O(experiment size) per tick.
        self._job_seq: Dict[str, int] = {
            jid: i for i, jid in enumerate(self.jobs)}
        self._pending_ids: Set[str] = set()
        self._pending_sorted: List[Tuple[int, str]] = []  # (seq, jid)
        self._pending_dead = 0       # tombstoned entries in the list
        self._pending_head = 0       # first possibly-live index (lazy)
        # bumped whenever anything the advisor's per-view maps consume
        # changes (view membership, suspicion, capacity, estimates) or
        # the allocation moves — lets decide() reuse its live/rate/cost
        # maps and the straggler scan skip ahead across quiet ticks
        self._views_epoch = 0
        self._strag_epoch = -1
        self._strag_until = -math.inf
        # change-stamp of the last full view refresh (directory churn +
        # GIS belief state): unchanged ⇒ the refresh pass is a no-op and
        # is skipped wholesale, including the _my_running() walk
        self._rv_key: Optional[tuple] = None
        # stamp of the last _fill_slots pass that found zero believed-
        # free slots: at saturation every tick re-derives the same
        # empty dispatch list until something actually moves
        self._nf_key: Optional[tuple] = None
        self._done_ids: Set[str] = set()
        self._active_ids: Set[str] = set()    # primaries STAGED|RUNNING
        self._running_ids: Set[str] = set()   # primaries RUNNING
        # attempt objects dispatched and possibly still holding (or about
        # to hold) a slot — replaces the full attempts-log walks in
        # _my_running()/_dispatch_price(); pruned lazily once an attempt
        # can no longer hold a slot
        self._inflight: Dict[int, Job] = {}
        self._dispatch_order: Dict[str, int] = {}  # primary -> 1st-dispatch seq
        # per-(resource) quote memo: value is reused while (t, queue
        # version, reservation-book version) are all unchanged
        self._price_cache: Dict[str, Tuple[Tuple, float]] = {}
        self._spot_cache: Dict[str, Tuple[Tuple, float]] = {}
        self._locked_cache: Dict[str, Tuple[Tuple, List[float]]] = {}
        # shared batched quote matrix: every broker on this trade object
        # reads the same per-tick float64 rows (None => scalar path)
        self._board = QuoteBoard.attach(trade)
        self._probe = (Job(spec=next(iter(self.jobs.values())).spec)
                       if self.jobs else None)
        self._tick_handle = None
        self._tick_count = 0
        self._seen_gis_generation = -1
        # telemetry (repro.core.telemetry): purely observational — every
        # hot-path site below guards on ``self._trace is not None`` (the
        # default), so the traced-off run pays one None check and the
        # traced-on run draws no RNG and reorders nothing
        self._trace = tracer
        # on the sharded grid each broker runs inside an administrative
        # domain: naming it prefixes this engine's trace track, so a
        # merged multi-domain trace keeps per-domain lanes apart (the
        # default "" leaves single-domain output byte-identical)
        self.domain = domain
        self._track = (f"{domain}/broker:{experiment}" if domain
                       else f"broker:{experiment}")
        self._open_spans: Set[str] = set()   # job spans begun, not ended
        self._open_attempts: Set[str] = set()  # attempt span ids in flight
        # quote-memo hit/miss tallies are plain ints counted always (an
        # int += is free next to the quote itself) and flushed to the
        # shared registry counters once per tick — per-quote Counter
        # calls were the single largest traced-on overhead
        self._memo_hits = 0
        self._memo_misses = 0
        self._memo_flushed = (0, 0)
        if tracer is not None:
            m = tracer.metrics
            self._m_memo_hit = m.counter("broker.quote_memo_hits")
            self._m_memo_miss = m.counter("broker.quote_memo_misses")
            self._m_attempts = m.histogram("broker.attempts_per_job",
                                           unit="attempts")
            self._m_att_latency = m.histogram(
                "broker.attempt_latency_s", unit="s",
                bounds=(60.0, 300.0, 600.0, 900.0, 1200.0, 1800.0,
                        2700.0, 3600.0, 7200.0, 14400.0, 28800.0))
            self._m_slack = m.histogram(
                "market.deadline_slack_h", unit="h",
                bounds=(-24.0, -12.0, -6.0, -2.0, -1.0, 0.0, 1.0, 2.0,
                        6.0, 12.0, 24.0, 72.0))
            self.advisor.bind_telemetry(tracer, self._track)
        for job in self.jobs.values():
            self._reindex(job)

        self._log("EXP_CREATED", n_jobs=len(self.jobs),
                  deadline=requirements.deadline, budget=requirements.budget,
                  strategy=requirements.strategy, user=requirements.user)
        for s in jobs:
            self._log("JOB_CREATED", job_id=s.job_id, point=s.point,
                      est=s.est_seconds_base)

    # ------------------------------------------------------------------
    @classmethod
    def from_plan(cls, experiment: str, p: plan_mod.Plan,
                  requirements: UserRequirements,
                  directory: ResourceDirectory, trade: TradeServer,
                  dispatcher: Dispatcher,
                  est_seconds: Callable[[Dict[str, Any]], float],
                  stage_in_bytes: int = 10_000_000,
                  stage_out_bytes: int = 1_000_000,
                  **kw) -> "NimrodG":
        specs = []
        for i, point in enumerate(p.points()):
            jid = f"j{i:05d}"
            steps = tuple(plan_mod.substitute(s, point, jid) for s in p.task)
            specs.append(JobSpec(
                job_id=jid, experiment=experiment, point=point, steps=steps,
                est_seconds_base=est_seconds(point),
                stage_in_bytes=stage_in_bytes,
                stage_out_bytes=stage_out_bytes))
        return cls(experiment, specs, requirements, directory, trade,
                   dispatcher, **kw)

    # ------------------------------------------------------------------
    # journaling / restart
    # ------------------------------------------------------------------
    def _log(self, kind: str, **fields) -> None:
        if self.journal is not None:
            t = self.sim.now if self.sim is not None else _time.time()
            self.journal.append(kind, t=t, **fields)

    @staticmethod
    def replay_journal(path: str) -> Dict[str, Any]:
        """Reconstruct experiment state from a journal (restart support).

        Returns {done: {job_id: cost}, spent: float, meta: {...}}.
        Jobs seen RUNNING/STAGED but never DONE are simply *not* in
        ``done`` — the restarted engine requeues them (exactly-once
        completion, at-least-once execution)."""
        done: Dict[str, float] = {}
        spent = 0.0
        meta: Dict[str, Any] = {}
        for ev in load_events(path):
            k = ev["kind"]
            if k == "EXP_CREATED":
                meta = {f: ev[f] for f in
                        ("n_jobs", "deadline", "budget", "strategy", "user")}
            elif k == "DONE":
                jid = ev["job_id"].split("~")[0]
                if jid not in done:
                    done[jid] = ev["cost"]
                    spent += ev["cost"]
            elif k == "KILL_SETTLED":
                spent += ev["cost"]
        return {"done": done, "spent": spent, "meta": meta}

    def restore_from(self, path: str) -> int:
        """Apply a prior journal: mark finished jobs done, restore spend.
        Returns number of jobs recovered as DONE."""
        st = self.replay_journal(path)
        for jid, cost in st["done"].items():
            if jid in self.jobs:
                j = self.jobs[jid]
                j.status = JobStatus.DONE
                j.actual_cost = cost
                self._reindex(j)
                self.report.n_done += 1
        self.ledger.settled += st["spent"]
        self.report.total_cost = st["spent"]
        self._log("RESTORED", n_done=len(st["done"]), spent=st["spent"])
        return len(st["done"])

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    def _now(self) -> float:
        return self.sim.now if self.sim is not None else _time.time()

    def _reindex(self, job: Job) -> None:
        """Re-derive a primary job's index-bucket membership from its
        current (status, attempt).  MUST be called after every mutation
        of either field — the invariant every O(1) read below relies on.
        Idempotent, so callers never reason about the previous state.
        Duplicates are never indexed (they live only in ``attempts``)."""
        jid = job.job_id
        seq = self._job_seq.get(jid)
        if seq is None:
            return
        pending = (job.status in (JobStatus.PENDING, JobStatus.FAILED)
                   and job.attempt < self.cfg.max_attempts)
        if pending and jid not in self._pending_ids:
            self._pending_ids.add(jid)
            key = (seq, jid)
            lst = self._pending_sorted
            i = bisect.bisect_left(lst, key)
            if i < len(lst) and lst[i] == key:
                # the entry is still there as a tombstone — revive it
                self._pending_dead -= 1
            else:
                lst.insert(i, key)
            if i < self._pending_head:
                self._pending_head = i
        elif not pending and jid in self._pending_ids:
            # tombstone, don't splice: a del from a 100k-entry list is an
            # O(n) memmove per dispatch.  Readers skip ids outside
            # _pending_ids; compaction below keeps the list bounded by
            # 2x the live entries (plus a floor so tiny lists don't churn)
            self._pending_ids.discard(jid)
            self._pending_dead += 1
            lst = self._pending_sorted
            if self._pending_dead > 16 and self._pending_dead * 2 > len(lst):
                pids = self._pending_ids
                self._pending_sorted = [e for e in lst if e[1] in pids]
                self._pending_dead = 0
                self._pending_head = 0
        if job.status is JobStatus.DONE:
            self._done_ids.add(jid)
        if job.status in (JobStatus.STAGED, JobStatus.RUNNING):
            self._active_ids.add(jid)
        else:
            self._active_ids.discard(jid)
        if job.status is JobStatus.RUNNING:
            self._running_ids.add(jid)
        else:
            self._running_ids.discard(jid)

    def _pending_live(self) -> List[Tuple[int, str]]:
        """The live (non-tombstoned) pending index entries, in seq
        order — what ``_pending_sorted`` held before tombstoning."""
        pids = self._pending_ids
        return [e for e in self._pending_sorted if e[1] in pids]

    def _pending_jobs(self) -> List[Job]:
        pids = self._pending_ids
        return [self.jobs[jid] for _, jid in self._pending_sorted
                if jid in pids]

    def _remaining(self) -> int:
        return len(self.jobs) - len(self._done_ids)

    def _quote_memo(self, cache: Dict[str, Tuple[Tuple, Any]],
                    resource: str, compute: Callable[[float], Any],
                    with_secondary: bool = False) -> Any:
        """Per-resource quote memo.  A quote is a pure function of
        (t, queue utilization, reservation book), so the cached value is
        reused until any of the three stamps moves; ``compute(t)`` may
        itself prune the book (bumping its stamp), so the entry is keyed
        on the post-call state."""
        cached = cache.get(resource)
        # the resale-book stamp participates only where the value reads
        # the resale book (_price): spot quotes and locked lists don't,
        # and must not recompute every time a listing moves
        sv = (self.secondary.version
              if with_secondary and self.secondary is not None else 0)
        key = (self._now(), self.directory.status(resource).version,
               self.trade.price_version(resource), sv)
        if cached is not None and cached[0] == key:
            self._memo_hits += 1
            return cached[1]
        self._memo_misses += 1
        value = compute(key[0])
        key = (key[0], self.directory.status(resource).version,
               self.trade.price_version(resource), sv)
        cache[resource] = (key, value)
        return value

    def _effective_with_resale(self, resource: str, t: float) -> float:
        """Effective price with rivals' resale listings merged in as one
        more price source — the advisor ranks the cheaper of the two.
        Runs inside the quote memo: its key already carries
        ``SecondaryMarket.version``, so the listing scan reruns exactly
        when the resale book moved."""
        base = self.trade.effective_price(resource, self.req.user, t)
        if self.secondary is not None:
            rate = self.secondary.best_rate(resource, t,
                                            exclude=self.req.user)
            if rate is not None and rate < base:
                return rate
        return base

    def _price(self, resource: str) -> float:
        # batched fast path: no resale book in play and no per-user
        # overlay on the row => the shared board row IS the effective
        # price (the board itself delegates reservation-bearing rows)
        board = self._board
        if board is not None and self.secondary is None:
            t = self.sim.now if self.sim is not None else _time.time()
            v = board.effective(resource, self.req.user, t)
            if v is not None:
                return v
        return self._quote_memo(
            self._price_cache, resource,
            lambda t: self._effective_with_resale(resource, t),
            with_secondary=True)

    def _spot(self, resource: str) -> float:
        board = self._board
        if board is not None:
            t = self.sim.now if self.sim is not None else _time.time()
            v = board.quote(resource, self.req.user, t)
            if v is not None:
                return v
        return self._quote_memo(
            self._spot_cache, resource,
            lambda t: self.trade.quote(resource, t, self.req.user))

    _NO_LOCKED: Tuple[float, ...] = ()

    def _locked_prices(self, resource: str) -> Sequence[float]:
        # an empty reservation book can't lock any price — skip the
        # memo-keyed book walk entirely (the walk's prune is a no-op on
        # an empty book, so deferring it changes nothing)
        board = self._board
        if board is not None:
            server = board.server_of(resource)
            if server is not None and not server.reservations:
                return self._NO_LOCKED
        return self._quote_memo(
            self._locked_cache, resource,
            lambda t: self.trade.reserved_price_list(resource,
                                                     self.req.user, t))

    def _dispatch_price(self, resource: str) -> float:
        """Price the *next* dispatch to ``resource`` pays.  Each of the
        user's reserved slots prices exactly one concurrent job at its
        own locked price (overlapping contracts can be struck at
        different prices); dispatches beyond the reserved draw-down pay
        the live spot quote — one cheap contract must not discount the
        whole queue."""
        locked = self._locked_prices(resource)
        if not locked:
            return self._spot(resource)
        # each in-flight contract-priced job consumes one reservation
        inflight = collections.Counter()
        for j in self._inflight.values():
            if (j.resource == resource
                    and j.status in (JobStatus.STAGED, JobStatus.RUNNING)):
                inflight[j.quoted_price] += 1
        for price in locked:
            if inflight[price] > 0:
                inflight[price] -= 1
                continue
            return price
        return self._spot(resource)

    def _my_running(self) -> Dict[str, int]:
        """Slots this experiment currently occupies, per resource.

        Counts ``slot_held`` (set by the executor at acquisition), not
        job status: a requeued job appears multiple times in the attempts
        log, and a STAGED dispatch still in the WAN hop holds nothing —
        either would misstate rival occupancy.  Walks the in-flight
        index, not the full attempts log; attempts that can no longer
        (re)acquire a slot are dropped on the way through."""
        mine: Dict[str, int] = {}
        dead: List[int] = []
        for key, j in self._inflight.items():
            if j.slot_held:
                if j.resource:
                    mine[j.resource] = mine.get(j.resource, 0) + 1
            elif j.status not in (JobStatus.STAGED, JobStatus.RUNNING):
                # terminal and slotless: a KILLED duplicate whose cancel
                # token fired, or a settled attempt — can never hold (or
                # price) a slot again
                dead.append(key)
        for key in dead:
            del self._inflight[key]
        return mine

    def _new_view(self, spec) -> ResourceView:
        est = self.dispatcher.estimate(self._probe, spec.name)
        return ResourceView(spec=spec, est_job_seconds=max(est, 1e-6))

    def _refresh_views(self) -> None:
        snap = None
        if self.gis_client is not None:
            # discovery phase through the information service: the
            # snapshot refreshes only when its TTL lapses, so membership
            # and liveness here can lag the world by ttl + heartbeats —
            # and an unchanged generation cannot add members, so the
            # membership diff below runs once per refresh, not per tick
            snap = self.gis_client.view(self._now())
            # O(1) whole-pass skip: everything the loops below derive is
            # a pure function of (snapshot, dispatch burns, directory
            # occupancy/liveness).  Unchanged stamps ⇒ every suspected/
            # avail_slots value would be written back identically
            rv_key = (snap.generation, self.gis_client.burns,
                      self.directory.churn, len(self.views))
            if rv_key == self._rv_key:
                return
            if snap.generation != self._seen_gis_generation:
                self._seen_gis_generation = snap.generation
                for name in sorted(snap.entries):
                    entry = snap.entries[name]
                    if (not entry.suspected and name not in self.views
                            and name in self.directory):
                        self.views[name] = self._new_view(entry.spec)
                        self._views_epoch += 1
        else:
            rv_key = (self.directory.churn, len(self.views))
            if rv_key == self._rv_key:
                return
            for spec in self.directory.discover(self.req.user):
                if spec.name not in self.views:
                    self.views[spec.name] = self._new_view(spec)
                    self._views_epoch += 1
        mine = self._my_running()
        mget = mine.get
        dstat = self.directory._status
        if snap is not None:
            # believed liveness: the snapshot's word plus dispatch
            # burns since — NOT the directory's ground truth.  This
            # reassertion must stay per-tick: completion/failure
            # handlers flip ResourceView.suspected between ticks and
            # the broker's belief always wins the argument back
            bad = self.gis_client.suspected_set()
            entries = snap.entries
            taken = snap.taken_at
            changed = False
            for name, v in self.views.items():
                susp = name in bad or name not in entries
                if v.suspected != susp:
                    v.suspected = susp
                    changed = True
                v.last_seen = taken
                st = dstat.get(name)
                if st is not None:
                    # free capacity = slots not held by OTHER users' jobs
                    others = st.running - mget(name, 0)
                    if others < 0:
                        others = 0
                    avail = v.spec.slots - others
                    if avail < 0:
                        avail = 0
                    if v.avail_slots != avail:
                        v.avail_slots = avail
                        changed = True
            if changed:
                self._views_epoch += 1
            self._rv_key = (snap.generation, self.gis_client.burns,
                            self.directory.churn, len(self.views))
        else:
            changed = False
            for name, v in self.views.items():
                st = dstat[name]
                susp = not st.up
                if v.suspected != susp:
                    v.suspected = susp
                    changed = True
                others = st.running - mget(name, 0)
                if others < 0:
                    others = 0
                avail = v.spec.slots - others
                if avail < 0:
                    avail = 0
                if v.avail_slots != avail:
                    v.avail_slots = avail
                    changed = True
            if changed:
                self._views_epoch += 1
            self._rv_key = (self.directory.churn, len(self.views))

    # ------------------------------------------------------------------
    # scheduling tick
    # ------------------------------------------------------------------
    def tick(self) -> None:
        if self._finished:
            return
        t = self._now()
        if self._trace is not None:
            self._tr_flush_memo()
        self._refresh_views()
        remaining = self._remaining()
        if remaining == 0:
            self._finish()
            return

        if self.auction is not None:
            bid = self.auction.step(
                t, {n: v.est_job_seconds for n, v in self.views.items()},
                remaining, self.ledger)
            if bid is not None:
                self._log("AUCTION_BID", price=bid.chip_hour_price,
                          slots=bid.slots)
                if self._trace is not None:
                    self._trace.instant(t, self._track, "auction", "bid",
                                        price=bid.chip_hour_price,
                                        slots=bid.slots)
            won = len(self.auction.contracts)
            if won > self.report.contracts_won:
                for c in self.auction.contracts[self.report.contracts_won:]:
                    self._log("CONTRACT", resource=c.resource,
                              price=c.chip_hour_price, slots=c.slots,
                              via=c.via)
                self.report.contracts_won = won

        # effective prices: an active negotiated contract (carried as a
        # price-locked reservation) beats the spot quote automatically
        prices = None
        if self._board is not None and self.secondary is None:
            # one board pass for the whole view set (t validated once)
            prices = self._board.effective_many(self.views, self.req.user, t)
        if prices is None:
            prices = {n: self._price(n) for n in self.views}
        contracted = (set(self.auction.contracted_resources(t))
                      if self.auction is not None else None)
        decision = self.advisor.decide(t, self.views, prices, remaining,
                                       self.ledger, set(self.allocated),
                                       contracted=contracted,
                                       views_epoch=self._views_epoch)
        if decision.release or decision.allocate:
            self._views_epoch += 1   # allocation moved: re-derive caches
        for r in decision.release:
            self.allocated.discard(r)
            self._log("RELEASE", resource=r)
        for r in decision.allocate:
            self.allocated.add(r)
            self._log("ALLOC", resource=r, price=prices.get(r, 0.0))
        self.report.peak_allocation = max(self.report.peak_allocation,
                                          len(self.allocated))

        if self.auction is not None and self.auction.secondary is not None:
            # the re-plan just decided which resources carry the backlog;
            # contracted windows on resources it left behind are idle —
            # resell them (or hand them back for the fee) instead of
            # sitting on paid-for capacity nobody here will use
            for rid in self.auction.shed_idle(t, keep=self.allocated):
                self._log("RESALE_SHED", rid=rid)
        if self.secondary is not None:
            for r in sorted(self.allocated):
                # a re-allocated resource reclaims this broker's own
                # unsold listings there first — a window back in use is
                # not idle, and must neither sell nor pay the expiry fee
                if self.secondary.reclaim(r, self.req.user, t):
                    self._log("RESALE_RECLAIM", resource=r)
                # then drain rivals' offers at planning time: a broker
                # paying spot on an allocated resource takes over a
                # cheaper listed window even while the queue is
                # momentarily full — the transferred reservation
                # reprices its NEXT dispatch there
                self._maybe_take_resale(r)

        self._fill_slots()
        self._check_stragglers()
        self._tick_count += 1
        if (self.cfg.timeline_stride <= 1
                or (self._tick_count - 1) % self.cfg.timeline_stride == 0):
            self.report.timeline.append(
                (t, len(self.allocated), self.report.n_done,
                 self.ledger.settled))

        # stall detection (all O(1) index reads)
        running = bool(self._active_ids)
        if not running and not self._finished:
            pending = bool(self._pending_ids)
            if not pending and self._remaining() > 0:
                self._finish(stall="max_attempts_exhausted")
                return
            up = [r for r in self.allocated
                  if r in self.views and self.directory.status(r).up]
            if pending and up:
                affordable = any(
                    self.advisor.may_commit(
                        cost_per_job(self.views[r], prices[r]), remaining,
                        self.ledger)
                    for r in up)
                if not affordable:
                    self._finish(stall="budget_exhausted")
                    return

        if self.sim is not None and not self._finished:
            self._tick_handle = self.sim.after(self.cfg.interval, self.tick)

    # ------------------------------------------------------------------
    # dispatch machinery
    # ------------------------------------------------------------------
    def _believed_free_slots(self, r: str, mine: Dict[str, int]) -> int:
        """Slots the broker THINKS are free on ``r``.  Live resources
        answer a queue probe truthfully (the PR-1 slot-race mechanic);
        a dead or departed one can't answer — a GIS broker whose stale
        snapshot still lists it alive believes everything beyond its own
        holdings is free, dispatches, and fast-fails."""
        st = self.directory.status(r)
        spec = self.directory.spec(r)
        if self.gis_client is None or st.up:
            return st.free_slots(spec)
        if self.views[r].suspected:
            return 0
        return max(0, spec.slots - mine.get(r, 0))

    def _fill_slots(self) -> None:
        if not self._pending_ids:
            return
        # saturation skip: the believed-free scan below is a pure
        # function of (directory occupancy/liveness, allocation, view
        # suspicion) — all stamped by (churn, views epoch).  If the last
        # pass under these exact stamps found nothing free, this one
        # will too (at saturation that is every tick)
        nf_key = (self.directory.churn, self._views_epoch)
        if nf_key == self._nf_key:
            return
        mine = self._my_running()
        # believed-free counts first: a resource with zero free slots
        # contributes nothing to the dispatch list, so its price lookup
        # is skipped entirely (at saturation that is every resource);
        # the count per resource is _believed_free_slots, inlined
        dstat = self.directory._status
        dspec = self.directory._specs
        gis_off = self.gis_client is None
        free: List[Tuple[str, int]] = []
        for r in self.allocated:
            st = dstat[r]
            spec = dspec[r]
            if st.up:
                k = spec.slots - st.running
            elif gis_off or self.views[r].suspected:
                k = 0
            else:
                k = spec.slots - mine.get(r, 0)
            if k > 0:
                free.append((r, k))
        if not free:
            self._nf_key = nf_key
            return
        free.sort(key=lambda rk: (cost_per_job(
            self.views[rk[0]], self._price(rk[0])), rk[0]))
        slots: List[str] = []
        for r, k in free:
            slots.extend([r] * k)
        remaining = self._remaining()
        # snapshot only as many pending jobs as there are slots to fill
        # (dispatching reindexes _pending_sorted mid-loop; zip pairs the
        # same (job, slot) tuples the full pending list would have)
        pend: List[Job] = []
        pids = self._pending_ids
        want = len(slots)
        lst = self._pending_sorted
        n = len(lst)
        # jobs dispatch in seq order, so tombstones pile up exactly at
        # the head — advance the lazy head pointer past them once, then
        # collect the first ``want`` live entries
        i = self._pending_head
        while i < n and lst[i][1] not in pids:
            i += 1
        self._pending_head = i
        while i < n and len(pend) < want:
            jid = lst[i][1]
            if jid in pids:
                pend.append(self.jobs[jid])
            i += 1
        for job, resource in zip(pend, slots):
            est = self.views[resource].est_job_seconds
            if self.secondary is not None:
                self._maybe_take_resale(resource)
            price = self._dispatch_price(resource)
            cost = price * self.directory.spec(resource).chips * est / HOUR
            if not self.advisor.may_commit(cost, remaining, self.ledger):
                continue
            self._dispatch(job, resource, cost, price=price)

    def _maybe_take_resale(self, resource: str) -> None:
        """Drain the cheapest resale offer on ``resource`` before paying
        spot: when a rival's listed reservation is all-in cheaper than
        the live quote, buy it — the reservation transfers to this
        broker and every dispatch there while the window lasts draws it
        at the locked price.  Holdings are capped at the queue's
        concurrency: a reservation beyond ``slots`` could never price a
        job and would be pure waste."""
        t = self._now()
        offer = self.secondary.best_offer(resource, t,
                                          exclude=self.req.user)
        if offer is None or offer.all_in_rate >= self._spot(resource) - 1e-12:
            return
        spec = self.directory.spec(resource)
        if self.trade.reserved_slots(resource, self.req.user, t) >= spec.slots:
            return
        lump = offer.lump(t)
        # the lump is a capacity purchase, not a per-job commitment: it
        # settles immediately, so plain budget headroom is the guard
        if not self.ledger.can_commit(lump):
            return
        r = self.secondary.buy(offer.reservation_id, self.req.user, t)
        if r is not None:
            self._log("RESALE_BUY", resource=resource,
                      rid=r.reservation_id, lump=lump,
                      rate=offer.all_in_rate)
            if self._trace is not None:
                self._trace.instant(t, self._track, "job", "resale_buy",
                                    resource=resource,
                                    rid=r.reservation_id, lump=lump,
                                    rate=offer.all_in_rate)

    # -- telemetry helpers (no-ops unless a tracer is attached) --------
    def _tr_flush_memo(self) -> None:
        """Push the plain-int quote-memo tallies into the shared registry
        counters (once per tick — never per quote)."""
        h, miss = self._memo_flushed
        if self._memo_hits != h:
            self._m_memo_hit.inc(self._memo_hits - h)
        if self._memo_misses != miss:
            self._m_memo_miss.inc(self._memo_misses - miss)
        self._memo_flushed = (self._memo_hits, self._memo_misses)

    def _tr_end_attempt(self, job: Job, t: float, outcome: str,
                        **args) -> None:
        # exactly-once per open span: a duplicate killed while its
        # dispatch is still in flight gets its span closed by the kill
        # loop AND a late blocked/failed callback — only the first wins
        sid = f"{self.experiment}/{job.job_id}/a{job.attempt}"
        if sid in self._open_attempts:
            self._open_attempts.discard(sid)
            self._trace.span_end(
                t, self._track, "job", "attempt", sid,
                outcome=outcome, resource=job.resource, **args)

    def _tr_job_done(self, primary: Job, t: float) -> None:
        """Close the job-level lifecycle span and feed the completion
        metrics: dispatch attempts it took (duplicates included) and
        deadline slack at completion (negative = finished late)."""
        jid = primary.job_id
        n_attempts = len(self.attempts[jid])
        self._m_attempts.observe(n_attempts)
        self._m_slack.observe((self.req.deadline - t) / HOUR)
        if jid in self._open_spans:
            self._open_spans.discard(jid)
            self._trace.span_end(
                t, self._track, "job", "job", f"{self.experiment}/{jid}",
                outcome="done", attempts=n_attempts,
                cost=primary.actual_cost)

    def _dispatch(self, job: Job, resource: str, committed: float,
                  price: Optional[float] = None) -> None:
        self.ledger.commit(committed)
        job.committed_cost = committed
        # seal the quote the broker committed against: settlements honor
        # it for the trade server's bid-validity window, after which
        # they re-quote (see honored_price in _handle_done)
        job.quoted_price = (price if price is not None
                            else self._dispatch_price(resource))
        job.submitted_at = self._now()
        primary = job.duplicate_of or job.job_id
        self.attempts[primary].append(job)
        if primary not in self._dispatch_order:
            self._dispatch_order[primary] = len(self._dispatch_order)
        self._inflight[id(job)] = job
        self._log("DISPATCH", job_id=job.job_id, resource=resource,
                  attempt=job.attempt + 1, committed=committed)
        if self._trace is not None:
            t = self._now()
            # span ids carry the identity (experiment/job_id[/aN]), so
            # args hold only what the id cannot: where it went and at
            # what committed price — every retained arg dict is heap the
            # traced-on market pays for all run long
            if primary not in self._open_spans:
                self._open_spans.add(primary)
                self._trace.span_begin(
                    t, self._track, "job", "job",
                    f"{self.experiment}/{primary}")
            # the attempt span must open BEFORE dispatcher.dispatch():
            # a zero-latency grid can fail the attempt re-entrantly,
            # and its end event needs an open begin to match
            sid = f"{self.experiment}/{job.job_id}/a{job.attempt + 1}"
            self._open_attempts.add(sid)
            self._trace.span_begin(
                t, self._track, "job", "attempt", sid,
                resource=resource, committed=committed,
                price=job.quoted_price)
        self.report.resources_used.add(resource)
        cb = DispatchCallbacks(on_started=self._on_started,
                               on_done=self._on_done,
                               on_failed=self._on_failed,
                               on_blocked=self._on_blocked)
        self.dispatcher.dispatch(job, resource, cb)
        # dispatch() mutated (status, attempt) — and, on a zero-latency
        # grid, may already have run failure handlers re-entrantly, so
        # derive the index from wherever the job actually landed
        self._reindex(job)

    # -- callbacks (invoked via the event queue drain) --
    def _on_started(self, job: Job) -> None:
        self._events.append(("started", job, None))
        self._drain_if_sim()

    def _on_done(self, job: Job, exec_seconds: float) -> None:
        self._events.append(("done", job, exec_seconds))
        self._drain_if_sim()

    def _on_failed(self, job: Job, reason: str) -> None:
        self._events.append(("failed", job, reason))
        self._drain_if_sim()

    def _on_blocked(self, job: Job, reason: str) -> None:
        self._events.append(("blocked", job, reason))
        self._drain_if_sim()

    def _drain_if_sim(self) -> None:
        if self.sim is not None:
            self.drain_events()

    def drain_events(self) -> None:
        while self._events:
            kind, job, arg = self._events.popleft()
            if kind == "started":
                self._handle_started(job)
            elif kind == "done":
                self._handle_done(job, arg)
            elif kind == "blocked":
                self._handle_blocked(job, arg)
            else:
                self._handle_failed(job, arg)

    def _handle_started(self, job: Job) -> None:
        job.status = JobStatus.RUNNING
        job.started_at = self._now()
        self._reindex(job)
        self._log("START", job_id=job.job_id, resource=job.resource)

    def _handle_done(self, job: Job, exec_seconds: float) -> None:
        primary_id = job.duplicate_of or job.job_id
        primary = self.jobs.get(primary_id)
        t = self._now()
        # the price sealed at dispatch is only honored inside its
        # validity window; a settlement arriving later re-quotes (an
        # active reservation/contract still locks the negotiated price)
        if job.quoted_price:
            price = self.trade.honored_price(
                job.resource, self.req.user, job.quoted_price,
                job.submitted_at, t)
        else:
            price = self.trade.effective_price(
                job.resource, self.req.user, job.submitted_at)
        actual = price * self.directory.spec(job.resource).chips * \
            exec_seconds / HOUR
        self.ledger.settle(job.committed_cost, actual)
        if self.bank is not None:
            self.bank.record(t=t, user=self.req.user,
                             owner=self.directory.spec(job.resource).site,
                             resource=job.resource, amount=actual)
        job.finished_at = t
        job.actual_cost = actual
        if job.resource in self.views:
            self.views[job.resource].observe_completion(
                exec_seconds, self.cfg.rate_ema)
            self._views_epoch += 1
        self._log("DONE", job_id=job.job_id, resource=job.resource,
                  duration=exec_seconds, cost=actual)
        if self._trace is not None:
            # the attempt span's end carries the settlement (outcome,
            # cost, duration); GridBank.record emits the money-side
            # "settle" instant — no separate job instant, the traced
            # market emits more events than sim events and every
            # redundant one costs gate headroom
            # dispatch-to-settlement latency (WAN hop + staging + run):
            # the dashboard's attempt-latency percentiles read this
            self._m_att_latency.observe(t - job.submitted_at)
            self._tr_end_attempt(job, t, "settled", cost=actual,
                                 duration=exec_seconds)

        if primary is None or primary.status == JobStatus.DONE:
            return  # lost the race; already settled above
        primary.status = JobStatus.DONE
        primary.finished_at = t
        primary.actual_cost += actual
        primary.result = job.result
        self._reindex(primary)
        self.report.n_done += 1
        self.report.total_cost = self.ledger.settled
        if self._trace is not None:
            self._tr_job_done(primary, t)
        # kill losing duplicates
        for other in self.attempts[primary_id]:
            if other is not job and other.status in (JobStatus.STAGED,
                                                     JobStatus.RUNNING):
                other.status = JobStatus.KILLED
                self.dispatcher.cancel(other)
                # pay only for chip time actually held: a duplicate still
                # in the dispatch hop never acquired a slot and costs 0
                elapsed = (max(t - other.acquired_at, 0.0)
                           if other.slot_held else 0.0)
                if other.quoted_price:
                    kp = self.trade.honored_price(
                        other.resource, self.req.user, other.quoted_price,
                        other.submitted_at, t)
                else:
                    kp = self.trade.effective_price(
                        other.resource, self.req.user, other.submitted_at)
                kcost = kp * self.directory.spec(other.resource).chips * \
                    elapsed / HOUR
                self.ledger.settle(other.committed_cost, kcost)
                if self.bank is not None:
                    self.bank.record(
                        t=t, user=self.req.user,
                        owner=self.directory.spec(other.resource).site,
                        resource=other.resource, amount=kcost, kind="kill")
                self._log("KILL_SETTLED", job_id=other.job_id, cost=kcost)
                if self._trace is not None:
                    # bank.record above already emitted the "kill" money
                    # instant; the span end carries the rest
                    self._tr_end_attempt(other, t, "killed", cost=kcost)
        if self._remaining() == 0:
            self._finish()
        else:
            self._fill_slots()

    def _handle_blocked(self, job: Job, reason: str) -> None:
        """The dispatch lost the race for the last free slot to another
        broker.  The resource is healthy and the job did not run: refund
        the commitment, requeue without burning an attempt, and do not
        suspect the resource."""
        if self._trace is not None:
            # before the attempt counter is handed back: the span id
            # must match the one _dispatch opened
            self._tr_end_attempt(job, self._now(), "slot_lost")
        self.ledger.settle(job.committed_cost, 0.0)
        job.committed_cost = 0.0
        job.attempt = max(0, job.attempt - 1)
        self.report.slot_races_lost += 1
        self._log("SLOT_LOST", job_id=job.job_id, resource=job.resource,
                  reason=reason)
        primary_id = job.duplicate_of or job.job_id
        primary = self.jobs.get(primary_id)
        if primary is None or primary.status == JobStatus.DONE:
            return
        if job.duplicate_of is None:
            job.status = JobStatus.PENDING
            self._reindex(job)
            self.report.requeues += 1
        else:
            job.status = JobStatus.KILLED   # duplicate: primary still runs
        # do NOT refill immediately — the slot we just lost is taken; the
        # next scheduling tick retries against fresh status

    def _handle_failed(self, job: Job, reason: str) -> None:
        primary_id = job.duplicate_of or job.job_id
        self.ledger.settle(job.committed_cost, 0.0)
        job.committed_cost = 0.0
        fault = is_resource_fault(reason)
        if job.resource in self.views:
            self.views[job.resource].failures += 1
            self.views[job.resource].suspected = True
            self._views_epoch += 1
        if fault and self.gis_client is not None and job.resource:
            # feed the burn back into the broker's cached view: suspect
            # locally until the next snapshot says otherwise
            self.gis_client.suspect(job.resource)
            if self._trace is not None:
                self._trace.instant(self._now(), self._track, "gis",
                                    "suspect", resource=job.resource,
                                    reason=reason)
        self._log("FAIL", job_id=job.job_id, resource=job.resource,
                  reason=reason, attempt=job.attempt)
        if self._trace is not None:
            self._tr_end_attempt(job, self._now(), "failed", reason=reason,
                                 fault=fault)
        primary = self.jobs.get(primary_id)
        if primary is None or primary.status == JobStatus.DONE:
            return
        if job.duplicate_of is None:
            self.report.requeues += 1
            if self._trace is not None:
                self._trace.instant(self._now(), self._track, "job",
                                    "requeue", job_id=job.job_id,
                                    resource=job.resource, fault=fault)
            if fault:
                # the machine died or left, not the job: its price-locked
                # commitment was refunded above, the attempt is handed
                # back (SLOT_LOST-style), and the job requeues cleanly
                job.attempt = max(0, job.attempt - 1)
                job.status = JobStatus.PENDING
                self.report.resource_losses += 1
            else:
                job.status = JobStatus.FAILED
                if job.attempt >= self.cfg.max_attempts:
                    self.report.n_failed_final += 1
            self._reindex(job)
        # a failed DUPLICATE keeps its STAGED/RUNNING status (and its
        # _inflight entry): it still blocks a re-race of its primary and
        # still draws down a locked reservation in _dispatch_price.
        # Long-standing engine behavior — the golden-equivalence hashes
        # pin it, so retiring the ghost is a scheduling change, not a
        # cleanup
        self._fill_slots()

    # ------------------------------------------------------------------
    # stragglers
    # ------------------------------------------------------------------
    def _check_stragglers(self) -> None:
        """Speculative execution (tail phase): a running job whose elapsed
        time exceeds ``factor x`` the *fastest allocated resource's*
        estimate gets a duplicate raced on a free slot — first completion
        wins.  (MapReduce-style: predictably-slow machines are also worth
        racing once faster slots are idle.)"""
        t = self._now()
        ests = [self.views[r].est_job_seconds for r in self.allocated
                if r in self.views]
        if not ests:
            return
        fastest = min(ests)
        # cheap pre-pass: no RUNNING primary past the elapsed threshold
        # means the ordered walk below would `continue` on every entry —
        # skip the per-tick sort entirely (stragglers are the tail case).
        # The earliest possible straggle time is remembered so quiet
        # stretches skip even the pre-pass: new dispatches start later
        # than every job already running, so the bound only moves when
        # the threshold inputs do (estimates/allocation = views epoch)
        if self._views_epoch == self._strag_epoch and t < self._strag_until:
            return
        thr = self.cfg.straggler_factor * fastest
        jobs = self.jobs
        min_started = None
        hit = False
        for jid in self._running_ids:
            j = jobs.get(jid)
            if j is None or j.status is not JobStatus.RUNNING:
                continue
            s = j.started_at
            if t - s > thr:
                hit = True
                break
            if min_started is None or s < min_started:
                min_started = s
        if not hit:
            self._strag_epoch = self._views_epoch
            self._strag_until = (min_started + thr if min_started is not None
                                 else t + thr)
            return
        # walk only the currently-RUNNING primaries, in first-dispatch
        # order — the order the full attempts-log walk used to visit
        # them in (budget-guarded ``break`` below makes order part of
        # the behavior, not just the cost)
        for primary_id in sorted(self._running_ids,
                                 key=self._dispatch_order.__getitem__):
            primary = self.jobs.get(primary_id)
            if primary is None or primary.status != JobStatus.RUNNING:
                continue
            attempts = self.attempts[primary_id]
            if any(a.duplicate_of for a in attempts
                   if a.status in (JobStatus.STAGED, JobStatus.RUNNING)):
                continue  # already racing a duplicate
            if t - primary.started_at <= self.cfg.straggler_factor * fastest:
                continue
            # find a different allocated resource with a free slot
            for r in sorted(self.allocated,
                            key=lambda n: (self.views[n].est_job_seconds, n)):
                if r == primary.resource:
                    continue
                st = self.directory.status(r)
                if st.free_slots(self.directory.spec(r)) <= 0:
                    continue
                dup_price = self._dispatch_price(r)
                cost = dup_price * self.directory.spec(r).chips * \
                    self.views[r].est_job_seconds / HOUR
                if not self.advisor.may_commit(cost, self._remaining(),
                                               self.ledger):
                    break
                self._dup_counter += 1
                dspec = dataclasses.replace(
                    primary.spec, job_id=f"{primary_id}~{self._dup_counter}")
                dup = Job(spec=dspec, duplicate_of=primary_id)
                self._log("DUPLICATE", job_id=dspec.job_id,
                          original=primary_id, resource=r)
                if self._trace is not None:
                    self._trace.instant(t, self._track, "job", "duplicate",
                                        job_id=dspec.job_id,
                                        original=primary_id, resource=r)
                self.report.duplicates_launched += 1
                self._dispatch(dup, r, cost, price=dup_price)
                break

    # ------------------------------------------------------------------
    def steer(self, *, deadline: Optional[float] = None,
              budget: Optional[float] = None) -> None:
        """Adjust the experiment's deadline and/or budget mid-run — the
        paper's client interaction ("the user can vary constraints such
        as deadline and budget" while monitoring a live experiment).
        Swaps the frozen ``UserRequirements`` on the engine and re-
        targets the advisor (the next re-plan prices against the new
        knobs); a budget change also moves the ledger's hard ceiling.
        Emits one ``steer`` instant so a steered run's trace carries
        every intervention and stays same-seed byte-reproducible."""
        if deadline is None and budget is None:
            return
        old = self.req
        self.req = dataclasses.replace(
            old,
            deadline=old.deadline if deadline is None else deadline,
            budget=old.budget if budget is None else budget)
        self.advisor.retarget(self.req)
        if budget is not None:
            self.ledger.budget = budget
        self._log("STEER", deadline=self.req.deadline,
                  budget=self.req.budget)
        if self._trace is not None:
            self._trace.instant(
                self._now(), self._track, "steer", "adjust",
                user=self.req.user, deadline=self.req.deadline,
                budget=self.req.budget, old_deadline=old.deadline,
                old_budget=old.budget)

    # ------------------------------------------------------------------
    @property
    def finished(self) -> bool:
        return self._finished

    def finish(self, stall: Optional[str] = None) -> None:
        """Public finalization hook (e.g. a marketplace driver cutting
        the run off at its horizon)."""
        self._finish(stall=stall)

    def _finish(self, stall: Optional[str] = None) -> None:
        if self._finished:
            return
        self._finished = True
        if self._tick_handle is not None:
            # a finished engine's tick chain leaves the heap NOW — in a
            # long marketplace run the clock must not keep popping dead
            # brokers' wakeups
            self._tick_handle.cancel()
            self._tick_handle = None
        t = self._now()
        if self.auction is not None:
            self.auction.withdraw(t)
        self.report.completion_time = t
        self.report.met_deadline = (self.report.n_done == self.report.n_jobs
                                    and t <= self.req.deadline + 1e-6)
        self.report.within_budget = self.ledger.settled <= self.req.budget + 1e-6
        self.report.total_cost = self.ledger.settled
        self.report.stall_reason = stall
        self._log("EXP_DONE", n_done=self.report.n_done,
                  cost=self.ledger.settled, stall=stall)
        if self._trace is not None:
            self._tr_flush_memo()
            # close whatever the run left open (sorted — deterministic):
            # attempts still in flight at the horizon, then their jobs
            for j in sorted((j for j in self._inflight.values()
                             if j.status in (JobStatus.STAGED,
                                             JobStatus.RUNNING)),
                            key=lambda j: j.job_id):
                self._tr_end_attempt(j, t, "unfinished")
            for sid in sorted(self._open_attempts):
                self._trace.span_end(t, self._track, "job", "attempt",
                                     sid, outcome="unfinished")
            self._open_attempts.clear()
            for jid in sorted(self._open_spans):
                self._trace.span_end(
                    t, self._track, "job", "job",
                    f"{self.experiment}/{jid}", outcome="unfinished",
                    status=self.jobs[jid].status.name)
            self._open_spans.clear()
            self._trace.instant(
                t, self._track, "market", "broker_finish",
                user=self.req.user, strategy=self.req.strategy,
                done=self.report.n_done, jobs=self.report.n_jobs,
                met_deadline=self.report.met_deadline,
                slack_h=(self.req.deadline - t) / HOUR,
                spent=self.ledger.settled, budget=self.req.budget,
                stall=stall)
        if self.sim is not None and self.stop_sim_when_done:
            self.sim.stop()

    # ------------------------------------------------------------------
    # drivers
    # ------------------------------------------------------------------
    def run_simulated(self, *, failures: bool = True,
                      horizon: Optional[float] = None) -> ExperimentReport:
        assert self.sim is not None, "construct with sim=Simulator()"
        if failures:
            fp = FailureProcess(self.sim, self.directory, seed=self.seed)
            for name in self.directory.all_names():
                fp.install(name)
        self.sim.after(0.0, self.tick)
        self.sim.run(until=horizon if horizon is not None
                     else self.req.deadline * 4 + 8 * HOUR)
        if not self._finished:
            self._finish(stall="horizon_reached")
        return self.report

    def run_local(self, poll: float = 0.02,
                  wall_timeout: float = 3600.0) -> ExperimentReport:
        """Drive real payload execution (thread-pool grid)."""
        assert self.sim is None
        t0 = _time.time()
        self.tick()
        last_tick = _time.time()
        while not self._finished and _time.time() - t0 < wall_timeout:
            _time.sleep(poll)
            self.drain_events()
            if self._remaining() == 0:
                self._finish()
                break
            if _time.time() - last_tick >= min(self.cfg.interval, 0.25):
                self.tick()
                last_tick = _time.time()
        if not self._finished:
            self._finish(stall="wall_timeout")
        return self.report
