"""Secondary capacity market + clearing-history price discovery.

Two follow-ups to the GRACE economy close the loop the primary market
leaves open (cs/0111048 makes supply-and-demand-driven price adjustment
the core mechanism; cs/0203019 models resale of reserved capacity
between brokers):

* **Resale.**  A broker whose deadline/budget re-plan leaves contracted
  reservations idle can *list* them on its domain's trade server
  instead of tearing them up.  The ask is a remaining-window pro-rata
  of the locked price (``ask_fraction`` of it, billed only for the
  window still ahead at fill time).  Other brokers see live listings
  merged into ``solicit_bids``/``effective_price`` as just another
  price source; a fill transfers the ``Reservation`` to the buyer
  (``TradeServer.transfer`` — admission quotas still enforced), the
  buyer keeps paying the *owner* the original locked price per use,
  and the lump the buyer pays the *seller* is mirrored through
  ``GridBank`` as a matched charge/refund pair (net zero to the owner,
  so every ledger still reconciles exactly).

* **Commitment fees.**  Advance reservations are commitments: with
  ``release_fee > 0``, a holder who hands a window back unexpired pays
  the owner ``release_fee`` x the remaining window's value at the
  locked price (bank kind ``"idle"``).  A listing that never sells
  pays the same fee over its listed-idle span.  The sum of these fees
  is the market's *wasted-contract spend* — the number resale exists
  to shrink.

* **Price discovery.**  Every auction clearing round and every resale
  fill appends to a per-resource ``ClearingHistory``; a
  ``PriceSchedule`` constructed with ``discovery_gain > 0`` EMA-nudges
  its posted base price toward the price level those trades imply
  (drift bounded to ``discovery_band`` around the original base).
  Owners' posted schedules thereby converge toward what capacity
  actually clears at.

Everything is deterministic on the virtual clock: listings iterate in
reservation-id order, fills and fees fire only from simulator events,
and no wall clock or RNG is consulted.  All of it is opt-in — with the
default knobs (``release_fee=0``, ``resale=False``,
``discovery_gain=0``) nothing here runs and the primary market is
bit-for-bit unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.economy import (AdmissionError, Reservation, TradeFederation,
                                TradeServer)

HOUR = 3600.0


@dataclasses.dataclass(frozen=True)
class Clearing:
    """One realized trade price on one resource: what the market said
    capacity there was worth at ``t`` (and what the owner was posting
    at that moment — the gap discovery is trying to close)."""
    t: float
    resource: str
    price: float                    # chip-hour price the trade cleared at
    posted: float                   # owner's forward quote at the same t
    source: str                     # "auction" | "resale"


class ClearingHistory:
    """Per-resource append-only log of clearing events.

    The ``AuctionHouse`` appends each site round's matched resources at
    the uniform clearing price; the ``SecondaryMarket`` appends each
    fill at its all-in rate.  ``PriceSchedule.observe_clearing`` feeds
    off the same clearing-round events; this log is the audit trail,
    and ``gap_by_observation`` is the bench's posted-vs-clearing
    convergence measure."""

    def __init__(self):
        self.entries: List[Clearing] = []
        self._by_resource: Dict[str, List[Clearing]] = {}

    def append(self, t: float, resource: str, price: float, posted: float,
               source: str) -> None:
        c = Clearing(t=t, resource=resource, price=price, posted=posted,
                     source=source)
        self.entries.append(c)
        self._by_resource.setdefault(resource, []).append(c)

    def for_resource(self, resource: str) -> List[Clearing]:
        return list(self._by_resource.get(resource, ()))

    def last_price(self, resource: str) -> Optional[float]:
        hist = self._by_resource.get(resource)
        return hist[-1].price if hist else None

    def gap_by_observation(self, source: str = "auction") -> List[float]:
        """Mean relative |posted - clearing| / posted gap at each
        resource's k-th clearing of the given ``source``, averaged
        across resources.  This is the discovery loop's own axis: with
        ``discovery_gain > 0`` every observation EMA-steps a resource's
        posted base toward what it cleared at, so the sequence shrinks
        (weakly) monotonically; with the gain at zero it is flat."""
        per: Dict[str, int] = {}
        buckets: List[List[float]] = []
        for c in self.entries:
            if c.source != source or c.posted <= 0:
                continue
            k = per.get(c.resource, 0)
            per[c.resource] = k + 1
            while len(buckets) <= k:
                buckets.append([])
            buckets[k].append(abs(c.posted - c.price) / c.posted)
        return [sum(b) / len(b) for b in buckets]


@dataclasses.dataclass
class ResaleListing:
    """One reservation up for resale.  The ask is quoted as a chip-hour
    *rate* premium; the lump a buyer actually pays is that rate over the
    window still remaining at fill time (remaining-window pro-rata) —
    a listing that sells late sells cheap."""
    reservation_id: int
    seller: str
    resource: str
    site: str
    chips: int
    listed_at: float
    end: float                      # reservation window end
    locked_price: float             # what the buyer keeps paying the owner
    ask_rate: float                 # chip-hour premium paid to the seller

    @property
    def all_in_rate(self) -> float:
        """The buyer's true chip-hour rate: owner usage at the locked
        price plus the seller's premium — the number advisors rank
        against the spot quote."""
        return self.locked_price + self.ask_rate

    def lump(self, t: float) -> float:
        """G$ the buyer pays the seller for the remaining window."""
        return self.ask_rate * self.chips * max(self.end - t, 0.0) / HOUR


@dataclasses.dataclass(frozen=True)
class ResaleFill:
    """Audit record of one secondary trade."""
    t: float
    reservation_id: int
    seller: str
    buyer: str
    resource: str
    lump: float
    rate: float                     # all-in chip-hour rate (price signal)


class SecondaryMarket:
    """Resale book + commitment-fee settlement over a trade federation.

    One instance serves the whole grid (listings carry their site, and
    transfers route to the owning domain's server).  Brokers register
    their ledgers so every fee, charge and refund lands in both the
    broker's ``BudgetLedger`` and the ``GridBank`` with the same
    ``+=`` — the two stay reconcilable to the bit.

    ``version`` is a monotone stamp bumped on every book mutation
    (list, fill, drop); broker-side quote memos key on it exactly like
    they key on ``TradeServer.book_version``.
    """

    def __init__(self, federation: TradeFederation, bank, *,
                 release_fee: float = 0.25,
                 resale: bool = True,
                 ask_fraction: float = 0.5,
                 history: Optional[ClearingHistory] = None):
        if release_fee < 0:
            raise ValueError("release_fee must be >= 0")
        if ask_fraction < 0:
            raise ValueError("ask_fraction must be >= 0")
        self.federation = federation
        self.bank = bank
        self.release_fee = release_fee
        self.resale = resale
        self.ask_fraction = ask_fraction
        self.history = history
        self.listings: Dict[int, ResaleListing] = {}
        self.fills: List[ResaleFill] = []
        # latest holder-by-purchase per reservation id: churn rebates
        # for a voided window must reach whoever bought it, not the
        # broker the contract was originally struck with
        self._buyers: Dict[int, str] = {}
        self.version = 0
        self.wasted_spend = 0.0         # G$ of idle/release fees, ever
        self.resale_volume = 0.0        # G$ of lumps changing hands
        self._ledgers: Dict[str, object] = {}
        self.tracer = None              # set by bind_telemetry

    # -- wiring --------------------------------------------------------
    def bind_telemetry(self, tracer) -> None:
        """Attach a ``repro.core.telemetry.Tracer``: fills, fees and
        book mutations emit ``resale`` instants, and the registry gains
        gauges over the book and the run-to-date G$ aggregates."""
        self.tracer = tracer
        m = tracer.metrics
        m.gauge("market.wasted_spend_gd", unit="G$",
                fn=lambda: self.wasted_spend)
        m.gauge("market.resale_volume_gd", unit="G$",
                fn=lambda: self.resale_volume)
        m.gauge("resale.listings", fn=lambda: float(len(self.listings)))
        m.gauge("resale.fills", fn=lambda: float(len(self.fills)))
    def register_user(self, user: str, ledger) -> None:
        """Attach a broker's ledger so the market can settle against it
        (fees, lump charges, lump refunds)."""
        self._ledgers[user] = ledger

    def _settle(self, user: str, resource: str, site: str, amount: float,
                t: float, kind: str) -> None:
        ledger = self._ledgers.get(user)
        if ledger is not None:
            ledger.settle(0.0, amount)
        if self.bank is not None:
            self.bank.record(t=t, user=user, owner=site, resource=resource,
                             amount=amount, kind=kind)

    def _charge_fee(self, user: str, resource: str, site: str,
                    amount: float, t: float) -> float:
        if amount <= 0.0:
            return 0.0
        self._settle(user, resource, site, amount, t, kind="idle")
        self.wasted_spend += amount
        if self.tracer is not None:
            self.tracer.instant(t, "resale", "resale", "fee",
                                user=user, resource=resource,
                                site=site, amount=amount)
        return amount

    def _fee(self, locked_price: float, chips: int, span: float) -> float:
        """The commitment fee on ``span`` seconds of a reserved window
        handed back (or idled) unexpired — the ONE definition both the
        release path and the expired-unsold path charge."""
        return self.release_fee * locked_price * chips * max(span, 0.0) / HOUR

    def _locate(self, reservation_id: int
                ) -> Optional[Tuple[str, TradeServer, Reservation]]:
        """Find a live reservation anywhere in the federation (ids are
        federation-unique, so the first hit is the only hit).  A linear
        scan on purpose: reservation books are pruned on access (the
        PR-2 invariant bounds them at O(live windows)), and shed/sweep
        run per re-plan / per watch sample, not per quote — the broker
        hot path never comes through here."""
        for site, server in self.federation.servers.items():
            # the find_reservation seam lets a wire-proxy server answer
            # by id without shipping its whole reservation book; plain
            # TradeServers implement it as the same linear scan
            r = server.find_reservation(reservation_id)
            if r is not None:
                return site, server, r
        return None

    # -- seller side ---------------------------------------------------
    def shed(self, reservation_id: int, seller: str, t: float) -> str:
        """The holder no longer needs this reservation.  With resale it
        goes on the book; without, it is released on the spot for the
        commitment fee.  Returns "listed" | "released" | "gone"."""
        if reservation_id in self.listings:
            return "listed"             # idempotent: already on the book
        loc = self._locate(reservation_id)
        if loc is None:
            return "gone"               # voided/expired/transferred away
        site, server, r = loc
        if r.user != seller or r.end <= t:
            return "gone"
        if self.resale:
            self.listings[reservation_id] = ResaleListing(
                reservation_id=reservation_id, seller=seller,
                resource=r.resource, site=site,
                chips=server.directory.spec(r.resource).chips,
                listed_at=t, end=r.end, locked_price=r.locked_price,
                ask_rate=self.ask_fraction * r.locked_price)
            self.version += 1
            return "listed"
        self.release(reservation_id, seller, t)
        return "released"

    def release(self, reservation_id: int, holder: str, t: float) -> float:
        """Cancel an unexpired reservation and charge the holder the
        commitment fee on the window handed back.  Returns the fee."""
        loc = self._locate(reservation_id)
        if loc is None:
            return 0.0
        site, server, r = loc
        if r.user != holder:
            return 0.0
        server.cancel(reservation_id)
        self.listings.pop(reservation_id, None)
        self.version += 1
        fee = self._fee(r.locked_price,
                        server.directory.spec(r.resource).chips,
                        r.end - t)
        return self._charge_fee(holder, r.resource, site, fee, t)

    def reclaim(self, resource: str, holder: str, t: float) -> int:
        """The holder's re-plan wants ``resource`` back: pull their own
        unsold listings on it off the book, fee-free — the window is in
        use again, not idle, so neither a fill nor the expiry fee may
        take it from under them.  Returns the number of listings
        reclaimed."""
        mine = [rid for rid, l in self.listings.items()
                if l.resource == resource and l.seller == holder]
        for rid in mine:
            del self.listings[rid]
        if mine:
            self.version += 1
            if self.tracer is not None:
                self.tracer.instant(t, "resale", "resale", "reclaim",
                                    holder=holder, resource=resource,
                                    listings=len(mine))
        return len(mine)

    def buyer_of(self, reservation_id: int) -> Optional[str]:
        """Who holds this reservation by purchase (None if it never
        traded hands)."""
        return self._buyers.get(reservation_id)

    def drop(self, reservation_id: int,
             t: Optional[float] = None) -> bool:
        """Remove a listing without a fee or a fill — the event-driven
        path for reservations voided under their listing (a churning
        site's contracts): the capacity was taken from the holder, not
        idled by them.  Exact and sweep-timing-independent — a void
        discovered only after the window's end must not look like an
        expired-unsold listing."""
        listing = self.listings.pop(reservation_id, None)
        if listing is None:
            return False
        self.version += 1
        if self.tracer is not None and t is not None:
            self.tracer.instant(t, "resale", "resale", "drop",
                                rid=reservation_id,
                                seller=listing.seller,
                                resource=listing.resource)
        return True

    # -- buyer side ----------------------------------------------------
    def offers_for(self, resource: str, t: float, *,
                   exclude: str = "") -> List[ResaleListing]:
        """Live listings on ``resource`` a buyer could fill right now,
        cheapest all-in rate first (ties broken by reservation id)."""
        out = [l for l in self.listings.values()
               if l.resource == resource and l.seller != exclude
               and l.end > t and l.site in self.federation.servers]
        # (all_in_rate, reservation_id) is a total order — rids are
        # federation-unique — so one sort fully determines the book view
        return sorted(out, key=lambda l: (l.all_in_rate, l.reservation_id))

    def offers_at_site(self, site: Optional[str], t: float, *,
                       exclude: str = "") -> List[ResaleListing]:
        """Live listings one domain's trade server should merge into its
        sealed-bid answers (``site=None`` = the whole grid)."""
        return [l for rid, l in sorted(self.listings.items())
                if (site is None or l.site == site) and l.seller != exclude
                and l.end > t and l.site in self.federation.servers]

    def best_offer(self, resource: str, t: float, *,
                   exclude: str = "") -> Optional[ResaleListing]:
        offers = self.offers_for(resource, t, exclude=exclude)
        return offers[0] if offers else None

    def best_rate(self, resource: str, t: float, *,
                  exclude: str = "") -> Optional[float]:
        offer = self.best_offer(resource, t, exclude=exclude)
        return offer.all_in_rate if offer is not None else None

    def buy(self, reservation_id: int, buyer: str, t: float
            ) -> Optional[Reservation]:
        """Fill a listing: transfer the reservation to the buyer and
        move the lump seller-ward through the bank.  Returns the (now
        buyer-held) reservation, or None if the fill is impossible
        (listing gone, site departed, buyer over quota)."""
        listing = self.listings.get(reservation_id)
        if listing is None or listing.seller == buyer or listing.end <= t:
            return None
        server = self.federation.servers.get(listing.site)
        if server is None:
            # domain left the grid under the listing: nothing to deliver
            del self.listings[reservation_id]
            self.version += 1
            return None
        try:
            r = server.transfer(reservation_id, buyer, t)
        except AdmissionError:
            return None
        if r is None:
            # reservation vanished (voided contract, pruned window)
            del self.listings[reservation_id]
            self.version += 1
            return None
        lump = listing.lump(t)
        # matched pair through the SAME owner: buyer charge + seller
        # refund net to zero domain revenue, and each side's ledger
        # moves by exactly its bank entry — reconciliation stays exact
        self._settle(buyer, listing.resource, listing.site, lump, t,
                     kind="resale")
        self._settle(listing.seller, listing.resource, listing.site, -lump,
                     t, kind="resale")
        del self.listings[reservation_id]
        self.version += 1
        self.resale_volume += lump
        self._buyers[reservation_id] = buyer
        fill = ResaleFill(t=t, reservation_id=reservation_id,
                          seller=listing.seller, buyer=buyer,
                          resource=listing.resource, lump=lump,
                          rate=listing.all_in_rate)
        self.fills.append(fill)
        if self.tracer is not None:
            self.tracer.instant(t, "resale", "resale", "fill",
                                seller=listing.seller, buyer=buyer,
                                resource=listing.resource,
                                rid=reservation_id, lump=lump,
                                rate=listing.all_in_rate)
        # the fill is a realized trade: log it for the audit trail and
        # the bench's price traces.  It does NOT nudge the owner's
        # schedule — the lump is a user-to-user payment the owner is no
        # party to; owners learn from their own clearing rounds
        if self.history is not None:
            sched = server.schedules.get(listing.resource)
            posted = (sched.chip_hour_price(t) if sched is not None
                      else listing.all_in_rate)
            self.history.append(t, listing.resource, listing.all_in_rate,
                                posted, "resale")
        return r

    # -- lifecycle -----------------------------------------------------
    def sweep(self, t: float) -> float:
        """Periodic housekeeping on the sim clock: expire listings whose
        window lapsed unsold (the seller pays the commitment fee over
        the listed-idle span) and drop listings whose reservation no
        longer exists (churn voided it — the breach rebate already
        compensated the holder; no fee on capacity that vanished).
        Returns the fees charged."""
        fees = 0.0
        for rid in sorted(self.listings):
            listing = self.listings[rid]
            if t >= listing.end:
                fees += self._expire(listing, t)
                continue
            server = self.federation.servers.get(listing.site)
            if server is None:
                continue            # departed: kept dormant until rejoin
            if server.find_reservation(rid) is None:
                del self.listings[rid]
                self.version += 1
        return fees

    def finalize(self, t: float) -> float:
        """End of the run: every listing still on the book goes unsold —
        settle their fees so the books close."""
        fees = self.sweep(t)
        for rid in sorted(self.listings):
            fees += self._expire(self.listings[rid], t)
        return fees

    def _expire(self, listing: ResaleListing, t: float) -> float:
        """Unsold: the window sat committed and idle from listing to its
        end — the same fee a straight release at listing time would
        have paid.  A reservation that vanished BEFORE its window ended
        (churn voided the contract under the listing) charges nothing:
        the capacity was taken from the holder, not idled by them, and
        the breach rebate already settled that loss."""
        del self.listings[listing.reservation_id]
        self.version += 1
        server = (self.federation.servers.get(listing.site)
                  or self.federation._departed.get(listing.site))
        cancelled = (server.cancel(listing.reservation_id)
                     if server is not None else False)
        if t < listing.end and not cancelled:
            return 0.0
        fee = self._fee(listing.locked_price, listing.chips,
                        listing.end - listing.listed_at)
        return self._charge_fee(listing.seller, listing.resource,
                                listing.site, fee, t)
