"""Deadline/budget-constrained (DBC) adaptive scheduling — paper §3.

The schedule advisor periodically re-plans against live grid state:

1. *discovery*   — authorized, believed-up resources; under a Grid
   Information Service this is a TTL-cached, heartbeat-stale snapshot
   (``ResourceView.last_seen``), not ground truth;
2. *trading*     — price quotes / sealed bids from the trade server;
3. *rate model*  — jobs/second each resource sustains: roofline-seeded
   estimate refined by an EMA of measured completions (the paper's
   "historical information, including job consumption rate");
4. *selection*   — a pluggable ``Strategy`` resolved from the registry
   in ``repro.core.strategies`` by ``UserRequirements.strategy``.  The
   three classic Nimrod/G policies live there (byte-identical to the
   historical if/elif dispatch):

   * ``cost``          minimize G$ subject to the deadline: cheapest
                       resources first, just enough aggregate rate;
   * ``time``          minimize completion time subject to the budget:
                       add resources cheapest-per-job first while the
                       rate-weighted projected spend fits the budget;
   * ``conservative``  like ``cost`` but guarantees every unfinished job
                       a budget share before committing a dispatch;

   alongside the economy-aware zoo (``auction``, ``reputation``,
   ``adaptive``, ``scavenger``) — see the package docstrings.

As the deadline tightens the cost strategy buys more (and more expensive)
resources — exactly the paper's Figure 3 behaviour.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Set, Tuple

try:
    import numpy as np
except ImportError:          # pragma: no cover - numpy is a CI dep
    np = None

from repro.core.economy import Bid, BudgetLedger, TradeServer, UserRequirements
from repro.core.resources import ResourceDirectory, ResourceSpec
from repro.core.strategies import Strategy, StrategyContext, create
from repro.core.strategies import cost_per_job  # noqa: F401  (re-export)

HOUR = 3600.0


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    interval: float = 120.0          # seconds between advisor wakeups
    safety: float = 1.15             # aggregate-rate margin over the minimum
    straggler_factor: float = 2.5    # duplicate when elapsed > f * estimate
    max_attempts: int = 5
    rate_ema: float = 0.5            # weight of new measurement
    min_resources: int = 1
    # record every k-th tick into ExperimentReport.timeline (1 = every
    # tick, the historical behavior).  A 10k-job horizon-length run at
    # stride 1 holds O(ticks) tuples per broker; large-scale sweeps set
    # this to keep reports bounded without touching scheduling behavior
    timeline_stride: int = 1


@dataclasses.dataclass
class ResourceView:
    """Scheduler-local model of one resource.

    ``avail_slots`` is the capacity this broker can actually use: total
    slots minus slots occupied by *other* users' jobs.  The single-user
    engine never shrinks it (it owns the whole queue); under a shared
    grid the marketplace engines refresh it every tick so rate and cost
    projections reflect free capacity, not exclusive ownership."""
    spec: ResourceSpec
    est_job_seconds: float           # current duration estimate
    measured_rate: Optional[float] = None    # jobs/s EMA (full resource)
    completions: int = 0
    failures: int = 0
    suspected: bool = False
    avail_slots: Optional[int] = None        # None = all of spec.slots
    # when the liveness/membership half of this view was last fetched
    # from the information service (None = omniscient directory path);
    # everything the advisor believes about this resource is as-of here
    last_seen: Optional[float] = None

    def _avail_fraction(self) -> float:
        if self.avail_slots is None or self.spec.slots <= 0:
            return 1.0
        return max(0, min(self.avail_slots, self.spec.slots)) / self.spec.slots

    def rate(self) -> float:
        full = (self.measured_rate if self.measured_rate is not None
                else self.spec.slots / max(self.est_job_seconds, 1e-9))
        return full * self._avail_fraction()

    def observe_completion(self, duration: float, ema: float) -> None:
        r = self.spec.slots / max(duration, 1e-9)
        self.measured_rate = (r if self.measured_rate is None
                              else (1 - ema) * self.measured_rate + ema * r)
        self.est_job_seconds = self.spec.slots / max(self.rate(), 1e-12)
        self.completions += 1
        self.suspected = False


def views_from_gis(snapshot, est_seconds_base: float
                   ) -> Dict[str, "ResourceView"]:
    """Build the scheduler's resource views from a GIS snapshot — the
    discovery-first path a broker on the wire grid uses (it holds no
    directory, only what the information service answered).  Suspected
    entries carry their flag through, so the advisor deprioritizes them
    exactly as it does on the in-process grid."""
    views: Dict[str, ResourceView] = {}
    for name, e in sorted(snapshot.entries.items()):
        views[name] = ResourceView(
            spec=e.spec,
            est_job_seconds=est_seconds_base / max(e.spec.perf_factor,
                                                   1e-6),
            suspected=e.suspected,
            last_seen=snapshot.taken_at)
    return views


@dataclasses.dataclass
class AllocationDecision:
    allocate: List[str]
    release: List[str]
    projected_rate: float
    needed_rate: float
    projected_cost_per_job: float
    feasible_time: bool
    feasible_budget: bool


class ScheduleAdvisor:
    """The pluggable scheduling policy (the paper exposes exactly this
    seam: "a user could build an alternative scheduler by using these
    APIs").  Policy lives in a ``Strategy`` resolved from the registry;
    the advisor owns what every policy shares — live-view filtering,
    the needed-rate computation, the canonical ranking, the
    ``min_resources`` floor and the decision bookkeeping."""

    def __init__(self, cfg: SchedulerConfig, requirements: UserRequirements,
                 strategy: Optional[Strategy] = None):
        self.cfg = cfg
        self.req = requirements
        # an unregistered strategy string fails HERE, at broker build
        # time — not as a silent fall-through to the cost policy
        self.strategy = (strategy if strategy is not None
                         else create(requirements.strategy))
        self._secondary = None
        self._bank = None
        self._history = None
        self._gis_client = None
        self._trace = None
        self._track = ""
        # last canonical ranking, keyed on exactly the inputs the sort
        # consumes — prices move piecewise (peak windows, slot churn),
        # so consecutive re-plans usually share one ordering
        self._rank_cpj: Optional[Dict[str, float]] = None
        self._rank_held: Optional[Set[str]] = None
        self._rank_list: Optional[List[str]] = None
        # (live, rates, cpj) from the last decide, valid while the
        # caller's views-epoch and the exact views/prices dict objects
        # are unchanged (the board hands out one shared prices dict per
        # clean stretch, so identity is a real stamp, not an accident)
        self._lv_epoch: Optional[int] = None
        self._lv_views = None
        self._lv_prices = None
        self._lv = None

    def bind_telemetry(self, tracer, track: str) -> None:
        """Attach a ``repro.core.telemetry.Tracer``: ``decide`` counts
        every re-plan and emits a ``sched``/``replan`` instant whenever
        the allocation actually changed.  Purely observational — the
        decision is computed identically with or without it."""
        self._trace = tracer
        self._track = track
        m = tracer.metrics
        self._m_decisions = m.counter("sched.decisions")
        self._m_replans = m.counter("sched.replans")

    def bind_market(self, *, secondary=None, bank=None, history=None,
                    gis_client=None) -> None:
        """Attach the marketplace's economy hooks (resale book, grid
        bank, clearing history, GIS client) so strategies can consult
        them.  The single-user engine never calls this — every strategy
        must work with the hooks at None."""
        self._secondary = secondary
        self._bank = bank
        self._history = history
        self._gis_client = gis_client

    def retarget(self, requirements: UserRequirements) -> None:
        """Swap the user's requirements mid-run — the paper's steering
        interaction (deadline/budget can change at any time).  The next
        ``decide`` re-plans against the new deadline; nothing else is
        cached off the old object.  Counted when telemetry is bound so
        a steered run's re-planning pressure is visible in the trace."""
        self.req = requirements
        if self._trace is not None:
            self._trace.metrics.counter("sched.retargets").inc()

    # -- selection strategies ------------------------------------------------

    def decide(self, t: float, views: Dict[str, ResourceView],
               prices: Dict[str, float], remaining_jobs: int,
               ledger: BudgetLedger, current: Set[str],
               contracted: Optional[Set[str]] = None,
               views_epoch: Optional[int] = None
               ) -> AllocationDecision:
        """Re-plan the allocation.  ``prices`` must already be
        *effective* prices (a negotiated contract's locked price where
        one is active, the spot quote otherwise) — the advisor ranks
        contracts and spot offers in one ordering.  ``contracted``
        resources win cost ties: capacity already paid for by a
        negotiated contract should be drawn down first."""
        # One pass over the views computes everything the ranking and
        # the feasibility sums below re-derive per-name in the scalar
        # path: the free-capacity rate and the cost-per-job, each the
        # exact expression ``ResourceView.rate``/``cost_per_job`` uses
        # (a 1.0 avail fraction multiplies out bit-exactly).
        if (views_epoch is not None and views_epoch == self._lv_epoch
                and views is self._lv_views and prices is self._lv_prices):
            live, rates, cpj = self._lv
            return self._decide_tail(t, views, prices, remaining_jobs,
                                     ledger, current, contracted,
                                     live, rates, cpj)
        live: Dict[str, ResourceView] = {}
        rates: Dict[str, float] = {}
        cpj: Dict[str, float] = {}
        for n, v in views.items():
            if v.suspected:
                continue
            live[n] = v
            spec = v.spec
            slots = spec.slots
            est = v.est_job_seconds
            full = v.measured_rate
            if full is None:
                full = slots / max(est, 1e-9)
            av = v.avail_slots
            if av is None or slots <= 0:
                rates[n] = full
            else:
                if av > slots:
                    av = slots
                elif av < 0:
                    av = 0
                rates[n] = full * (av / slots)
            cpj[n] = prices[n] * spec.chips * est / HOUR
        if views_epoch is not None:
            self._lv_epoch = views_epoch
            self._lv_views = views
            self._lv_prices = prices
            self._lv = (live, rates, cpj)
        return self._decide_tail(t, views, prices, remaining_jobs, ledger,
                                 current, contracted, live, rates, cpj)

    def _decide_tail(self, t: float, views: Dict[str, ResourceView],
                     prices: Dict[str, float], remaining_jobs: int,
                     ledger: BudgetLedger, current: Set[str],
                     contracted: Optional[Set[str]],
                     live: Dict[str, ResourceView],
                     rates: Dict[str, float],
                     cpj: Dict[str, float]) -> AllocationDecision:
        """Everything after the per-view map build: ranking, strategy
        selection, the floor and the decision bookkeeping."""
        time_left = max(self.req.deadline - t, 1e-6)
        needed = self.cfg.safety * remaining_jobs / time_left

        held = contracted or set()
        if (self._rank_list is not None
                and (cpj is self._rank_cpj or cpj == self._rank_cpj)
                and held == self._rank_held):
            ranked = self._rank_list
        else:
            if np is not None and len(live) > 1:
                # one lexsort over (cpj, not-held, name) — the same
                # lexicographic key tuple, evaluated as three flat arrays
                names = list(live)
                order = np.lexsort((
                    np.array(names),
                    np.fromiter((n not in held for n in names),
                                dtype=bool, count=len(names)),
                    np.fromiter((cpj[n] for n in names),
                                dtype=np.float64, count=len(names))))
                ranked = [names[i] for i in order]
            else:
                ranked = sorted(
                    live, key=lambda n: (cpj[n], n not in held, n))
            # the cpj dict is rebuilt fresh every call and never mutated
            # after select(), so holding a reference is a valid stamp
            self._rank_cpj = cpj
            self._rank_held = set(held)
            self._rank_list = ranked
        if not ranked:   # transient: everything down/suspected — hold state
            if self._trace is not None:
                self._m_decisions.inc()
            return AllocationDecision(
                allocate=[], release=[], projected_rate=0.0,
                needed_rate=needed, projected_cost_per_job=math.inf,
                feasible_time=False, feasible_budget=False)

        # current/held/ranked pass by reference: every registered
        # strategy treats the context as read-only (select() builds its
        # own result set), and ``ranked`` may be the advisor's cached
        # ranking — a strategy that mutated it would corrupt the cache
        ctx = StrategyContext(
            t=t, req=self.req, cfg=self.cfg, views=live, prices=prices,
            remaining_jobs=remaining_jobs, ledger=ledger,
            needed_rate=needed, current=current, held=held,
            ranked=ranked, secondary=self._secondary,
            bank=self._bank, history=self._history,
            gis_client=self._gis_client, rates=rates, cpj=cpj)
        chosen = self.strategy.select(ctx)

        need = self.cfg.min_resources - len(chosen)
        if need > 0:
            # prefer resources with free capacity when topping up — the
            # stable zero-rate-last partition of ``ranked``, walked only
            # until the floor is met
            fallback: List[str] = []
            for n in ranked:
                if rates[n] > 0 and n not in chosen:
                    fallback.append(n)
                    if len(fallback) == need:
                        break
            if len(fallback) < need:
                for n in ranked:
                    if rates[n] <= 0 and n not in chosen:
                        fallback.append(n)
                        if len(fallback) == need:
                            break
            chosen |= set(fallback)

        rate = 0.0
        wsum = 0.0
        for n in chosen:
            r = rates[n]
            rate += r
            wsum += r * cpj[n]
        wcost = (wsum / rate) if rate > 0 else math.inf
        decision = AllocationDecision(
            allocate=sorted(chosen - current),
            release=sorted(current - chosen),
            projected_rate=rate,
            needed_rate=needed,
            projected_cost_per_job=wcost,
            feasible_time=rate + 1e-12 >= remaining_jobs / time_left,
            feasible_budget=(wcost * remaining_jobs <= ledger.remaining + 1e-9),
        )
        if self._trace is not None:
            self._m_decisions.inc()
            if decision.allocate or decision.release:
                self._m_replans.inc()
                self._trace.instant(
                    t, self._track, "sched", "replan",
                    allocate=",".join(decision.allocate),
                    release=",".join(decision.release),
                    projected_rate=rate, needed_rate=needed,
                    cost_per_job=(wcost if math.isfinite(wcost) else -1.0),
                    remaining=remaining_jobs)
        return decision

    # -- per-dispatch budget guard -------------------------------------------

    def may_commit(self, est_cost: float, remaining_jobs: int,
                   ledger: BudgetLedger) -> bool:
        return self.strategy.may_commit(est_cost, remaining_jobs, ledger)


# ---------------------------------------------------------------------------
# contract mode (paper §3, "second method"): negotiate before running
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ContractQuote:
    feasible: bool
    est_completion: float            # absolute virtual time
    est_cost: float
    n_resources: int
    reserved: Tuple[int, ...] = ()   # reservation ids if accepted


def negotiate_contract(t: float, req: UserRequirements, n_jobs: int,
                       trade: TradeServer, views: Dict[str, ResourceView],
                       accept: bool = False,
                       accept_at: Optional[float] = None) -> ContractQuote:
    """Solicit bids, pick the cheapest feasible set, optionally lock it in
    with advance reservations.  The user can then proceed or renegotiate
    with a different deadline/budget (exactly the paper's protocol).

    ``accept_at`` is when the user actually signs (defaults to ``t``,
    i.e. on the spot).  A user who deliberates past a sealed bid's
    validity loses its price: the reservation locks at the live quote
    instead — an expired bid is re-quoted, never silently honored."""
    bids = trade.solicit_bids(
        t, req.user, lambda spec: views[spec.name].est_job_seconds
        if spec.name in views else 3600.0)
    time_left = max(req.deadline - t, 1e-6)
    needed = n_jobs / time_left

    chosen: List[Bid] = []
    acc = 0.0
    by_cpj = sorted(
        bids, key=lambda b: b.chip_hour_price * trade.directory.spec(
            b.resource).chips / max(b.est_rate, 1e-9))
    for b in by_cpj:
        if acc >= needed:
            break
        chosen.append(b)
        acc += b.est_rate / HOUR
    feasible_time = acc >= needed
    if acc <= 0:
        return ContractQuote(False, math.inf, math.inf, 0)
    completion = t + n_jobs / acc
    cost = 0.0
    for b in chosen:
        share = (b.est_rate / HOUR) / acc * n_jobs
        spec = trade.directory.spec(b.resource)
        # amortized per-job cost: the whole resource bills
        # chip_hour_price * chips per hour and sustains est_rate
        # jobs/hour, so one job costs price * chips / est_rate.
        # (est_rate already counts every slot — multiplying by
        # spec.slots again overstated the quote by the slot count and
        # made feasible contracts look budget-infeasible.)  This is the
        # resource-level price of the farm's chip-hours; note the
        # engine's per-dispatch settlement bills each concurrent job
        # the full chip complement, so on a slots>1 queue the two
        # conventions differ — everywhere both run today (gusto-style
        # testbeds) slots == 1 and they agree exactly.
        cost += share * b.chip_hour_price * spec.chips / max(b.est_rate, 1e-9)
    feasible = feasible_time and cost <= req.budget
    rids: Tuple[int, ...] = ()
    if feasible and accept:
        at = t if accept_at is None else accept_at
        # resale-backed bids (resale_rid != 0) price the quote but are
        # not reservable here: locking one in means buying the listing
        # on the secondary market, never reserving fresh capacity at
        # the all-in rate (that would pay the seller's premium to the
        # owner — or crash on a queue the listing already fills)
        rids = tuple(
            trade.reserve(
                b.resource, req.user, at, req.deadline, at,
                locked_price=(b.chip_hour_price
                              if at <= b.valid_until else None)
            ).reservation_id for b in chosen if not b.resale_rid)
    return ContractQuote(feasible, completion, cost, len(chosen), rids)
