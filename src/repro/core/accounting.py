"""GridBank: resource-owner revenue accounting (paper §7, GRACE).

GRACE's economy has two sides.  PR 1 built the consumer side — each
broker's ``BudgetLedger`` tracks what a *user* spends.  This module adds
the producer side: every settlement a broker makes is mirrored into a
grid-wide bank as revenue for the resource's owner (its administrative
domain).  Owners can then see which users fund them (and extend quota
courtesies to proven patrons — admission driven by realized revenue),
and the market as a whole can be audited: every grid-dollar a user spent
must show up as exactly one grid-dollar of some owner's revenue.

Reconciliation notes: per-user totals are accumulated in the same order
and with the same ``+=`` operations as the brokers' ledgers, so
``user_spend(u) == ledger.settled`` holds bit-for-bit.  The grand
totals are genuinely two-sided — producer books (per-owner sums) vs.
consumer books (per-user sums) — and both are checked against an
``fsum`` over the raw entry log, to within one part in 1e9.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Tuple

HOUR = 3600.0


@dataclasses.dataclass(frozen=True)
class BankEntry:
    """One settlement: ``user`` paid ``owner`` ``amount`` G$ for chip
    time on ``resource`` at virtual time ``t``.  ``amount`` is negative
    for ``kind="refund"`` — an owner paying a user back (e.g. the
    breach rebate when a departing site voids a live contract)."""
    t: float
    user: str
    owner: str                      # administrative domain (spec.site)
    resource: str
    amount: float
    kind: str = "settle"            # settle | kill | contract | refund


class ReconciliationError(Exception):
    """The books do not balance: spend and revenue diverged."""


class GridBank:
    """Double-entry ledger between users and resource owners."""

    def __init__(self):
        self.entries: List[BankEntry] = []
        self._spend: Dict[str, float] = {}
        self._revenue: Dict[str, float] = {}
        self._pair: Dict[Tuple[str, str], float] = {}
        self._owner_kind: Dict[Tuple[str, str], float] = {}
        # exactly-once keys already booked (``record_once``): in the
        # sharded grid every settlement crosses a wire and may be
        # retried or replayed from a journal — the id set is what keeps
        # a re-delivered settlement from double-booking revenue
        self._settled_ids: set = set()
        self.tracer = None              # set by bind_telemetry

    def bind_telemetry(self, tracer) -> None:
        """Attach a ``repro.core.telemetry.Tracer``: every entry emits a
        ``bank`` instant on the owning domain's track, and the registry
        gains derived gauges over the live books (grand totals and the
        per-owner revenue-by-kind family the issue tracker asks for)."""
        self.tracer = tracer
        m = tracer.metrics
        self._m_settlements = m.counter("bank.settlements")
        m.gauge("bank.total_spend_gd", unit="G$", fn=self.total_spend)
        m.gauge("bank.total_revenue_gd", unit="G$", fn=self.total_revenue)
        m.gauge("bank.entries", fn=lambda: float(len(self.entries)))
        m.multi_gauge(
            "bank.revenue_by_kind_gd", unit="G$",
            fn=lambda: {f"{o}/{k}": v
                        for (o, k), v in self._owner_kind.items()})

    # -- recording -----------------------------------------------------
    def record(self, *, t: float, user: str, owner: str, resource: str,
               amount: float, kind: str = "settle") -> None:
        if amount == 0.0:
            return                  # nothing moved; keep the book compact
        self.entries.append(BankEntry(t=t, user=user, owner=owner,
                                      resource=resource, amount=amount,
                                      kind=kind))
        self._spend[user] = self._spend.get(user, 0.0) + amount
        self._revenue[owner] = self._revenue.get(owner, 0.0) + amount
        key = (user, owner)
        self._pair[key] = self._pair.get(key, 0.0) + amount
        ok = (owner, kind)
        self._owner_kind[ok] = self._owner_kind.get(ok, 0.0) + amount
        if self.tracer is not None:
            # plain settlements are the overwhelmingly common entry and
            # already visible as the broker's attempt-span end (cost) and
            # the revenue_by_kind gauge family; per-entry instants are
            # reserved for the exceptional money movements (kill, fee,
            # refund, ...) so the bank track stays readable and the
            # traced-on hot path stays under the overhead gate
            if kind == "settle":
                self._m_settlements.inc()
            else:
                self.tracer.instant(t, f"site:{owner}", "bank", kind,
                                    user=user, resource=resource,
                                    amount=amount)

    def record_once(self, settlement_id: str, *, t: float, user: str,
                    owner: str, resource: str, amount: float,
                    kind: str = "settle") -> bool:
        """Idempotent settlement: book the entry unless ``settlement_id``
        was already booked.  Returns True when the entry was recorded,
        False for a duplicate (a retried wire delivery or a journal
        replay after a crash) — the caller can tell at-most-once
        delivery failed without the books ever seeing the double."""
        if settlement_id in self._settled_ids:
            return False
        self._settled_ids.add(settlement_id)
        self.record(t=t, user=user, owner=owner, resource=resource,
                    amount=amount, kind=kind)
        return True

    def seen_settlement(self, settlement_id: str) -> bool:
        return settlement_id in self._settled_ids

    # -- queries -------------------------------------------------------
    def users(self) -> List[str]:
        return sorted(self._spend)

    def owners(self) -> List[str]:
        return sorted(self._revenue)

    def user_spend(self, user: str) -> float:
        return self._spend.get(user, 0.0)

    def owner_revenue(self, owner: str) -> float:
        return self._revenue.get(owner, 0.0)

    def pair_spend(self, user: str, owner: str) -> float:
        """What ``user`` has actually paid ``owner`` so far — the
        realized-revenue signal owners feed back into admission."""
        return self._pair.get((user, owner), 0.0)

    def total_revenue(self) -> float:
        """Grand total from the producer-side books (per-owner sums)."""
        return math.fsum(self._revenue.values())

    def total_spend(self) -> float:
        """Grand total from the consumer-side books (per-user sums) —
        independently accumulated, so comparing it against
        ``total_revenue`` is a genuine two-sided audit."""
        return math.fsum(self._spend.values())

    def kind_total(self, kind: str) -> float:
        """Signed G$ total of one entry kind — e.g. ``"idle"`` is the
        market's aggregate wasted-contract spend (commitment fees paid
        for reserved-but-unused windows), and ``"resale"`` nets to zero
        by construction (every fill is a matched charge/refund pair)."""
        return math.fsum(e.amount for e in self.entries if e.kind == kind)

    def owner_kind_total(self, owner: str, kind: str) -> float:
        """Signed G$ one owner has moved under one entry kind — e.g.
        ``owner_kind_total(site, "refund")`` is (minus) the breach
        rebates the domain has paid back, the per-domain risk signal
        reputation-aware brokers price resources by.  Indexed at
        ``record`` time so every-tick reads stay O(1)."""
        return self._owner_kind.get((owner, kind), 0.0)

    def total_refunds(self) -> float:
        """G$ owners have paid BACK to users (contract-breach rebates
        from departing sites).  Positive number; the signed entries are
        already netted into spend/revenue."""
        return -math.fsum(e.amount for e in self.entries
                          if e.kind == "refund")

    def top_patrons(self, owner: str, n: int = 3) -> List[Tuple[str, float]]:
        pairs = [(u, amt) for (u, o), amt in self._pair.items()
                 if o == owner]
        return sorted(pairs, key=lambda p: (-p[1], p[0]))[:n]

    # -- audit ---------------------------------------------------------
    def kind_breakdown(self, user: Optional[str] = None) -> str:
        """Per-kind signed totals (settle/kill/contract/refund/idle/
        resale), grid-wide or for one user — the diagnosis a bare
        "books don't balance" error denies its reader.  Public because
        the online money-conservation watchdog
        (``repro.core.monitor``) attaches it to violations too."""
        by_kind: Dict[str, float] = {}
        for e in self.entries:
            if user is not None and e.user != user:
                continue
            by_kind[e.kind] = by_kind.get(e.kind, 0.0) + e.amount
        if not by_kind:
            return "no entries"
        return ", ".join(f"{k}={v!r}" for k, v in sorted(by_kind.items()))

    def reconcile(self, ledgers: Optional[Mapping[str, object]] = None,
                  tol: float = 0.0) -> float:
        """Audit the books; returns the grand total that both sides agree
        on.  Raises ``ReconciliationError`` if (a) owner revenue and user
        spend diverge (they are the same entry multiset summed two ways —
        fsum makes the comparison exact), or (b) a broker ledger's
        ``settled`` differs from the bank's record of that user.  Error
        messages carry the per-kind delta breakdown so a mismatch is
        diagnosable from the message alone."""
        by_owner = self.total_revenue()
        by_user = self.total_spend()
        total = math.fsum(e.amount for e in self.entries)
        if not (abs(by_owner - by_user) <= tol + 1e-9 * max(1.0, abs(total))):
            raise ReconciliationError(
                f"owner revenue {by_owner!r} != user spend {by_user!r} "
                f"(delta {by_owner - by_user!r}); "
                f"per-kind totals: {self.kind_breakdown()}")
        if ledgers is not None:
            for user, ledger in sorted(ledgers.items()):
                settled = getattr(ledger, "settled", ledger)
                if settled != self.user_spend(user):
                    bank = self.user_spend(user)
                    raise ReconciliationError(
                        f"user {user!r}: ledger settled {settled!r} != "
                        f"bank record {bank!r} "
                        f"(delta {settled - bank!r}); "
                        f"per-kind totals for {user!r}: "
                        f"{self.kind_breakdown(user)}")
        return total

    def statement(self) -> str:
        """Human-readable owner revenue statement."""
        lines = [f"GridBank: {len(self.entries)} settlements, "
                 f"{self.total_revenue():.2f}G$ total"]
        for owner in self.owners():
            patrons = ", ".join(f"{u}:{amt:.1f}"
                                for u, amt in self.top_patrons(owner))
            lines.append(f"  {owner:10s} revenue={self.owner_revenue(owner):10.2f}"
                         f"  top patrons: {patrons}")
        return "\n".join(lines)
