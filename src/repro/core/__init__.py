"""The paper's system: Nimrod/G resource management & scheduling.

Components (paper Figure 1): client, parametric engine, scheduler,
dispatcher, job wrapper — plus the GRACE computational-economy market
(per-site trade servers, sealed bids, reservations, the double-auction /
contract-net auction house, owner revenue accounting) and the
virtual-time grid simulator.
"""
from repro.core.accounting import (BankEntry, GridBank, ReconciliationError)
from repro.core.auctions import (Ask, AuctionBid, AuctionBroker,
                                 AuctionHouse, ClearingRound, Contract,
                                 CounterOffer, DoubleAuctionBook,
                                 NegotiationTimeout)
from repro.core.economy import (AdmissionError, Bid, BudgetLedger,
                                PriceSchedule, Reservation, TradeFederation,
                                TradeServer, UserRequirements)
from repro.core.gis import (GISClient, GISEntry, GISRecord, GISRegistry,
                            GISSnapshot, GridInformationService,
                            department_of)
from repro.core.jobs import Job, JobSpec, JobStatus
from repro.core.marketplace import (Marketplace, MarketReport, MarketUser,
                                    UserOutcome, mixed_auction_market,
                                    standard_market)
from repro.core.monitor import (BrokerHealth, ExperimentMonitor,
                                InvariantViolation, SiteHealth,
                                SteeringAction)
from repro.core.parametric import ExperimentReport, NimrodG
from repro.core.persistence import (Journal, load_events, replay,
                                    stable_dumps)
from repro.core.plan import Plan, PlanError, parse_plan, substitute
from repro.core.resources import (ResourceDirectory, ResourceSpec,
                                  ResourceStatus, gusto_like_testbed)
from repro.core.protocol import (PROTOCOL_VERSION, Message, ProtocolError,
                                 example_messages)
from repro.core.protocol import dumps as protocol_dumps
from repro.core.protocol import loads as protocol_loads
from repro.core.scheduler import (AllocationDecision, ContractQuote,
                                  ResourceView, ScheduleAdvisor,
                                  SchedulerConfig, negotiate_contract,
                                  views_from_gis)
from repro.core.secondary import (Clearing, ClearingHistory, ResaleFill,
                                  ResaleListing, SecondaryMarket)
from repro.core.simulator import (ChurnProcess, ConservativeClock,
                                  FailureProcess, Simulator,
                                  WallClockSimulator, duration_model)
from repro.core.telemetry import (Counter, Gauge, Histogram,
                                  MetricsRegistry, MultiGauge, Subscription,
                                  TraceEvent, Tracer, export_chrome_trace,
                                  export_jsonl, load_chrome_trace)
from repro.core.strategies import (Strategy, StrategyContext,
                                   available_strategies, cost_per_job,
                                   strategy_class)
from repro.core.strategies import create as create_strategy
from repro.core.strategies import register as register_strategy
from repro.core.dispatcher import (RESOURCE_DEPARTED, SLOT_LOST,
                                   DispatchCallbacks, Dispatcher,
                                   LocalExecutor, SimulatedExecutor,
                                   StagingProxy, is_resource_fault)
from repro.core.transport import (DomainConfig, DomainEndpoint,
                                  DomainProcess, LoopbackTransport,
                                  RemoteGIS, RemoteTradeServer,
                                  TransportError, WireFederation,
                                  build_domain, spawn_domains,
                                  wrap_federation_loopback)

__all__ = [
    "AdmissionError", "AllocationDecision", "Ask", "AuctionBid",
    "AuctionBroker", "AuctionHouse", "BankEntry", "Bid", "BudgetLedger",
    "BrokerHealth", "ChurnProcess", "Clearing", "ClearingHistory",
    "ClearingRound", "ConservativeClock",
    "Contract", "ContractQuote", "Counter",
    "CounterOffer", "DispatchCallbacks", "Dispatcher", "DoubleAuctionBook",
    "DomainConfig", "DomainEndpoint", "DomainProcess",
    "ExperimentMonitor", "ExperimentReport", "FailureProcess",
    "GISClient", "GISEntry",
    "GISRecord", "GISRegistry", "GISSnapshot", "Gauge", "GridBank",
    "GridInformationService", "Histogram", "Job", "JobSpec",
    "InvariantViolation",
    "JobStatus", "Journal", "LocalExecutor", "LoopbackTransport",
    "MarketReport", "MarketUser",
    "Marketplace", "Message", "MetricsRegistry", "MultiGauge",
    "NegotiationTimeout", "NimrodG", "PROTOCOL_VERSION", "Plan",
    "PlanError",
    "PriceSchedule", "ProtocolError", "ReconciliationError",
    "RemoteGIS", "RemoteTradeServer", "ResaleFill", "ResaleListing",
    "Reservation",
    "ResourceDirectory", "ResourceSpec", "ResourceStatus", "ResourceView",
    "RESOURCE_DEPARTED", "SLOT_LOST", "ScheduleAdvisor", "SchedulerConfig",
    "SecondaryMarket", "SimulatedExecutor", "Simulator", "SiteHealth",
    "StagingProxy", "SteeringAction", "Strategy",
    "StrategyContext", "Subscription", "TraceEvent", "Tracer",
    "TradeFederation",
    "TradeServer", "TransportError", "UserOutcome", "UserRequirements",
    "WallClockSimulator", "WireFederation",
    "available_strategies", "build_domain", "cost_per_job",
    "create_strategy",
    "department_of",
    "duration_model", "example_messages", "export_chrome_trace",
    "export_jsonl",
    "gusto_like_testbed", "is_resource_fault",
    "load_chrome_trace",
    "load_events", "mixed_auction_market", "negotiate_contract",
    "parse_plan", "protocol_dumps", "protocol_loads",
    "register_strategy", "replay", "spawn_domains", "stable_dumps",
    "standard_market",
    "strategy_class", "substitute", "views_from_gis",
    "wrap_federation_loopback",
]
