"""Multi-user grid marketplace (paper §3 + §7 GRACE).

Nimrod/G's premise is *distributed ownership*: many users, each with an
independent deadline/budget broker, competing for the same scattered
resources, with prices mediating demand.  ``Marketplace`` realizes that
experiment: N concurrent ``NimrodG`` engines — each with its own
``UserRequirements``, ``BudgetLedger`` and ``ScheduleAdvisor`` — run
against ONE shared ``ResourceDirectory``/``TradeServer`` on a single
``Simulator`` clock.  Trading runs through one ``TradeServer`` per
administrative domain (federated behind ``TradeFederation``), an
``AuctionHouse`` clears negotiated contracts between brokers and owners,
and every settlement is mirrored into the ``GridBank`` as the owning
domain's revenue.

What the shared grid changes versus the single-user engine:

* slot accounting is contention-safe — a broker's dispatch can lose the
  race for the last free slot (``SLOT_LOST``) and requeues without
  burning an attempt or suspecting the resource;
* owners quote demand-responsive prices (utilization-indexed multiplier,
  the GRACE supply-and-demand knob), so a crowded grid gets expensive
  and cost-minimizing brokers back off to off-peak/cheap machines;
* each broker reads *free* capacity (slots not held by rivals), not the
  resource's full rate;
* discovery runs through the hierarchical ``GridInformationService``:
  brokers plan against TTL-cached, heartbeat-stale snapshots, and with
  ``run(churn=True)`` whole sites leave and rejoin mid-run (in-flight
  jobs fail over, contracts are voided with breach rebates through the
  bank, the trade federation's membership tracks the GIS).

Everything unfolds in virtual time from seeded RNG streams: the entire
market run is exactly reproducible per seed.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.accounting import GridBank
from repro.core.auctions import AuctionBroker, AuctionHouse
from repro.core.dispatcher import Dispatcher, SimulatedExecutor
from repro.core.economy import (PriceSchedule, TradeFederation, TradeServer,
                                UserRequirements)
from repro.core.gis import GridInformationService
from repro.core.jobs import JobSpec
from repro.core.parametric import NimrodG
from repro.core.resources import (ResourceDirectory, ResourceSpec,
                                  gusto_like_testbed)
from repro.core.scheduler import SchedulerConfig
from repro.core.secondary import ClearingHistory, SecondaryMarket
from repro.core.simulator import ChurnProcess, FailureProcess, Simulator
from repro.core.strategies import strategy_class

HOUR = 3600.0


@dataclasses.dataclass(frozen=True)
class MarketUser:
    """One participant: their broker's knobs (paper's deadline + budget)."""
    name: str
    deadline: float                  # absolute virtual time
    budget: float                    # G$
    strategy: str = "cost"           # any name in repro.core.strategies
    n_jobs: int = 50
    est_seconds: float = 1800.0      # per-job runtime on perf_factor=1


@dataclasses.dataclass
class UserOutcome:
    """Per-user market result (the broker's report, condensed)."""
    user: str
    strategy: str
    n_jobs: int
    n_done: int
    completion_time: float
    spent: float
    budget: float
    met_deadline: bool
    within_budget: bool
    requeues: int
    slot_races_lost: int
    peak_allocation: int
    stall_reason: Optional[str]
    contracts_won: int = 0
    resource_losses: int = 0         # dispatches burned on dead resources

    def row(self) -> str:
        return (f"{self.user:12s} {self.strategy:12s} "
                f"{self.n_done:4d}/{self.n_jobs:<4d} "
                f"t={self.completion_time / HOUR:7.2f}h "
                f"spent={self.spent:9.2f}/{self.budget:<9.0f} "
                f"met={str(self.met_deadline):5s} "
                f"races_lost={self.slot_races_lost:3d} "
                f"requeues={self.requeues:3d} "
                f"burned={self.resource_losses:3d} "
                f"contracts={self.contracts_won:3d}")


@dataclasses.dataclass
class MarketReport:
    seed: int
    n_users: int
    n_resources: int
    outcomes: List[UserOutcome]
    total_jobs: int
    total_done: int
    total_spent: float
    slot_races_lost: int
    deadline_met_frac: float
    price_trace: List[Tuple[float, float]]   # (t, mean grid quote)
    contracts_struck: int = 0
    owner_revenue: Dict[str, float] = dataclasses.field(default_factory=dict)
    # information-layer / churn telemetry
    resource_losses: int = 0                 # dispatches burned on corpses
    evictions: int = 0                       # in-flight jobs failed over
    refunds: float = 0.0                     # G$ of contract-breach rebates
    churn_trace: List[Tuple[float, str, str]] = dataclasses.field(
        default_factory=list)                # (t, leave|join, site)
    gis_refreshes: int = 0                   # broker snapshot fetches
    # secondary-market telemetry (all zero when the market is off)
    resale_enabled: bool = False
    resales: int = 0                         # listings filled
    resale_volume: float = 0.0               # G$ of lumps seller-ward
    wasted_spend: float = 0.0                # G$ of idle/commitment fees

    def summary(self) -> str:
        lines = [f"marketplace seed={self.seed}: {self.n_users} users on "
                 f"{self.n_resources} resources — "
                 f"{self.total_done}/{self.total_jobs} jobs, "
                 f"{self.deadline_met_frac:.0%} deadlines met, "
                 f"spend={self.total_spent:.1f}G$, "
                 f"slot races lost={self.slot_races_lost}, "
                 f"contracts={self.contracts_struck}"]
        lines += ["  " + o.row() for o in self.outcomes]
        if self.owner_revenue:
            lines.append("  owner revenue: " + ", ".join(
                f"{o}={v:.1f}" for o, v in sorted(self.owner_revenue.items())))
        if self.churn_trace or self.resource_losses:
            lines.append(
                f"  churn: {len(self.churn_trace)} membership events, "
                f"{self.evictions} in-flight evictions, "
                f"{self.resource_losses} dispatches burned on stale views, "
                f"refunds={self.refunds:.1f}G$")
        if self.resale_enabled or self.wasted_spend:
            lines.append(
                f"  secondary: resale={'on' if self.resale_enabled else 'off'}"
                f", {self.resales} fills, volume={self.resale_volume:.1f}G$, "
                f"wasted-contract spend={self.wasted_spend:.1f}G$")
        return "\n".join(lines)

    def stable_repr(self) -> str:
        """Byte-stable serialization (repr floats are exact) for
        determinism checks: two same-seed runs must match exactly."""
        parts = [f"seed={self.seed};users={self.n_users};"
                 f"res={self.n_resources}"]
        for o in self.outcomes:
            parts.append(
                f"{o.user}|{o.strategy}|{o.n_done}/{o.n_jobs}"
                f"|t={o.completion_time!r}|spent={o.spent!r}"
                f"|met={o.met_deadline}|races={o.slot_races_lost}"
                f"|rq={o.requeues}|rl={o.resource_losses}"
                f"|peak={o.peak_allocation}"
                f"|stall={o.stall_reason}|contracts={o.contracts_won}")
        parts.append("revenue=" + ",".join(
            f"{o}:{v!r}" for o, v in sorted(self.owner_revenue.items())))
        parts.append(f"churn={self.churn_trace!r};ev={self.evictions}"
                     f";refunds={self.refunds!r}")
        if self.resale_enabled or self.resales or self.wasted_spend:
            # only emitted when the secondary market ran: default-market
            # serializations stay byte-identical to the pre-PR-5 ones
            parts.append(f"secondary={self.resale_enabled}"
                         f";fills={self.resales}"
                         f";vol={self.resale_volume!r}"
                         f";wasted={self.wasted_spend!r}")
        parts.append("trace=" + ",".join(
            f"({t!r},{p!r})" for t, p in self.price_trace))
        return "\n".join(parts)


class Marketplace:
    """N brokers, one grid, one clock.

    Each user gets their own dispatcher/executor (the paper's per-broker
    architecture) but all of them mutate the same directory status — the
    shared truth the slot race is fought over.
    """

    def __init__(self, specs: Optional[Sequence[ResourceSpec]] = None,
                 *, n_machines: int = 20, seed: int = 0,
                 demand_elasticity: float = 0.5,
                 spot_amplitude: float = 0.0,
                 dispatch_latency: float = 1.0,
                 noise_sigma: float = 0.1,
                 max_reservations_per_user: Optional[int] = None,
                 auction_round: float = HOUR,
                 auction_window: float = 2 * HOUR,
                 idle_discount: float = 0.25,
                 gis_ttl: float = 600.0,
                 heartbeat_interval: float = 300.0,
                 gis_suspect_after: int = 2,
                 churn_mean_uptime_h: float = 8.0,
                 churn_mean_downtime_h: float = 2.0,
                 churn_min_sites: int = 1,
                 churn_rebate: float = 0.25,
                 release_fee: float = 0.0,
                 resale: bool = False,
                 ask_fraction: float = 0.5,
                 discovery_gain: float = 0.0,
                 discovery_band: float = 0.5,
                 wire: str = "direct",
                 tracer=None):
        self.seed = seed
        # optional telemetry.Tracer: when set, every subsystem below is
        # bound to it (spans, instants, registry metrics); when None —
        # the default — no instrumentation site in the market fires
        self.tracer = tracer
        self._snap_tick = 0
        self.sim = Simulator()
        self.directory = ResourceDirectory()
        for spec in (specs if specs is not None
                     else gusto_like_testbed(n_machines, seed=seed)):
            self.directory.register(spec)
        self.schedules: Dict[str, PriceSchedule] = {
            name: PriceSchedule(self.directory.spec(name),
                                demand_elasticity=demand_elasticity,
                                spot_amplitude=spot_amplitude,
                                discovery_gain=discovery_gain,
                                discovery_band=discovery_band)
            for name in self.directory.all_names()}
        # the producer side of the economy: every settlement lands in
        # the bank as the owning domain's revenue
        self.bank = GridBank()
        if tracer is not None:
            self.bank.bind_telemetry(tracer)
        # one trade server per administrative domain, federated — the
        # cross-domain price board brokers arbitrage over.  Kwargs kept
        # so a site rejoining after churn gets an identical fresh server.
        self._server_kw = dict(
            max_reservations_per_user=max_reservations_per_user,
            bank=self.bank)
        self.trade = TradeFederation.from_directory(
            self.directory, self.schedules, **self._server_kw)
        # wire="loopback" re-plumbs every cross-domain call through the
        # protocol codec (repro.core.transport) — same objects, same
        # clock, byte-identical reports; the differential the real
        # multi-process deployment is certified against
        if wire not in ("direct", "loopback"):
            raise ValueError(f"wire must be 'direct' or 'loopback', "
                             f"got {wire!r}")
        self.wire = wire
        if wire == "loopback":
            from repro.core.transport import wrap_federation_loopback
            self.trade = wrap_federation_loopback(self.trade)
        # realized-trade price log: clearing rounds and resale fills
        # append here; schedules with discovery_gain > 0 learn from the
        # clearing rounds (fills are user-to-user and don't nudge)
        self.history = ClearingHistory()
        self.auction_house = AuctionHouse(
            self.trade, round_interval=auction_round,
            window=auction_window, idle_discount=idle_discount,
            history=self.history)
        if tracer is not None:
            self.auction_house.bind_telemetry(tracer)
        # secondary capacity market: with release_fee > 0 idle windows
        # handed back cost their holder the commitment fee; with resale
        # they can be listed and transferred to rival brokers instead
        self.secondary: Optional[SecondaryMarket] = None
        if resale or release_fee > 0.0:
            self.secondary = SecondaryMarket(
                self.trade, self.bank, release_fee=release_fee,
                resale=resale, ask_fraction=ask_fraction,
                history=self.history)
            if tracer is not None:
                self.secondary.bind_telemetry(tracer)
            if resale:
                for server in self.trade.servers.values():
                    server.secondary = self.secondary
        # the information layer: brokers discover through this, never by
        # reading the directory — so what they know is heartbeat-stale
        # and TTL-cached, and membership can churn under them
        self.gis_ttl = gis_ttl
        self.gis = GridInformationService(
            self.directory, heartbeat_interval=heartbeat_interval,
            suspect_after=gis_suspect_after,
            price_fn=lambda name, t: self.trade.forward_quote(name, t))
        if tracer is not None:
            self.gis.bind_telemetry(tracer)
        for name in self.directory.all_names():
            self.gis.register(self.directory.spec(name), 0.0)
        for site, server in self.trade.servers.items():
            self.gis.register_trade_server(site, server)
        self.churn_mean_uptime_h = churn_mean_uptime_h
        self.churn_mean_downtime_h = churn_mean_downtime_h
        self.churn_min_sites = churn_min_sites
        self.churn_rebate = churn_rebate
        self.churn: Optional[ChurnProcess] = None
        self.churn_trace: List[Tuple[float, str, str]] = []
        self.evictions = 0
        self.refunds = 0.0
        self.dispatch_latency = dispatch_latency
        self.noise_sigma = noise_sigma
        self.users: List[MarketUser] = []
        self.engines: List[NimrodG] = []
        self.price_trace: List[Tuple[float, float]] = []
        self._gis_handle = None
        self._auction_handle = None

    # ------------------------------------------------------------------
    def add_user(self, user: MarketUser,
                 sched_cfg: Optional[SchedulerConfig] = None) -> NimrodG:
        if any(u.name == user.name for u in self.users):
            raise ValueError(f"user {user.name!r} already in market")
        executor = SimulatedExecutor(
            self.sim, self.directory,
            seed=f"{self.seed}:{user.name}",
            noise_sigma=self.noise_sigma,
            dispatch_latency=self.dispatch_latency)
        dispatcher = Dispatcher(executor, self.directory)
        jobs = [JobSpec(job_id=f"{user.name}:j{i:05d}", experiment=user.name,
                        point={"i": i}, steps=(),
                        est_seconds_base=user.est_seconds)
                for i in range(user.n_jobs)]
        req = UserRequirements(deadline=user.deadline, budget=user.budget,
                               strategy=user.strategy, user=user.name)
        # strategies that negotiate (double auction + contracts) bring
        # their own bidder; the registry decides, not a string compare
        scls = strategy_class(user.strategy)
        broker = (scls.make_auction_broker(self.auction_house, user.name,
                                           secondary=self.secondary,
                                           bank=self.bank)
                  if scls.wants_auction_broker else None)
        engine = NimrodG(user.name, jobs, req, self.directory, self.trade,
                         dispatcher, sim=self.sim,
                         sched_cfg=sched_cfg or SchedulerConfig(),
                         seed=self.seed, stop_sim_when_done=False,
                         auction=broker, bank=self.bank,
                         secondary=(self.secondary
                                    if self.secondary is not None
                                    and self.secondary.resale else None),
                         gis=self.gis, gis_ttl=self.gis_ttl,
                         history=self.history, tracer=self.tracer)
        if self.secondary is not None:
            self.secondary.register_user(user.name, engine.ledger)
        self.users.append(user)
        self.engines.append(engine)
        return engine

    def _engine_for(self, user: str) -> Optional[NimrodG]:
        for u, e in zip(self.users, self.engines):
            if u.name == user:
                return e
        return None

    # ------------------------------------------------------------------
    # membership churn: whole sites leave and rejoin mid-run
    # ------------------------------------------------------------------
    def _site_leaves(self, site: str, rejoin_at: float) -> bool:
        if site not in self.trade.servers:
            return False             # already gone (shouldn't happen)
        if len(self.trade.servers) - 1 < self.churn_min_sites:
            return False             # veto: never empty the grid
        t = self.sim.now
        # 1. the machines vanish: down + departed, ETA published, and
        #    the GIS registration is withdrawn (brokers' cached views
        #    keep advertising them until their TTL lapses)
        names = self.directory.site_resources(site)
        for name in names:
            st = self.directory.status(name)
            st.departed = True
            st.set_up(False)
            st.next_transition = rejoin_at
            self.gis.deregister(name, t)
        # 2. in-flight work fails over NOW — requeued without burning
        #    an attempt, commitments refunded by each engine's handler
        evicted_before = self.evictions
        for name in names:
            for engine in self.engines:
                self.evictions += engine.dispatcher.executor.interrupt(name)
        if self.tracer is not None and self.evictions > evicted_before:
            self.tracer.instant(t, f"site:{site}", "churn", "eviction",
                                site=site,
                                jobs=self.evictions - evicted_before)
        # 3. live contracts on the dying domain are voided; the owner
        #    pays each holder a breach rebate through the bank (the
        #    consumer's ledger is credited the same amount: the books
        #    still reconcile to the cent)
        for user, c, remaining in self.auction_house.remove_site(site, t):
            holders: Dict[int, str] = {}
            if self.secondary is not None:
                # a listing over a voided reservation dies with it, fee-
                # free and at void time (never rediscovered post-expiry
                # as "unsold" — the breach rebate settles this loss);
                # and a window that was RESOLD belongs to its buyer now,
                # so the rebate for that slice must follow it
                for rid in c.reservation_ids:
                    self.secondary.drop(rid, t)
                    buyer = self.secondary.buyer_of(rid)
                    if buyer is not None and buyer != user:
                        holders[rid] = buyer
            if not holders:
                self._pay_rebate(user, site, c.resource, t,
                                 self.churn_rebate * remaining)
                continue
            # per-window split: each reservation carries an equal share
            # of the contract's remaining value (max_commitment is
            # price x chips x slots x left — one slot each)
            per_rid = remaining / max(len(c.reservation_ids), 1)
            for rid in c.reservation_ids:
                self._pay_rebate(holders.get(rid, user), site, c.resource,
                                 t, self.churn_rebate * per_rid)
        # 4. the domain's trade server leaves the federation (it stays
        #    behind as a read-only price board for stale views)
        self.trade.remove_server(site)
        self.gis.deregister_trade_server(site)
        self.churn_trace.append((t, "leave", site))
        if self.tracer is not None:
            self.tracer.instant(t, f"site:{site}", "churn", "site_leave",
                                site=site, rejoin_at=rejoin_at,
                                resources=len(names))
        return True

    def _pay_rebate(self, user: str, site: str, resource: str, t: float,
                    amt: float) -> None:
        """Breach rebate for one voided window, credited to whoever
        holds it (the contract's broker, or the buyer of a resold
        reservation) — ledger and bank move together, so the books
        still reconcile to the cent."""
        engine = self._engine_for(user)
        if amt > 0.0 and engine is not None:
            engine.ledger.settle(0.0, -amt)
            self.bank.record(t=t, user=user, owner=site,
                             resource=resource, amount=-amt,
                             kind="refund")
            self.refunds += amt

    def drain_site(self, site: str) -> bool:
        """Steering: force ``site`` out of the grid NOW and keep it out
        (rejoin ETA published as ``inf`` — unlike churn, nothing
        schedules a return).  Same departure semantics as a churn leave:
        in-flight jobs fail over, live contracts are voided with breach
        rebates, the domain's trade server leaves the federation.
        Returns False when the drain was vetoed (the site is already
        gone, or removing it would empty the grid below
        ``churn_min_sites``).  The ``ExperimentMonitor`` records a
        ``steer`` instant around this call."""
        return self._site_leaves(site, rejoin_at=math.inf)

    def _site_joins(self, site: str) -> None:
        t = self.sim.now
        # fresh trade server — the old book died with the old site
        names = self.directory.site_resources(site)
        server = TradeServer(self.directory,
                             {n: self.schedules[n] for n in names},
                             site=site, **self._server_kw)
        if self.secondary is not None and self.secondary.resale:
            server.secondary = self.secondary
        self.trade.add_server(site, server)
        # hand the auction house whatever the federation now fronts the
        # site with (in wire mode add_server wrapped it in a proxy)
        self.auction_house.add_site(site, self.trade.servers[site])
        self.gis.register_trade_server(site, self.trade.servers[site])
        for name in names:
            st = self.directory.status(name)
            st.departed = False
            st.set_up(True)
            st.next_transition = math.inf
            self.gis.register(self.directory.spec(name), t)
        self.churn_trace.append((t, "join", site))
        if self.tracer is not None:
            self.tracer.instant(t, f"site:{site}", "churn", "site_join",
                                site=site, resources=len(names))

    # ------------------------------------------------------------------
    def mean_quote(self, t: float) -> float:
        names = self.directory.all_names()
        if not names:
            return 0.0
        return sum(self.trade.quote(n, t) for n in names) / len(names)

    def _watch(self, sample_interval: float, horizon: float) -> None:
        t = self.sim.now
        self.price_trace.append((t, self.mean_quote(t)))
        if self.tracer is not None:
            # the price signal samples every tick; the full registry
            # snapshot (a few dozen counter events each) every 4th —
            # metrics move slowly against the watch cadence and the
            # run-end snapshot always lands the final values
            self.tracer.counter(t, "market", "price.mean_quote",
                                self.price_trace[-1][1])
            if self._snap_tick % 4 == 0:
                self.tracer.snapshot_counters(t)
            self._snap_tick += 1
        if self.secondary is not None:
            # housekeeping on the sim clock: expire unsold listings
            # (charging their commitment fees) and drop dangling ones
            self.secondary.sweep(t)
        if all(e.finished for e in self.engines):
            # nobody is trading anymore: the heartbeat pump and clearing
            # rounds leave the heap with the brokers, then the clock stops
            for handle in (self._gis_handle, self._auction_handle):
                if handle is not None:
                    handle.cancel()
            self.sim.stop()
            return
        if t + sample_interval <= horizon:
            self.sim.after(sample_interval,
                           lambda: self._watch(sample_interval, horizon))

    def run(self, *, failures: bool = False, churn: bool = False,
            horizon: Optional[float] = None,
            sample_interval: float = 600.0) -> MarketReport:
        if not self.engines:
            raise ValueError("no users in the market — add_user() first")
        if horizon is None:
            horizon = max(u.deadline for u in self.users) * 1.5 + 8 * HOUR
        self._gis_handle = self.gis.start(self.sim, until=horizon)
        wall0 = time.perf_counter() if self.tracer is not None else 0.0
        if failures:
            fp = FailureProcess(self.sim, self.directory, seed=self.seed,
                                tracer=self.tracer)
            for name in self.directory.all_names():
                fp.install(name)
        if churn:
            self.churn = ChurnProcess(
                self.sim, self.directory, seed=self.seed,
                mean_uptime_hours=self.churn_mean_uptime_h,
                mean_downtime_hours=self.churn_mean_downtime_h,
                on_leave=self._site_leaves, on_join=self._site_joins)
            for site in self.directory.sites():
                self.churn.install(site)
        if any(e.auction is not None for e in self.engines):
            self._auction_handle = self.auction_house.start(self.sim)
        for engine in self.engines:
            self.sim.after(0.0, engine.tick)
        self.sim.after(0.0, lambda: self._watch(sample_interval, horizon))
        self.sim.run(until=horizon)
        for engine in self.engines:
            if not engine.finished:
                engine.finish(stall="horizon_reached")
        if self.secondary is not None:
            # close the resale book: whatever never sold pays its fee
            # now, and the reports re-read the ledgers so late fees and
            # lump refunds show up in each user's final spend
            self.secondary.finalize(self.sim.now)
            for engine in self.engines:
                engine.report.total_cost = engine.ledger.settled
                engine.report.within_budget = (
                    engine.ledger.settled <= engine.req.budget + 1e-6)
        if self.tracer is not None:
            m = self.tracer.metrics
            m.gauge("market.sim_events").set(float(self.sim.events))
            # final registry snapshot BEFORE the wall-derived gauges are
            # registered: everything in the event stream (and hence the
            # JSONL export) stays deterministic; throughput lands only
            # in the registry, i.e. the Chrome export's otherData
            self.tracer.snapshot_counters(self.sim.now)
            wall = max(time.perf_counter() - wall0, 1e-9)
            m.gauge("market.events_per_sec", unit="ev/s").set(
                self.sim.events / wall)
            m.gauge("market.wall_seconds", unit="s").set(wall)
        return self._report()

    # ------------------------------------------------------------------
    def _report(self) -> MarketReport:
        outcomes = []
        for user, engine in zip(self.users, self.engines):
            rep = engine.report
            outcomes.append(UserOutcome(
                user=user.name, strategy=user.strategy,
                n_jobs=rep.n_jobs, n_done=rep.n_done,
                completion_time=rep.completion_time,
                spent=rep.total_cost, budget=user.budget,
                met_deadline=rep.met_deadline,
                within_budget=rep.within_budget,
                requeues=rep.requeues,
                slot_races_lost=rep.slot_races_lost,
                peak_allocation=rep.peak_allocation,
                stall_reason=rep.stall_reason,
                contracts_won=rep.contracts_won,
                resource_losses=rep.resource_losses))
        total_jobs = sum(o.n_jobs for o in outcomes)
        total_done = sum(o.n_done for o in outcomes)
        met = sum(1 for o in outcomes if o.met_deadline)
        return MarketReport(
            seed=self.seed, n_users=len(outcomes),
            n_resources=len(self.directory.all_names()),
            outcomes=outcomes, total_jobs=total_jobs, total_done=total_done,
            total_spent=sum(o.spent for o in outcomes),
            slot_races_lost=sum(o.slot_races_lost for o in outcomes),
            deadline_met_frac=met / max(len(outcomes), 1),
            price_trace=list(self.price_trace),
            contracts_struck=len(self.auction_house.contracts),
            owner_revenue={o: self.bank.owner_revenue(o)
                           for o in self.bank.owners()},
            resource_losses=sum(o.resource_losses for o in outcomes),
            evictions=self.evictions,
            refunds=self.refunds,
            churn_trace=list(self.churn_trace),
            gis_refreshes=sum(e.gis_client.refreshes for e in self.engines
                              if e.gis_client is not None),
            resale_enabled=(self.secondary is not None
                            and self.secondary.resale),
            resales=(len(self.secondary.fills)
                     if self.secondary is not None else 0),
            resale_volume=(self.secondary.resale_volume
                           if self.secondary is not None else 0.0),
            wasted_spend=(self.secondary.wasted_spend
                          if self.secondary is not None else 0.0))


# ---------------------------------------------------------------------------
def standard_market(n_users: int, *, n_machines: int = 20, seed: int = 0,
                    deadline_h: float = 12.0, budget: float = 5_000.0,
                    n_jobs: int = 40, est_seconds: float = 1800.0,
                    strategies: Sequence[str] = ("cost", "time",
                                                 "conservative"),
                    demand_elasticity: float = 0.5,
                    dispatch_latency: float = 1.0,
                    sched_cfg: Optional[SchedulerConfig] = None,
                    **market_kw) -> Marketplace:
    """Canonical N-user market: strategies round-robin over the mix,
    deadlines/budgets slightly staggered so brokers are heterogeneous but
    everything stays deterministic in (n_users, seed).  Extra keywords
    (``gis_ttl=``, ``churn_mean_uptime_h=``, ...) pass through to
    ``Marketplace``; ``sched_cfg`` (e.g. ``timeline_stride`` for big
    sweeps) is applied to every broker."""
    market = Marketplace(n_machines=n_machines, seed=seed,
                         demand_elasticity=demand_elasticity,
                         dispatch_latency=dispatch_latency,
                         **market_kw)
    for i in range(n_users):
        market.add_user(MarketUser(
            name=f"user{i:02d}",
            deadline=(deadline_h + 2.0 * (i % 3)) * HOUR,
            budget=budget * (1.0 + 0.25 * (i % 4)),
            strategy=strategies[i % len(strategies)],
            n_jobs=n_jobs,
            est_seconds=est_seconds), sched_cfg=sched_cfg)
    return market


def mixed_auction_market(n_users: int, **kw) -> Marketplace:
    """``standard_market`` with auction brokers in the mix: every other
    user negotiates (double auction / contracts), the rest buy at the
    posted price — the head-to-head the GRACE papers call for."""
    kw.setdefault("strategies", ("auction", "cost", "auction", "time",
                                 "auction", "conservative"))
    return standard_market(n_users, **kw)
