"""Batched quote evaluation: one float64 component matrix per tick,
shared by every broker trading on the same server/federation.

The scalar path prices one (resource, user, t) query at a time:
``TradeServer.quote`` walks schedule -> peak window -> spot curve ->
demand premium per call, and N brokers repeat the identical walk M
times per tick.  At the 100k–1M job tier that walk *is* the simulator
(see BENCH_scale.json).  The ``QuoteBoard`` assembles, once per
distinct sim time, a ``(resources x price-components)`` float64 matrix

    column 0: posted base price (after auction price discovery drift)
    column 1: peak-hours multiplier
    column 2: spot-curve factor
    column 3: demand premium (queue utilization x elasticity)

and reduces it left-to-right, so every broker's quote at that t is one
array lookup.  Rows are re-validated against the same stamps the scalar
memo keys on — ``ResourceStatus.version`` (slot churn moves the demand
premium) and ``PriceSchedule.version`` (price discovery moves the
base) — and recomputed individually when a stamp moved within a tick.

Bit-exactness contract (what keeps golden runs byte-identical):

* every factor is produced by the same left-associated multiply chain
  the scalar code uses — ``((base * peak) * spot) * demand`` — and
  numpy elementwise float64 ops round identically to CPython floats;
* the spot factor keeps calling ``math.sin`` per row (numpy's sin may
  differ in the last ulp), so transcendentals never go through numpy;
* a per-user factor or a live reservation book drops the row back to
  the scalar path (``None`` / delegated call) — the board only serves
  the stampable, user-agnostic part of the price.

When numpy is unavailable the board refuses to attach and every caller
falls back to the scalar memo path unchanged.
"""
from __future__ import annotations

import math
from typing import List, Optional

try:
    import numpy as np
except ImportError:          # pragma: no cover - numpy is a CI dep
    np = None

HOUR = 3600.0
TWO_PI = 2.0 * math.pi


class QuoteBoard:
    """Shared per-(server|federation) batched quote matrix.

    Attach with :meth:`attach`; all engines on the same ``trade``
    object share one instance, so the matrix is built once per distinct
    sim time no matter how many brokers query it.
    """

    def __init__(self, trade, directory):
        self._trade = trade
        self._directory = directory
        self._t: Optional[float] = None
        self._mstamp = -1            # directory+federation membership
        self._single = not hasattr(trade, "server_for")
        self._names: List[str] = []
        self._index = {}
        self._rebuild()

    # -- attachment ----------------------------------------------------
    @classmethod
    def attach(cls, trade) -> Optional["QuoteBoard"]:
        """Get-or-create the board shared through ``trade``.  Returns
        ``None`` (callers use the scalar path) when numpy is missing or
        ``trade`` is not a stampable server/federation."""
        if np is None:
            return None
        # wire federations quote through protocol messages; the batched
        # board reads schedules/status objects directly, which do not
        # exist broker-side across a process boundary
        if not getattr(trade, "supports_board", True):
            return None
        board = getattr(trade, "_board", None)
        if board is not None:
            return board
        directory = getattr(trade, "directory", None)
        if directory is None or not hasattr(trade, "membership_version") \
                or not hasattr(directory, "membership_version"):
            return None
        board = cls(trade, directory)
        trade._board = board
        return board

    # -- (re)build -----------------------------------------------------
    def _rebuild(self) -> None:
        """Re-derive membership-dependent bindings: the row <-> resource
        mapping and each row's spec/status/schedule/server objects."""
        directory = self._directory
        trade = self._trade
        names = directory.all_names()
        self._names = names
        self._index = {n: i for i, n in enumerate(names)}
        self._specs = [directory.spec(n) for n in names]
        self._stats = [directory.status(n) for n in names]
        if self._single:
            self._scheds = [trade.schedules[n] for n in names]
            self._servers = [trade] * len(names)
        else:
            self._servers = [trade.server_for(n) for n in names]
            self._scheds = [s.schedules[n]
                            for s, n in zip(self._servers, names)]
        n = len(names)
        self._M = np.empty((n, 4), dtype=np.float64)
        self._slots = np.array([max(s.slots, 1) for s in self._specs],
                               dtype=np.float64)
        self._zero_slots = [i for i, s in enumerate(self._specs)
                            if s.slots <= 0]
        self._sver = [-1] * n
        self._schver = [-1] * n
        self._quote: List[float] = [0.0] * n
        self._pre: List[float] = [0.0] * n
        # clean-build skip state: the version-sum of every stamp the
        # matrix consumes, and the [lo, hi) sim-time window inside which
        # no row's peak-hours membership flips.  While both hold (and no
        # spot curve is live) the quote vector is t-invariant.
        self._vsum = -1
        self._win_lo = 0.0
        self._win_hi = -1.0
        self._amp_rows: List[int] = []
        # unique servers backing the rows (a federation maps many rows
        # to one server) — their book_versions stamp the bulk-dict cache
        seen = {}
        for s in self._servers:
            seen[id(s)] = s
        self._userv = list(seen.values())
        self._em = None              # (version-sum, {name: price}) or None
        self._mstamp = (directory.membership_version
                        + trade.membership_version)
        self._t = None

    def _build(self, t: float) -> None:
        """Assemble the component matrix for sim time ``t`` and reduce
        it into per-row quote (spot) and forward (no-demand) prices."""
        mstamp = (self._directory.membership_version
                  + self._trade.membership_version)
        if mstamp != self._mstamp:
            self._rebuild()
        scheds = self._scheds
        stats = self._stats
        vsum = 0
        for st in stats:
            vsum += st.version
        for sc in scheds:
            vsum += sc.version
        if (self._t is not None and vsum == self._vsum
                and not self._amp_rows and self._win_lo <= t < self._win_hi):
            # every stamped input is unchanged, no spot curve is live and
            # no peak-hours membership flips before _win_hi: the quote
            # vector is t-invariant here — restamp, keep the arrays
            self._t = t
            return
        M = self._M
        n = len(scheds)
        # column 0/3 inputs re-read on every full build: base_price
        # drifts under auction price discovery (stamped by
        # PriceSchedule.version); elasticity/amplitude/period are
        # treated as fixed between stamp movements — retuning them
        # mid-run requires bumping the schedule's version
        M[:, 0] = [sc.base_price for sc in scheds]
        phase = np.array([sc.phase for sc in scheds], dtype=np.float64)
        day = (t / HOUR + phase) % 24.0
        peakmult = np.array([sc.spec.peak_multiplier for sc in scheds],
                            dtype=np.float64)
        inwin = (day >= 8.0) & (day < 20.0)
        M[:, 1] = np.where(inwin, peakmult, 1.0)
        # spot column: math.sin per row for ulp-compat with the scalar
        # schedule; amplitude==0 rows (the default) skip the call
        M[:, 2] = 1.0
        amp_rows: List[int] = []
        for i in range(n):
            sc = scheds[i]
            if sc.spot_amplitude:
                amp_rows.append(i)
                M[i, 2] = 1.0 + sc.spot_amplitude * math.sin(
                    TWO_PI * (t + sc.phase * HOUR) / sc.spot_period)
        self._amp_rows = amp_rows
        running = np.array([st.running for st in stats], dtype=np.float64)
        util = np.minimum(1.0, np.maximum(0.0, running / self._slots))
        for i in self._zero_slots:
            util[i] = 1.0
        elast = np.array([sc.demand_elasticity for sc in scheds],
                         dtype=np.float64)
        M[:, 3] = 1.0 + elast * util
        pre = (M[:, 0] * M[:, 1]) * M[:, 2]
        quote = pre * M[:, 3]
        # .tolist() hands back exact CPython floats — np.float64 must
        # never leak into ledgers/journals (repr differs under numpy 2)
        self._pre = pre.tolist()
        self._quote = quote.tolist()
        for i in range(n):
            self._sver[i] = stats[i].version
            self._schver[i] = scheds[i].version
        # validity window: next 08:00/20:00 crossing over all rows (the
        # tiny margin keeps float drift in the crossing time conservative)
        if n:
            h8 = (8.0 - day) % 24.0
            h8[h8 == 0.0] = 24.0
            h20 = (20.0 - day) % 24.0
            h20[h20 == 0.0] = 24.0
            self._win_hi = t + float(min(h8.min(), h20.min())) * HOUR - 1e-6
        else:
            self._win_hi = math.inf
        self._win_lo = t
        self._vsum = vsum
        self._em = None
        self._t = t
        self._mstamp = mstamp

    def _recompute_row(self, i: int, t: float) -> None:
        """One row's stamp moved mid-tick (slot churn or price
        discovery): redo that row with the scalar multiply chain."""
        sc = self._scheds[i]
        st = self._stats[i]
        base = sc.base_price
        pre = (base * float(self._M[i, 1])) * float(self._M[i, 2])
        util = st.utilization(self._specs[i])
        demand = 1.0 + sc.demand_elasticity * max(0.0, min(1.0, util))
        self._M[i, 0] = base
        self._M[i, 3] = demand
        self._pre[i] = pre
        self._quote[i] = pre * demand
        self._sver[i] = st.version
        self._schver[i] = sc.version

    def _row(self, resource: str, t: float) -> int:
        """Row index serving a single-name query, or -1 for the scalar
        fallback.  Singles never trigger a matrix build: completion
        handlers price one resource at event times between ticks, and
        rebuilding every row for that one lookup costs more than the
        scalar walk — the bulk tick path (:meth:`effective_many`) is
        what assembles the matrix."""
        if t != self._t or (self._directory.membership_version
                            + self._trade.membership_version
                            != self._mstamp):
            return -1
        i = self._index.get(resource)
        if i is None:
            return -1
        if (self._stats[i].version != self._sver[i]
                or self._scheds[i].version != self._schver[i]):
            self._recompute_row(i, t)
        return i

    # -- queries (None => caller takes the scalar path) ----------------
    def quote(self, resource: str, user: str, t: float) -> Optional[float]:
        """Spot quote — ``trade.quote(resource, t, user)``."""
        i = self._row(resource, t)
        if i < 0 or self._scheds[i].user_factors:
            return None
        return self._quote[i]

    def effective(self, resource: str, user: str, t: float
                  ) -> Optional[float]:
        """Effective price — ``trade.effective_price(resource, user,
        t)``.  Rows whose server holds ANY live reservation delegate to
        the scalar book walk (which also prunes, exactly as before)."""
        i = self._row(resource, t)
        if i < 0 or self._scheds[i].user_factors:
            return None
        server = self._servers[i]
        if server.reservations:
            return server.effective_price(resource, user, t)
        return self._quote[i]

    def effective_many(self, names, user: str, t: float):
        """Effective prices for every resource in ``names`` at once —
        the tick-time ``{n: effective_price(n, user, t)}`` dict in one
        board pass (t/membership validated once, not per name).
        Returns ``None`` wholesale when any name is unknown or carries
        per-user factors: the caller then takes its scalar dictcomp.

        The full-board result is cached against the sum of every
        status/schedule/book version, so the N brokers ticking at one
        sim time share a single dict build (reservation-delegated rows
        are user-dependent and disable the cache).  Callers must treat
        the returned dict as read-only."""
        if t != self._t or (self._directory.membership_version
                            + self._trade.membership_version
                            != self._mstamp):
            self._build(t)
        stats, scheds = self._stats, self._scheds
        vs = 0
        for st in stats:
            vs += st.version
        for sc in scheds:
            vs += sc.version
        for s in self._userv:
            vs += s.book_version
        em = self._em
        names_all = self._names
        if em is not None and em[0] == vs:
            full = em[1]
        else:
            sver, schver = self._sver, self._schver
            quote, servers = self._quote, self._servers
            full = {}
            delegated = False
            for i, name in enumerate(names_all):
                if scheds[i].user_factors:
                    self._em = None
                    return None
                if (stats[i].version != sver[i]
                        or scheds[i].version != schver[i]):
                    self._recompute_row(i, t)
                server = servers[i]
                if server.reservations:
                    # live book: user- and prune-dependent — price it
                    # scalar and keep the result out of the shared cache
                    delegated = True
                    full[name] = server.effective_price(name, user, t)
                else:
                    full[name] = quote[i]
            self._em = None if delegated else (vs, full)
        if list(names) == names_all:
            return full
        out = {}
        for name in names:
            v = full.get(name)
            if v is None:
                return None
            out[name] = v
        return out

    def forward(self, resource: str, user: str, t: float
                ) -> Optional[float]:
        """Forward quote — the schedule with utilization pinned to 0."""
        i = self._row(resource, t)
        if i < 0 or self._scheds[i].user_factors:
            return None
        return self._pre[i]

    def server_of(self, resource: str):
        """The trade server owning ``resource`` (membership-checked),
        or ``None`` if unknown — callers use it to skip empty-book
        reservation scans without a ``server_for`` dict walk."""
        if (self._directory.membership_version
                + self._trade.membership_version != self._mstamp):
            self._rebuild()
        i = self._index.get(resource)
        return None if i is None else self._servers[i]
