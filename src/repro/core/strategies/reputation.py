"""``reputation`` — discount domains by observed churn/failure history.

Planning against a TTL-stale GIS view means cheap capacity on a flaky
domain is not actually cheap: dispatches burn, in-flight work gets
evicted, and voided contracts come back as breach refunds.  This
strategy prices that in.  Each resource's cost-per-job is marked up by
a risk premium built from three observations the broker already has:

* its own dispatch outcomes on the resource (``ResourceView.failures``
  vs completions — the paper's "historical information");
* how often its GIS client had to *suspect* the resource since the run
  started (burned dispatches on stale snapshots — churn seen from the
  information layer);
* the owning domain's breach record in the ``GridBank``: refunds paid
  back as a fraction of gross revenue (a domain that keeps voiding
  contracts is a domain that keeps leaving).

Selection is then the classic cost prefix over the risk-adjusted
ranking — so with no history (or outside a marketplace) it degrades to
exactly ``cost``.  The auction side-car gets the same signal: its bids
penalize flaky sites through ``AuctionBroker.site_penalty``.
"""
from __future__ import annotations

from typing import Set

from repro.core.strategies.base import (Strategy, StrategyContext,
                                        accumulate_rate, cost_per_job,
                                        register)


def domain_breach_ratio(bank, site: str) -> float:
    """Refunds the domain has paid back, as a fraction of its gross
    take (revenue before refunds netted out).  0 with no history."""
    if bank is None:
        return 0.0
    refunds = -bank.owner_kind_total(site, "refund")   # entries are < 0
    if refunds <= 0.0:
        return 0.0
    gross = bank.owner_revenue(site) + refunds
    return min(1.0, refunds / max(gross, 1e-9))


@register
class ReputationStrategy(Strategy):
    name = "reputation"
    wants_auction_broker = True
    description = "cost ranking marked up by churn/failure reputation"

    #: full risk (1.0) doubles a resource's effective cost-per-job
    risk_premium = 1.0
    #: each dispatch-time suspicion adds this much risk (capped at 1)
    suspicion_weight = 0.25

    def _risk(self, ctx: StrategyContext, name: str) -> float:
        view = ctx.views[name]
        fail = view.failures / (view.failures + view.completions + 1.0)
        burns = 0.0
        if ctx.gis_client is not None:
            count = ctx.gis_client.suspicion_count(name)
            burns = min(1.0, self.suspicion_weight * count)
        breach = domain_breach_ratio(ctx.bank, view.spec.site)
        return fail + burns + breach

    def select(self, ctx: StrategyContext) -> Set[str]:
        ranked = sorted(
            ctx.views,
            key=lambda n: (cost_per_job(ctx.views[n], ctx.prices[n])
                           * (1.0 + self.risk_premium * self._risk(ctx, n)),
                           n not in ctx.held, n))
        return accumulate_rate(ranked, ctx.views, ctx.needed_rate,
                               ctx.rates)

    @classmethod
    def make_auction_broker(cls, house, user, *, secondary=None, bank=None):
        from repro.core.auctions import AuctionBroker
        penalty = ((lambda site, t: domain_breach_ratio(bank, site))
                   if bank is not None else None)
        return AuctionBroker(house, user, secondary=secondary,
                             site_penalty=penalty)
