"""``cost`` — minimize G$ subject to the deadline (paper §3).

Cheapest resources per job first, just enough aggregate rate to hit the
deadline with the safety margin.  This is the original Nimrod/G cost
strategy, byte-for-byte: the canonical ranking is exactly the one the
advisor precomputes, and selection is the shared prefix accumulation.
"""
from __future__ import annotations

from typing import Set

from repro.core.strategies.base import (Strategy, StrategyContext,
                                        accumulate_rate, register)


@register
class CostStrategy(Strategy):
    name = "cost"
    legacy = True
    description = "cheapest-per-job prefix meeting the deadline rate"

    def select(self, ctx: StrategyContext) -> Set[str]:
        return accumulate_rate(ctx.ranked, ctx.views, ctx.needed_rate,
                               ctx.rates)
