"""``auction`` — cost-style allocation plus a negotiation side-car.

The allocation loop is identical to ``cost`` (negotiated contracts
enter it through the effective prices and the ``held`` tie-break); what
changes is the wiring: ``Marketplace.add_user`` attaches an
``AuctionBroker`` that bids in the double auction and sheds idle
contracted windows to the secondary market.
"""
from __future__ import annotations

from repro.core.strategies.base import register
from repro.core.strategies.cost import CostStrategy


@register
class AuctionStrategy(CostStrategy):
    name = "auction"
    legacy = False
    wants_auction_broker = True
    description = "cost selection + sealed bids into the double auction"
