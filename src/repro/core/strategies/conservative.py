"""``conservative`` — cost selection plus a per-job budget share guard.

Like ``cost``, but before every dispatch it guarantees each unfinished
job an equal share of the remaining budget: the broker never lets one
expensive dispatch starve the backlog.  Original Nimrod/G behaviour,
byte-for-byte.
"""
from __future__ import annotations

from repro.core.strategies.base import register
from repro.core.strategies.cost import CostStrategy


@register
class ConservativeStrategy(CostStrategy):
    name = "conservative"
    legacy = True
    description = "cost selection; every job keeps its budget share"

    def may_commit(self, est_cost, remaining_jobs, ledger) -> bool:
        if not ledger.can_commit(est_cost):
            return False
        if remaining_jobs > 0:
            share = ledger.remaining / remaining_jobs
            return est_cost <= share + 1e-9
        return True
