"""Pluggable broker strategies (see ``base`` for the registry API).

Importing this package populates the registry with the built-in zoo:
the three legacy Nimrod/G policies (``cost`` / ``time`` /
``conservative``), the negotiating ``auction`` profile, and the
economy-aware strategies built on the PR 2–5 machinery
(``reputation``, ``adaptive``, ``scavenger``).
"""
from repro.core.strategies.base import (Strategy, StrategyContext,
                                        accumulate_rate,
                                        available_strategies, cost_per_job,
                                        create, register, strategy_class,
                                        unregister)
# registration side-effects: each module @registers its class on import
from repro.core.strategies import adaptive as _adaptive      # noqa: F401
from repro.core.strategies import auction as _auction        # noqa: F401
from repro.core.strategies import conservative as _cons      # noqa: F401
from repro.core.strategies import cost as _cost              # noqa: F401
from repro.core.strategies import reputation as _reputation  # noqa: F401
from repro.core.strategies import scavenger as _scavenger    # noqa: F401
from repro.core.strategies import time_opt as _time          # noqa: F401

__all__ = [
    "Strategy", "StrategyContext", "accumulate_rate",
    "available_strategies", "cost_per_job", "create", "register",
    "strategy_class", "unregister",
]
