"""``time`` — minimize completion time subject to the budget (paper §3).

Greedy in cheapest-per-job order: keep adding resources while the
rate-weighted projected spend for the remaining backlog still fits the
remaining budget.  Original Nimrod/G time strategy, byte-for-byte.
"""
from __future__ import annotations

import math
from typing import Set

from repro.core.strategies.base import (Strategy, StrategyContext,
                                        cost_per_job, register)


@register
class TimeStrategy(Strategy):
    name = "time"
    legacy = True
    description = "maximal rate whose projected spend fits the budget"

    def select(self, ctx: StrategyContext) -> Set[str]:
        chosen: Set[str] = set()
        rate = 0.0
        spend_rate = 0.0             # G$/s of the allocation
        rates, cpj = ctx.rates, ctx.cpj
        for name in ctx.ranked:
            r = rates[name] if rates is not None else ctx.views[name].rate()
            if r <= 0:
                continue             # fully contended: no free capacity
            c = (cpj[name] if cpj is not None
                 else cost_per_job(ctx.views[name], ctx.prices[name]))
            new_rate = rate + r
            new_spend = spend_rate + r * c
            projected = ctx.remaining_jobs * (new_spend / new_rate) \
                if new_rate > 0 else math.inf
            if projected <= ctx.ledger.remaining + 1e-9:
                chosen.add(name)
                rate, spend_rate = new_rate, new_spend
        return chosen
