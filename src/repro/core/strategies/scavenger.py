"""``scavenger`` — drain ``SecondaryMarket`` listings before paying
spot.

When resale is on, rival brokers list contracted windows they no longer
need; the engine's dispatch path already buys a listing whenever it is
the cheapest way onto an allocated resource.  This strategy steers the
*allocation* there too: resources with a live resale listing (excluding
the broker's own) rank ahead of everything else, cheapest-per-job
within each group, and selection is the classic cost prefix over that
ordering.  Listed capacity is someone's sunk commitment fee — buying it
recycles paid-for slot-hours instead of minting fresh spot demand.
Without a resale book (or with an empty one) the ranking collapses to
the canonical order and the strategy degrades to exactly ``cost``.
"""
from __future__ import annotations

from typing import Set

from repro.core.strategies.base import (Strategy, StrategyContext,
                                        accumulate_rate, cost_per_job,
                                        register)


@register
class ScavengerStrategy(Strategy):
    name = "scavenger"
    description = "resale listings first, spot capacity only after"

    def select(self, ctx: StrategyContext) -> Set[str]:
        def has_listing(name: str) -> bool:
            if ctx.secondary is None:
                return False
            return ctx.secondary.best_rate(name, ctx.t,
                                           exclude=ctx.req.user) is not None

        ranked = sorted(
            ctx.views,
            key=lambda n: (not has_listing(n),
                           cost_per_job(ctx.views[n], ctx.prices[n]),
                           n not in ctx.held, n))
        return accumulate_rate(ranked, ctx.views, ctx.needed_rate,
                               ctx.rates)
