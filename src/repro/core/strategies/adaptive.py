"""``adaptive`` — fit price curves from ``ClearingHistory`` to time
purchases.

Posted quotes are the owner's ask; the ``ClearingHistory`` records what
capacity actually *traded* for (auction rounds, resale fills).  This
strategy fits a least-squares line through each resource's recent
clearings and treats the extrapolated value at ``t`` as the fair price.
Resources currently quoting at or under ``patience`` times fair are
bought first (in the canonical cheap-per-job order); overpriced ones
are deferred — but only as long as the fairly-priced pool covers the
needed rate.  Deadline pressure always wins: once the fair pool runs
out, the deferred resources are bought in rank order, so selection
stays weakly monotone in the needed rate (larger targets only extend
the walk).  With no clearings yet (or outside a marketplace) every
resource is "fair" and the strategy degrades to exactly ``cost``.
"""
from __future__ import annotations

from typing import Optional, Set

from repro.core.strategies.base import Strategy, StrategyContext, register


@register
class AdaptiveStrategy(Strategy):
    name = "adaptive"
    description = "defer buys quoting above the fitted clearing trend"

    #: pay up to this multiple of the fitted clearing price before
    #: calling a quote overpriced
    patience = 1.05
    #: clearings per resource the fit looks back over
    window = 8

    def fair_price(self, ctx: StrategyContext, name: str
                   ) -> Optional[float]:
        """Extrapolated clearing price at ``ctx.t`` (None = no data)."""
        if ctx.history is None:
            return None
        hist = ctx.history.for_resource(name)[-self.window:]
        if not hist:
            return None
        if len(hist) == 1:
            return hist[0].price
        t0 = hist[0].t
        xs = [c.t - t0 for c in hist]
        ys = [c.price for c in hist]
        n = float(len(xs))
        mx, my = sum(xs) / n, sum(ys) / n
        var = sum((x - mx) ** 2 for x in xs)
        if var <= 1e-12:                       # all clearings at one t
            return my
        slope = sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / var
        pred = my + slope * ((ctx.t - t0) - mx)
        # bound the extrapolation by the observed band: a two-point
        # trend must not predict free (or absurd) capacity
        lo, hi = min(ys), max(ys)
        return min(max(pred, 0.5 * lo), 2.0 * hi)

    def select(self, ctx: StrategyContext) -> Set[str]:
        fair, deferred = [], []
        for name in ctx.ranked:
            pred = self.fair_price(ctx, name)
            if (pred is None
                    or ctx.prices[name] <= self.patience * pred + 1e-12):
                fair.append(name)
            else:
                deferred.append(name)
        chosen: Set[str] = set()
        acc = 0.0
        rates = ctx.rates
        for name in fair + deferred:           # patience yields to need
            if acc >= ctx.needed_rate:
                break
            r = rates[name] if rates is not None else ctx.views[name].rate()
            if r <= 0:
                continue
            chosen.add(name)
            acc += r
        return chosen
