"""Strategy plug-in seam: the paper's "a user could build an
alternative scheduler by using these APIs", made literal.

A ``Strategy`` owns the two policy decisions the broker delegates:

* ``select(ctx)``   — which resources to hold this tick, given the
  advisor's pre-computed market context;
* ``may_commit``    — the per-dispatch budget guard (the conservative
  policy's per-job share check lives here, not in the engine).

``StrategyContext`` packages everything ``ScheduleAdvisor.decide``
knows at re-plan time: the live (non-suspected) views, effective
prices, the canonical cheapest-per-job ranking, the backlog, the
ledger — plus the economy hooks PRs 2–5 added (resale book, bank,
clearing history, GIS client) when the broker runs inside a
marketplace.  The hooks are ``None`` on the bare single-user path, so
every strategy must degrade gracefully without them.

The registry maps ``UserRequirements.strategy`` strings to classes.
Registering a strategy is all it takes to enter the conformance suite
(``tests/test_strategies.py``) and the tournament bench — coverage by
registration, not by edit.
"""
from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Dict, List, Optional, Sequence, Set,
                    Type)

if TYPE_CHECKING:  # import-time cycle: scheduler imports this package
    from repro.core.economy import BudgetLedger, UserRequirements
    from repro.core.scheduler import ResourceView, SchedulerConfig

HOUR = 3600.0


def cost_per_job(view: "ResourceView", price_chip_hour: float) -> float:
    """G$ one job costs on ``view`` at ``price_chip_hour`` — the unit
    every ranking below is denominated in."""
    return price_chip_hour * view.spec.chips * view.est_job_seconds / HOUR


@dataclasses.dataclass
class StrategyContext:
    """Everything a strategy may consult for one ``select`` call."""
    t: float
    req: "UserRequirements"
    cfg: "SchedulerConfig"
    views: Dict[str, "ResourceView"]     # live (non-suspected) only
    prices: Dict[str, float]             # effective chip-hour prices
    remaining_jobs: int
    ledger: "BudgetLedger"
    needed_rate: float                   # safety-margined jobs/s target
    current: Set[str]                    # allocation entering the tick
    held: Set[str]                       # contracted (pre-paid) resources
    ranked: List[str]                    # canonical cheapest-per-job order
    # economy hooks (None outside a marketplace / when the leg is off)
    secondary: Optional[object] = None   # SecondaryMarket
    bank: Optional[object] = None        # GridBank
    history: Optional[object] = None     # ClearingHistory
    gis_client: Optional[object] = None  # GISClient
    # advisor-precomputed per-name maps over ``views`` (None when a
    # caller builds a context by hand): ``rates[n] == views[n].rate()``
    # and ``cpj[n] == cost_per_job(views[n], prices[n])``, bit-exactly —
    # strategies use them to skip re-deriving the same floats
    rates: Optional[Dict[str, float]] = None
    cpj: Optional[Dict[str, float]] = None

    def rank(self, key) -> List[str]:
        """Re-rank the live views by a strategy-specific key.  The key
        gets ``(ctx, name)``; ties MUST be broken deterministically, so
        the name is always appended as the last sort component."""
        return sorted(self.views, key=lambda n: (key(self, n), n))


class Strategy:
    """Base policy: subclasses override ``select`` (and optionally
    ``may_commit`` / the auction-broker factory) and register under a
    unique ``name`` — the string users put in
    ``UserRequirements.strategy``."""

    name: str = ""
    #: the three original Nimrod/G policies, guarded byte-identical by
    #: tests/test_golden_equivalence.py
    legacy: bool = False
    #: whether Marketplace.add_user should wire an AuctionBroker so the
    #: engine also negotiates (double auction + contract-net)
    wants_auction_broker: bool = False
    description: str = ""

    def select(self, ctx: StrategyContext) -> Set[str]:
        """Return the resource names to hold this tick.  The advisor
        applies the ``min_resources`` floor afterwards — a strategy may
        legitimately return an empty set when nothing is worth buying."""
        raise NotImplementedError

    def may_commit(self, est_cost: float, remaining_jobs: int,
                   ledger: "BudgetLedger") -> bool:
        """Per-dispatch budget guard.  The ledger's ``can_commit`` is
        the hard wall every policy must respect; subclasses may only
        tighten it, never loosen it."""
        return ledger.can_commit(est_cost)

    @classmethod
    def make_auction_broker(cls, house, user: str, *, secondary=None,
                            bank=None):
        """Factory for the engine's negotiation side-car (only called
        when ``wants_auction_broker``).  The default is the plain
        truthful bidder; strategies can shape bids (e.g. reputation
        penalties) by overriding this."""
        from repro.core.auctions import AuctionBroker
        return AuctionBroker(house, user, secondary=secondary)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Strategy]] = {}


def register(cls: Type[Strategy]) -> Type[Strategy]:
    """Class decorator: add ``cls`` to the registry under ``cls.name``."""
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a non-empty .name")
    if cls.name in _REGISTRY and _REGISTRY[cls.name] is not cls:
        raise ValueError(f"strategy {cls.name!r} already registered "
                         f"by {_REGISTRY[cls.name].__name__}")
    _REGISTRY[cls.name] = cls
    return cls


def unregister(name: str) -> None:
    """Remove a registry entry (tests registering throwaway strategies
    clean up with this — production code never unregisters)."""
    _REGISTRY.pop(name, None)


def strategy_class(name: str) -> Type[Strategy]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown strategy {name!r}; registered: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def create(name: str) -> Strategy:
    """Fresh instance per broker — strategies may keep per-broker state."""
    return strategy_class(name)()


def available_strategies() -> List[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# shared selection rules (the classic prefix accumulations)
# ---------------------------------------------------------------------------

def accumulate_rate(ranked: Sequence[str],
                    views: Dict[str, "ResourceView"],
                    needed: float,
                    rates: Optional[Dict[str, float]] = None) -> Set[str]:
    """Walk ``ranked`` accumulating free rate until ``needed`` is met —
    the cost-optimal rule, shared by every strategy that only changes
    the *ordering*.  Skipping zero-rate entries (fully contended) keeps
    the walk weakly monotone in ``needed``: a larger target can only
    extend the chosen prefix.  ``rates`` (when the advisor precomputed
    it) short-circuits the per-name ``rate()`` recomputation."""
    chosen: Set[str] = set()
    acc = 0.0
    for name in ranked:
        if acc >= needed:
            break
        r = rates[name] if rates is not None else views[name].rate()
        if r <= 0:
            continue             # fully contended: no free capacity
        chosen.add(name)
        acc += r
    return chosen
