"""Dispatcher + job wrapper (paper §2).

The dispatcher "initiates the execution of a task on the selected resource
as per the scheduler's instruction [and] periodically updates the status of
task execution to the parametric-engine".  The job wrapper "is responsible
for staging of application tasks and data; starting execution ... and
sending results back".

Two executors implement the same contract:

* ``SimulatedExecutor`` — runs the wrapper phases (stage-in, execute,
  stage-out) in virtual time on the DES, honoring resource failures.
* ``LocalExecutor``     — runs real Python payloads (e.g. jit'd train
  steps) on a thread pool; used by the end-to-end examples where the
  "grid" is this machine.

Closed clusters route staging through ``StagingProxy`` (paper §4's
master-node GASS proxy).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, Optional, Union

from repro.core.jobs import Job, JobStatus
from repro.core.resources import ResourceDirectory
from repro.core.simulator import Simulator, duration_model


class StagingProxy:
    """Master-node mediator for closed clusters: all stage traffic flows
    through it; it counts bytes (and in the DES costs 2x time, modeled in
    duration_model)."""

    def __init__(self):
        self.bytes_in = 0
        self.bytes_out = 0
        self.transfers = 0

    def stage(self, n_bytes: int, inbound: bool) -> None:
        self.transfers += 1
        if inbound:
            self.bytes_in += n_bytes
        else:
            self.bytes_out += n_bytes


# Reason string the executors report when a dispatch loses the race for
# the last free slot to a rival broker.  Distinct from a real failure: the
# resource is healthy, the job should simply requeue (no attempt burned,
# no suspicion cast on the resource).
SLOT_LOST = "slot contention: lost race for free slot"

# Reason reported when a whole site departs mid-run (churn) and takes its
# in-flight jobs with it.  Like every "resource ..." reason it is the
# machine's fault, not the job's: requeue without burning an attempt.
RESOURCE_DEPARTED = "resource departed: site left the grid"


def is_resource_fault(reason: str) -> bool:
    """True for failures caused by the resource dying or leaving (as
    opposed to the job's own payload failing).  A broker scheduling
    against a stale information-service view *will* dispatch to corpses;
    those burned dispatches requeue like ``SLOT_LOST`` — suspicion is
    cast on the resource, never an attempt charged to the job."""
    return reason.startswith("resource ")


@dataclasses.dataclass
class DispatchCallbacks:
    on_started: Callable[[Job], None]
    on_done: Callable[[Job, float], None]        # (job, exec_seconds)
    on_failed: Callable[[Job, str], None]        # (job, reason)
    on_blocked: Optional[Callable[[Job, str], None]] = None  # slot races

    def blocked(self, job: Job, reason: str) -> None:
        (self.on_blocked or self.on_failed)(job, reason)


class SimulatedExecutor:
    """Job-wrapper phases in virtual time, failure-aware.

    ``dispatch_latency`` models the WAN hop between a broker's decision
    and the remote queue actually granting the slot — with it non-zero,
    two brokers that decided in the same scheduling round genuinely race
    for the last slot and one of them loses (gets ``SLOT_LOST``)."""

    def __init__(self, sim: Simulator, directory: ResourceDirectory,
                 seed: Union[int, str] = 0, noise_sigma: float = 0.15,
                 dispatch_latency: float = 0.0):
        self.sim = sim
        self.directory = directory
        self.seed = seed
        self.noise_sigma = noise_sigma
        self.dispatch_latency = dispatch_latency
        self.proxy = StagingProxy()
        self.slot_races_lost = 0
        self._running: Dict[str, dict] = {}    # job_id -> {cancelled: bool}
        # independent per-resource count of slots this executor holds,
        # maintained at exactly the acquire/release sites.  The online
        # slot-accounting watchdog cross-checks it against the
        # directory's ``running`` book in O(1) per resource — a rogue
        # release moves one book but not the other
        self._held: Dict[str, int] = {}

    def submit(self, job: Job, resource: str, cb: DispatchCallbacks) -> None:
        # register the cancel token BEFORE the latency hop: a duplicate
        # killed while still in flight must never acquire a slot and run
        token = {"cancelled": False, "job": job, "cb": cb,
                 "resource": resource}
        self._running[job.job_id] = token
        if self.dispatch_latency > 0.0:
            self.sim.after(
                self.dispatch_latency,
                lambda: self._acquire_and_run(job, resource, cb, token))
        else:
            self._acquire_and_run(job, resource, cb, token)

    def _drop_token(self, job: Job, token: dict) -> None:
        if self._running.get(job.job_id) is token:
            del self._running[job.job_id]

    def _acquire_and_run(self, job: Job, resource: str,
                         cb: DispatchCallbacks, token: dict) -> None:
        if token["cancelled"]:          # killed while in the WAN hop
            self._drop_token(job, token)
            return
        spec = self.directory.spec(resource)
        st = self.directory.status(resource)
        if not st.up:
            self._drop_token(job, token)
            cb.on_failed(job, RESOURCE_DEPARTED if st.departed
                         else "resource unavailable at submit")
            return
        if not st.acquire(spec):
            self._drop_token(job, token)
            self.slot_races_lost += 1
            cb.blocked(job, SLOT_LOST)
            return
        job.slot_held = True
        self._held[resource] = self._held.get(resource, 0) + 1
        job.acquired_at = self.sim.now
        s_in, ex, s_out = duration_model(
            spec, job.spec.est_seconds_base, job.spec.stage_in_bytes,
            job.spec.stage_out_bytes, load=st.load,
            noise_sigma=self.noise_sigma,
            seed=(self.seed, job.job_id, job.attempt, resource))
        if spec.closed:
            self.proxy.stage(job.spec.stage_in_bytes, inbound=True)

        def _fail_if_down(phase_next: Callable[[], None], reason: str):
            def wrapped():
                if token["cancelled"]:
                    self._finish(job, spec.name, token)
                    return
                if not self.directory.status(resource).up:
                    self._finish(job, spec.name, token)
                    cb.on_failed(job, reason)
                    return
                phase_next()
            return wrapped

        def start_exec():
            cb.on_started(job)
            self.sim.after(ex, _fail_if_down(do_stage_out,
                                             "resource failed during run"))

        def do_stage_out():
            if spec.closed:
                self.proxy.stage(job.spec.stage_out_bytes, inbound=False)
            self.sim.after(s_out, _fail_if_down(finish,
                                                "resource failed staging out"))

        def finish():
            self._finish(job, spec.name, token)
            cb.on_done(job, ex)

        self.sim.after(s_in, _fail_if_down(start_exec,
                                           "resource failed staging in"))

    def _finish(self, job: Job, resource: str, token: dict) -> None:
        # idempotent AND token-gated: interrupt() may finish a job whose
        # phase timers are still in the heap, and the engine may have
        # redispatched the same job since — a late closure holding the
        # old token must neither pop the new token nor release the slot
        # the new dispatch acquired
        if self._running.get(job.job_id) is not token:
            return
        del self._running[job.job_id]
        if job.slot_held:
            job.slot_held = False
            self._held[resource] -= 1
            self.directory.status(resource).release()

    def cancel(self, job: Job) -> None:
        tok = self._running.get(job.job_id)
        if tok:
            tok["cancelled"] = True

    def interrupt(self, resource: str,
                  reason: str = RESOURCE_DEPARTED) -> int:
        """Fail over everything in flight on ``resource`` RIGHT NOW —
        a departing site does not wait for phase boundaries.  Slots are
        released, callbacks fire immediately (jobs still in the WAN hop
        included: their dispatch was racing toward a corpse), and the
        phase timers already in the heap become no-ops.  Returns the
        number of dispatches failed over."""
        victims = [tok for jid, tok in sorted(self._running.items())
                   if tok["resource"] == resource and not tok["cancelled"]]
        for tok in victims:
            tok["cancelled"] = True
            self._finish(tok["job"], resource, tok)
            tok["cb"].on_failed(tok["job"], reason)
        return len(victims)

    def estimate(self, job: Job, resource: str) -> float:
        spec = self.directory.spec(resource)
        s_in, ex, s_out = duration_model(
            spec, job.spec.est_seconds_base, job.spec.stage_in_bytes,
            job.spec.stage_out_bytes, load=self.directory.status(resource).load,
            noise_sigma=0.0, seed=())
        return s_in + ex + s_out


class LocalExecutor:
    """Real execution: ``job.spec.payload`` is a callable() -> result."""

    def __init__(self, directory: ResourceDirectory, max_workers: int = 4):
        self.directory = directory
        self.pool = ThreadPoolExecutor(max_workers=max_workers)
        self.proxy = StagingProxy()
        self._futures: Dict[str, Future] = {}
        self._lock = threading.Lock()

    def submit(self, job: Job, resource: str, cb: DispatchCallbacks) -> None:
        spec = self.directory.spec(resource)
        st = self.directory.status(resource)
        with self._lock:
            if not st.up:
                cb.on_failed(job, "resource unavailable at submit")
                return
            if not st.acquire(spec):
                cb.blocked(job, SLOT_LOST)
                return
            job.slot_held = True
            job.acquired_at = time.time()

        def run():
            cb.on_started(job)
            t0 = time.monotonic()
            try:
                job.result = (job.spec.payload() if callable(job.spec.payload)
                              else None)
            except Exception as e:  # noqa: BLE001 — job failure, not ours
                with self._lock:
                    job.slot_held = False
                    st.release()
                cb.on_failed(job, f"payload raised: {e!r}")
                return
            with self._lock:
                job.slot_held = False
                st.release()
            cb.on_done(job, time.monotonic() - t0)

        self._futures[job.job_id] = self.pool.submit(run)

    def cancel(self, job: Job) -> None:
        f = self._futures.get(job.job_id)
        if f:
            f.cancel()

    def estimate(self, job: Job, resource: str) -> float:
        spec = self.directory.spec(resource)
        return job.spec.est_seconds_base / max(spec.perf_factor, 1e-6)

    def shutdown(self) -> None:
        self.pool.shutdown(wait=True)


class Dispatcher:
    """Thin mediation layer the engine talks to (paper's component)."""

    def __init__(self, executor, directory: ResourceDirectory):
        self.executor = executor
        self.directory = directory
        self.dispatched = 0

    def dispatch(self, job: Job, resource: str, cb: DispatchCallbacks
                 ) -> None:
        job.resource = resource
        job.status = JobStatus.STAGED
        job.attempt += 1
        self.dispatched += 1
        self.executor.submit(job, resource, cb)

    def cancel(self, job: Job) -> None:
        self.executor.cancel(job)

    def estimate(self, job: Job, resource: str) -> float:
        return self.executor.estimate(job, resource)
