"""Grid telemetry: sim-clock tracing + metrics for the whole market.

Nimrod/G's broker is defined by what it watches — it "monitors and
steers" experiments against deadline and budget — and the GRACE economy
papers evaluate every scheduling claim from traced job/price timelines.
This module is that observation layer for the repro: a per-run
``Tracer`` every subsystem emits typed events into, a
``MetricsRegistry`` of counters/gauges/histograms snapshotted on the
sim clock, and exporters to Chrome trace-event JSON (drop the file into
https://ui.perfetto.dev) and a byte-stable JSONL event log.

Design constraints, in order:

* **Zero overhead when disabled.**  Every instrumentation site in the
  market guards on ``if tracer is not None`` — the default everywhere —
  so the traced-off hot path pays one attribute read and a None check.
  Telemetry only *observes*: it draws no RNG, mutates no market state,
  and never reorders events, so same-seed runs are byte-identical with
  it on, off, or toggled (the golden-equivalence hashes pin this).

* **Bounded memory, stable order.**  Events land in per-category ring
  buffers (``collections.deque(maxlen=...)``), so a heartbeat flood can
  never evict job spans — each category evicts only its own oldest.
  Every event carries a monotone global sequence number; ``events()``
  merges the rings back into one deterministically ordered stream.

* **Sim time is the timeline.**  All record methods take the virtual
  clock ``t`` explicitly; the Chrome export maps one sim second to one
  exported second (``ts`` microseconds), one track per broker/domain.

Span taxonomy (also documented in the README "Observability" section):

===========  ========================  =====================================
category     names                     emitted by
===========  ========================  =====================================
``job``      ``job`` / ``attempt``     parametric: async span per job
             spans; ``requeue``,       (first dispatch -> completion) and
             ``duplicate``,            per dispatch attempt.  The attempt
             ``resale_buy``            span *end* carries the ``outcome``
                                       arg (``settled`` / ``killed`` /
                                       ``slot_lost`` / ``failed`` /
                                       ``unfinished``) — there are no
                                       separate settle/kill instants
``sched``    ``replan``                scheduler: advisor decisions that
                                       changed the allocation
``auction``  ``clearing_round``,       auctions: one instant per site
             ``contract``, ``bid``,    round, per struck contract, per
             ``discovery_nudge``       posted-price EMA nudge
``gis``      ``heartbeat_pump``,       gis + parametric: liveness pumps,
             ``register``,             (de)registrations, dispatch-burn
             ``deregister``,           suspicions
             ``suspect``
``churn``    ``site_leave``,           simulator + marketplace: membership
             ``site_join``,            churn, machine failures, in-flight
             ``resource_down``,        evictions
             ``resource_up``,
             ``eviction``
``bank``     exceptional entry kinds   accounting: one instant per
             only (``kill``,           *exceptional* money movement;
             ``contract``,             plain settlements are tallied in
             ``refund``, ``idle``,     the ``bank.settlements`` counter
             ``resale``, ``fee``)      (the attempt span already shows
                                       each one)
``resale``   ``fill``, ``fee``,        secondary market book events
             ``reclaim``, ``drop``
``market``   ``broker_finish``         marketplace: per-broker outcome
             instants,                 instants, per-tick price samples,
             ``price.mean_quote``      with full registry snapshots
             counter samples           every 4th watch tick
``metric``   one ``C`` sample per      ``Tracer.snapshot_counters`` —
             scalar instrument         the registry flushed onto the
                                       timeline
===========  ========================  =====================================
"""
from __future__ import annotations

import bisect
import collections
import json
import math
from typing import (Any, Callable, Dict, Iterator, List, NamedTuple,
                    Optional)
from typing import Tuple

from repro.core.persistence import stable_dumps

#: default per-category ring capacity — big enough that a full
#: standard_market run keeps every event, small enough that a 10k-job
#: benchmark sweep stays bounded (drops are counted, never silent)
DEFAULT_RING = 100_000


class TraceEvent(NamedTuple):
    """One recorded event.  ``ph`` follows the Chrome trace-event
    phases: ``"b"``/``"e"`` async span begin/end (``span`` is the id —
    async, because one broker track carries many overlapping jobs),
    ``"i"`` instant, ``"C"`` counter sample.  A NamedTuple, not a
    dataclass: recording is the traced-on hot path and tuple
    construction is several times cheaper.  ``args`` keeps call-site
    kwargs order; every exporter serializes with sorted keys, so the
    stream stays canonical without a per-event sort."""
    seq: int
    t: float
    track: str
    cat: str
    name: str
    ph: str
    span: str = ""
    args: Optional[Dict[str, Any]] = None

    def to_json(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"seq": self.seq, "t": self.t,
                             "track": self.track, "cat": self.cat,
                             "name": self.name, "ph": self.ph}
        if self.span:
            d["span"] = self.span
        if self.args:
            d["args"] = {k: self.args[k] for k in sorted(self.args)}
        return d


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class Counter:
    """Monotone count (events, cache hits).  ``inc`` only."""
    __slots__ = ("name", "unit", "value")

    def __init__(self, name: str, unit: str = ""):
        self.name = name
        self.unit = unit
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def get(self) -> float:
        return self.value


class Gauge:
    """Point-in-time value.  Either ``set()`` explicitly or construct
    with ``fn`` — a derived gauge evaluated at snapshot time (e.g.
    ``lambda: secondary.wasted_spend``)."""
    __slots__ = ("name", "unit", "fn", "_value")

    def __init__(self, name: str, unit: str = "",
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.unit = unit
        self.fn = fn
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = v

    def get(self) -> float:
        return float(self.fn()) if self.fn is not None else self._value


class MultiGauge:
    """A labeled family of derived gauges: ``fn`` returns a dict of
    label -> value at snapshot time (e.g. per-owner revenue by entry
    kind).  Labels are sorted on read — deterministic snapshots."""
    __slots__ = ("name", "unit", "fn")

    def __init__(self, name: str, fn: Callable[[], Dict[str, float]],
                 unit: str = ""):
        self.name = name
        self.unit = unit
        self.fn = fn

    def get(self) -> Dict[str, float]:
        return {k: v for k, v in sorted(self.fn().items())}


class Histogram:
    """Fixed-bucket histogram (attempts-per-job, deadline slack).
    Buckets are upper bounds; observations above the last bound land in
    the overflow bucket.  Tracks count/sum/min/max exactly."""
    __slots__ = ("name", "unit", "bounds", "buckets", "count", "total",
                 "min", "max")

    DEFAULT_BOUNDS = (0.5, 1.0, 2.0, 3.0, 5.0, 8.0, 13.0, 21.0, 50.0,
                      100.0, 1000.0)

    def __init__(self, name: str, unit: str = "",
                 bounds: Optional[Tuple[float, ...]] = None):
        self.name = name
        self.unit = unit
        self.bounds = tuple(bounds) if bounds is not None \
            else self.DEFAULT_BOUNDS
        self.buckets = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        self.buckets[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """Estimate the ``p``-th percentile (``p`` in [0, 100]) from the
        bucket counts: linear interpolation inside the containing bucket,
        with the observed min/max tightening the first and last occupied
        buckets (so p0/p100 are exact and an overflow-bucket estimate
        never exceeds the largest observation)."""
        if not 0.0 <= p <= 100.0:
            raise ValueError(f"percentile must be in [0, 100], got {p}")
        if self.count == 0:
            return 0.0
        target = p / 100.0 * self.count
        cum = 0.0
        for i, n in enumerate(self.buckets):
            if n == 0:
                continue
            lo = self.min if i == 0 else max(self.bounds[i - 1], self.min)
            hi = self.max if i == len(self.bounds) \
                else min(self.bounds[i], self.max)
            if hi < lo:
                hi = lo
            if cum + n >= target:
                frac = (target - cum) / n
                return lo + frac * (hi - lo)
            cum += n
        return self.max

    def summary(self) -> Dict[str, Any]:
        return {"count": self.count, "sum": self.total,
                "mean": self.mean(),
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "buckets": dict(zip([f"le_{b}" for b in self.bounds]
                                    + ["overflow"], self.buckets))}


class MetricsRegistry:
    """Get-or-create registry shared by every subsystem in one run.
    Registering an existing name returns the existing instrument (so N
    brokers share one ``broker.quote_memo_hits``); re-registering under
    a different type is a bug and raises."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}

    def _get_or_create(self, cls, name: str, **kw):
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m
        m = cls(name, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, unit: str = "") -> Counter:
        return self._get_or_create(Counter, name, unit=unit)

    def gauge(self, name: str, unit: str = "",
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._get_or_create(Gauge, name, unit=unit, fn=fn)
        if fn is not None:
            g.fn = fn
        return g

    def multi_gauge(self, name: str, fn: Callable[[], Dict[str, float]],
                    unit: str = "") -> MultiGauge:
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, MultiGauge):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not MultiGauge")
            m.fn = fn
            return m
        m = MultiGauge(name, fn, unit=unit)
        self._metrics[name] = m
        return m

    def histogram(self, name: str, unit: str = "",
                  bounds: Optional[Tuple[float, ...]] = None) -> Histogram:
        return self._get_or_create(Histogram, name, bounds=bounds, unit=unit)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Any]:
        """Deterministic point-in-time read of every instrument, sorted
        by name: scalars for counters/gauges, label dicts for
        multi-gauges, ``summary()`` dicts for histograms."""
        out: Dict[str, Any] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Histogram):
                out[name] = m.summary()
            else:
                out[name] = m.get()
        return out


# ---------------------------------------------------------------------------
# the tracer
# ---------------------------------------------------------------------------

class Subscription:
    """Handle returned by ``Tracer.subscribe``; ``cancel()`` detaches
    the consumer (idempotent)."""
    __slots__ = ("tracer", "category", "fn")

    def __init__(self, tracer: "Tracer", category: str,
                 fn: Callable[[TraceEvent], None]):
        self.tracer = tracer
        self.category = category
        self.fn = fn

    def cancel(self) -> None:
        self.tracer.unsubscribe(self.category, self.fn)


class Tracer:
    """Per-run event recorder + metrics registry.

    All record methods take the virtual time ``t`` explicitly (the
    tracer never reads a clock — determinism is the caller's ``t``).
    Events are bounded per category; ``dropped`` counts ring evictions
    so truncation is never silent.

    Besides the ring buffers (post-hoc reads), consumers can
    ``subscribe(category, fn)`` to the live stream: each recorded event
    is also delivered synchronously at emit time — on the sim clock, in
    global seq order — to every subscriber of its category (and of the
    ``"*"`` wildcard).  Rings, exporters and the no-subscriber hot path
    are unchanged; with no subscribers a record costs one extra bool
    check.
    """

    def __init__(self, ring: int = DEFAULT_RING, domain: str = ""):
        if ring <= 0:
            raise ValueError("ring capacity must be positive")
        self.ring = ring
        # originating administrative domain: in a sharded run each
        # process tags every event it records with its domain, so merged
        # traces say WHERE an event happened, not just when.  Empty (the
        # default, every single-process run) adds nothing — the exported
        # bytes stay identical to an untagged tracer's
        self.domain = domain
        self.metrics = MetricsRegistry()
        self._rings: Dict[str, collections.deque] = {}
        self.dropped: Dict[str, int] = {}
        self._seq = 0
        # streaming subscribers: category -> consumer list.  The lists
        # are copy-on-write (subscribe/unsubscribe replace them) so
        # delivery iterates without defensive copies; _have_subs keeps
        # the subscriber-free record path at a single bool check
        self._subs: Dict[str, List[Callable[[TraceEvent], None]]] = {}
        self._have_subs = False
        self._sub_q: collections.deque = collections.deque()
        self._delivering = False

    # -- streaming subscribers -----------------------------------------
    def subscribe(self, category: str, fn: Callable[[TraceEvent], None],
                  raw: bool = False) -> Subscription:
        """Attach a live consumer: ``fn(event)`` is called for every
        subsequently recorded event of ``category`` (``"*"`` = all
        categories), synchronously at emit time and in seq order.
        Consumers see each event exactly when it happens on the sim
        clock — an invariant watchdog can raise *at* the violation, not
        at export time.  Events a consumer records re-entrantly (e.g. a
        steering instant) queue behind the event being delivered, so the
        stream every consumer observes stays seq-ordered.  A consumer
        exception propagates to the recording site — that is the point
        for watchdogs.  ``raw=True`` consumers receive the plain tuple
        (field order = ``TraceEvent``) instead of a materialized
        NamedTuple — the constructor is the dominant bus cost, and a
        hot-path consumer that indexes anyway shouldn't pay it.
        Returns a ``Subscription``; ``cancel()`` detaches (effective
        from the next event)."""
        self._subs[category] = self._subs.get(category, []) + [(fn, raw)]
        self._have_subs = True
        return Subscription(self, category, fn)

    def unsubscribe(self, category: str,
                    fn: Callable[[TraceEvent], None]) -> None:
        subs = self._subs.get(category)
        if subs is None:
            return
        rest = [e for e in subs if e[0] is not fn]
        if len(rest) == len(subs):
            return
        if rest:
            self._subs[category] = rest
        else:
            del self._subs[category]
        self._have_subs = bool(self._subs)

    def _deliver(self, ev: tuple) -> None:
        if self._delivering:
            # re-entrant record (e.g. a steering instant emitted from a
            # consumer): queue behind the event being delivered so every
            # consumer observes the stream in seq order
            self._sub_q.append(ev)
            return
        self._delivering = True
        q = self._sub_q
        subs_by_cat = self._subs
        raw = ev
        try:
            while True:
                subs = subs_by_cat.get(raw[3])
                event = None            # materialized once, only if needed
                if subs:
                    for fn, wants_raw in subs:
                        if wants_raw:
                            fn(raw)
                        else:
                            if event is None:
                                event = TraceEvent._make(raw)
                            fn(event)
                wild = subs_by_cat.get("*")
                if wild:
                    for fn, wants_raw in wild:
                        if wants_raw:
                            fn(raw)
                        else:
                            if event is None:
                                event = TraceEvent._make(raw)
                            fn(event)
                if not q:
                    break
                raw = q.popleft()
        finally:
            self._delivering = False

    # -- recording -----------------------------------------------------
    # each recorder inlines the ring append rather than delegating to a
    # shared helper, and the rings hold PLAIN TUPLES (field order =
    # TraceEvent) that ``events()`` materialises lazily: recording is
    # the traced-on hot path (a market run emits more trace events than
    # sim events) and both the extra call frame and the NamedTuple
    # constructor measurably move the bench_telemetry gate
    def _record(self, t: float, track: str, cat: str, name: str, ph: str,
                span: str, args: Dict[str, Any]) -> None:
        if self.domain:
            args = {**(args or {}), "domain": self.domain}
        ring = self._rings.get(cat)
        if ring is None:
            ring = self._rings[cat] = collections.deque(maxlen=self.ring)
        elif len(ring) == self.ring:
            self.dropped[cat] = self.dropped.get(cat, 0) + 1
        ev = (self._seq, t, track, cat, name, ph, span, args or None)
        ring.append(ev)
        self._seq += 1
        if self._have_subs:
            self._deliver(ev)

    def span_begin(self, t: float, track: str, cat: str, name: str,
                   span: str, **args: Any) -> None:
        """Open an async span (``span`` is the id matching the end —
        async, so one track can carry many overlapping jobs)."""
        if self.domain:
            args["domain"] = self.domain
        ring = self._rings.get(cat)
        if ring is None:
            ring = self._rings[cat] = collections.deque(maxlen=self.ring)
        elif len(ring) == self.ring:
            self.dropped[cat] = self.dropped.get(cat, 0) + 1
        ev = (self._seq, t, track, cat, name, "b", span, args or None)
        ring.append(ev)
        self._seq += 1
        if self._have_subs:
            self._deliver(ev)

    def span_end(self, t: float, track: str, cat: str, name: str,
                 span: str, **args: Any) -> None:
        if self.domain:
            args["domain"] = self.domain
        ring = self._rings.get(cat)
        if ring is None:
            ring = self._rings[cat] = collections.deque(maxlen=self.ring)
        elif len(ring) == self.ring:
            self.dropped[cat] = self.dropped.get(cat, 0) + 1
        ev = (self._seq, t, track, cat, name, "e", span, args or None)
        ring.append(ev)
        self._seq += 1
        if self._have_subs:
            self._deliver(ev)

    def instant(self, t: float, track: str, cat: str, name: str,
                **args: Any) -> None:
        if self.domain:
            args["domain"] = self.domain
        ring = self._rings.get(cat)
        if ring is None:
            ring = self._rings[cat] = collections.deque(maxlen=self.ring)
        elif len(ring) == self.ring:
            self.dropped[cat] = self.dropped.get(cat, 0) + 1
        ev = (self._seq, t, track, cat, name, "i", "", args or None)
        ring.append(ev)
        self._seq += 1
        if self._have_subs:
            self._deliver(ev)

    def counter(self, t: float, track: str, name: str,
                value: float) -> None:
        """One counter-track sample (renders as a value graph)."""
        ring = self._rings.get("metric")
        if ring is None:
            ring = self._rings["metric"] = collections.deque(
                maxlen=self.ring)
        elif len(ring) == self.ring:
            self.dropped["metric"] = self.dropped.get("metric", 0) + 1
        args = {"value": value}
        if self.domain:
            args["domain"] = self.domain
        ev = (self._seq, t, track, "metric", name, "C", "", args)
        ring.append(ev)
        self._seq += 1
        if self._have_subs:
            self._deliver(ev)

    def snapshot_counters(self, t: float, track: str = "metrics") -> None:
        """Emit every registry instrument as counter samples at ``t`` —
        the per-tick snapshot the marketplace watch loop records.
        Histograms sample their count and sum (rates and means are
        derivable between consecutive samples)."""
        for name, m in sorted(self.metrics._metrics.items()):
            if isinstance(m, Histogram):
                self.counter(t, track, f"{name}.count", m.count)
                self.counter(t, track, f"{name}.sum", m.total)
            elif isinstance(m, MultiGauge):
                for label, v in m.get().items():
                    self.counter(t, track, f"{name}/{label}", v)
            else:
                self.counter(t, track, name, m.get())

    # -- reading -------------------------------------------------------
    def events(self) -> List[TraceEvent]:
        """Every retained event, merged across category rings back into
        one stream ordered by the global sequence number."""
        merged: List[tuple] = []
        for ring in self._rings.values():
            merged.extend(ring)
        merged.sort()                       # tuples lead with seq
        return [TraceEvent._make(e) for e in merged]

    def n_events(self) -> int:
        return sum(len(r) for r in self._rings.values())

    def n_dropped(self) -> int:
        return sum(self.dropped.values())

    def categories(self) -> Dict[str, int]:
        return {cat: len(ring)
                for cat, ring in sorted(self._rings.items())}

    # -- exports -------------------------------------------------------
    def jsonl_lines(self) -> Iterator[str]:
        """The JSONL event log, one canonical-JSON line per event via
        the journal's ``stable_dumps`` — same-seed runs produce
        byte-identical streams (nothing wall-clock-derived is ever in
        here; registry metrics are exported separately)."""
        for ev in self.events():
            yield stable_dumps(ev.to_json())

    def to_chrome(self, run_name: str = "nimrod-market") -> Dict[str, Any]:
        """Chrome trace-event JSON (object format) — loadable by
        Perfetto / chrome://tracing.  One pid for the grid, one tid per
        track (broker/domain), ``ts`` in microseconds of sim time.
        The full metrics snapshot and ring-drop counts ride along in
        ``otherData``."""
        events = self.events()
        tracks = sorted({e.track for e in events})
        tid = {name: i + 1 for i, name in enumerate(tracks)}
        out: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
            "args": {"name": run_name}}]
        for name in tracks:
            out.append({"name": "thread_name", "ph": "M", "pid": 1,
                        "tid": tid[name], "args": {"name": name}})
        for ev in events:
            d: Dict[str, Any] = {
                "name": ev.name, "cat": ev.cat, "ph": ev.ph,
                "ts": ev.t * 1e6, "pid": 1, "tid": tid[ev.track]}
            if ev.ph in ("b", "e"):
                d["id"] = ev.span
            elif ev.ph == "i":
                d["s"] = "t"        # thread-scoped instant
            if ev.args:
                d["args"] = {k: ev.args[k] for k in sorted(ev.args)}
            out.append(d)
        return {"traceEvents": out,
                "displayTimeUnit": "ms",
                "otherData": {
                    "run": run_name,
                    "sim_time_unit": "1 exported second == 1 sim second",
                    "events": self.n_events(),
                    "dropped": dict(sorted(self.dropped.items())),
                    "metrics": self.metrics.snapshot()}}


def export_chrome_trace(tracer: Tracer, path: str,
                        run_name: str = "nimrod-market") -> str:
    """Write the Perfetto-loadable Chrome trace to ``path``; returns
    the path for chaining."""
    with open(path, "w", encoding="utf-8") as f:
        json.dump(tracer.to_chrome(run_name=run_name), f, sort_keys=True)
        f.write("\n")
    return path


def export_jsonl(tracer: Tracer, path: str) -> str:
    """Write the deterministic JSONL event log to ``path`` (truncates —
    an export is a snapshot, not a journal append)."""
    with open(path, "w", encoding="utf-8") as f:
        for line in tracer.jsonl_lines():
            f.write(line + "\n")
    return path


def load_chrome_trace(path: str) -> Dict[str, Any]:
    """Read back an exported Chrome trace (the dashboard's input)."""
    with open(path, encoding="utf-8") as f:
        return json.load(f)
