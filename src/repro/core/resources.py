"""Grid resources and the resource directory (the paper's MDS analogue).

A *resource* here is a TPU slice owned by some administrative domain:
it has a capability (chips, peak FLOP/s, HBM bandwidth), an access policy
(which users are authorized), a queue, an owner-set price schedule, a
reliability model (MTBF), and optionally sits behind a closed-cluster
proxy (only the master node speaks to the WAN — paper §4).

All dynamic behaviour is driven by the virtual clock so scheduler
experiments are deterministic and unit-testable.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

# TPU v5e per-chip constants (match the roofline section)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link


@dataclasses.dataclass(frozen=True)
class ResourceSpec:
    name: str
    site: str
    department: str = ""              # sub-site registry ("" = site/main)
    chips: int = 8
    peak_flops_per_chip: float = PEAK_FLOPS
    perf_factor: float = 1.0          # relative efficiency of this slice
    slots: int = 1                    # concurrent jobs the queue runs
    base_price: float = 1.0           # G$ per chip-hour at off-peak
    peak_multiplier: float = 2.0      # daytime price multiplier
    mtbf_hours: float = 400.0         # mean time between failures
    mttr_hours: float = 1.0           # mean time to repair
    closed: bool = False              # behind a master-node proxy
    authorized_users: Tuple[str, ...] = ()   # empty = everyone
    stage_bw: float = 1e9             # bytes/s for stage-in/out

    def effective_flops(self) -> float:
        return self.chips * self.peak_flops_per_chip * self.perf_factor


@dataclasses.dataclass
class ResourceStatus:
    up: bool = True
    running: int = 0
    queued: int = 0
    load: float = 0.0                 # exogenous competing load [0,1)
    # published repair/rejoin ETA: FailureProcess writes the scheduled
    # repair time when it takes the resource down (ChurnProcess the
    # rejoin time), and clears it on recovery — the GIS answers "when
    # is it back up?" from this, never from the event queue
    next_transition: float = math.inf
    departed: bool = False            # site left the grid (churn)
    # monotone stamp bumped on every slot acquire/release: quote caches
    # key on it (utilization feeds demand pricing), so a cached price is
    # reused exactly as long as nothing that prices off this queue moved
    version: int = 0
    # lifetime acquire/release tallies: the slot-accounting invariant
    # (acquires == releases + running) the ExperimentMonitor watchdog
    # audits online.  ``release`` clamps ``running`` at zero, so a
    # double release is invisible in ``running`` alone — the tallies
    # keep the evidence
    acquires: int = 0
    releases: int = 0
    # back-reference to the owning ResourceDirectory (set at register
    # time): every occupancy/liveness flip bumps the directory-wide
    # ``churn`` stamp so brokers can skip whole refresh passes in O(1)
    _dir: object = dataclasses.field(default=None, repr=False,
                                     compare=False)

    def free_slots(self, spec: ResourceSpec) -> int:
        return max(0, spec.slots - self.running) if self.up else 0

    def acquire(self, spec: ResourceSpec) -> bool:
        """Atomically claim one slot.  With many brokers sharing a grid the
        check and the increment must be one operation — a broker that read
        "1 free" a moment ago can still lose the slot to a rival and must
        be told so (it requeues; it must not over-subscribe the queue)."""
        if not self.up or self.running >= spec.slots:
            return False
        self.running += 1
        self.acquires += 1
        self.version += 1
        d = self._dir
        if d is not None:
            d.churn += 1
        return True

    def release(self) -> None:
        self.running = max(0, self.running - 1)
        self.releases += 1
        self.version += 1
        d = self._dir
        if d is not None:
            d.churn += 1

    def set_up(self, up: bool) -> None:
        """Flip liveness through here, never by assigning ``up``
        directly: failure/churn processes must bump the directory churn
        stamp or a broker's O(1) view-refresh skip would keep serving
        the stale liveness."""
        self.up = up
        d = self._dir
        if d is not None:
            d.churn += 1

    def utilization(self, spec: ResourceSpec) -> float:
        """Fraction of the queue occupied — the demand half of GRACE's
        supply-and-demand pricing."""
        if spec.slots <= 0:
            return 1.0
        return min(1.0, max(0.0, self.running / spec.slots))


class ResourceDirectory:
    """MDS-style directory: registration, discovery, authorization."""

    def __init__(self):
        self._specs: Dict[str, ResourceSpec] = {}
        self._status: Dict[str, ResourceStatus] = {}
        # monotone stamp bumped on every register/deregister: the shared
        # quote board keys its row <-> resource binding on it
        self.membership_version = 0
        # monotone stamp bumped on every state flip that can change a
        # broker's derived view of the grid — slot acquire/release,
        # liveness flips, membership.  "Unchanged churn" ⇒ every
        # (up, running) pair in the directory is exactly as last seen
        self.churn = 0

    # -- registration (resource owners) --
    def register(self, spec: ResourceSpec) -> None:
        if spec.name in self._specs:
            raise ValueError(f"resource {spec.name!r} already registered")
        self._specs[spec.name] = spec
        self._status[spec.name] = ResourceStatus(_dir=self)
        self.membership_version += 1
        self.churn += 1

    def deregister(self, name: str) -> None:
        self._specs.pop(name, None)
        self._status.pop(name, None)
        self.membership_version += 1
        self.churn += 1

    # -- discovery (schedulers) --
    def discover(self, user: str, *, site: Optional[str] = None,
                 min_chips: int = 0, up_only: bool = True
                 ) -> List[ResourceSpec]:
        out = []
        for spec in self._specs.values():
            if spec.authorized_users and user not in spec.authorized_users:
                continue
            if site is not None and spec.site != site:
                continue
            if spec.chips < min_chips:
                continue
            if up_only and not self._status[spec.name].up:
                continue
            out.append(spec)
        return sorted(out, key=lambda s: s.name)

    def spec(self, name: str) -> ResourceSpec:
        return self._specs[name]

    def status(self, name: str) -> ResourceStatus:
        return self._status[name]

    def all_names(self) -> List[str]:
        return sorted(self._specs)

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def sites(self) -> List[str]:
        return sorted({s.site for s in self._specs.values()})

    def site_resources(self, site: str) -> List[str]:
        return sorted(n for n, s in self._specs.items() if s.site == site)


def gusto_like_testbed(n_machines: int = 70, seed: int = 0,
                       sites: Sequence[str] = ("ANL", "ISI", "Monash", "UVA",
                                               "UTK"),
                       ) -> List[ResourceSpec]:
    """A testbed shaped like the paper's GUSTO trial (~70 heterogeneous
    machines across several administrative domains, varied speed/price)."""
    import random
    rng = random.Random(seed)
    specs = []
    for i in range(n_machines):
        site = sites[i % len(sites)]
        perf = rng.choice([0.5, 0.75, 1.0, 1.0, 1.5, 2.0])
        price = rng.choice([0.5, 1.0, 1.0, 2.0, 3.0]) * (0.8 + 0.4 * rng.random())
        specs.append(ResourceSpec(
            name=f"{site.lower()}-{i:03d}", site=site,
            department=f"d{(i // len(sites)) % 3}",
            chips=rng.choice([1, 1, 2, 4]),
            perf_factor=perf,
            slots=1,
            base_price=price,
            peak_multiplier=rng.choice([1.0, 1.5, 2.0, 3.0]),
            mtbf_hours=rng.choice([100.0, 200.0, 400.0, 800.0]),
            mttr_hours=rng.choice([0.25, 0.5, 1.0]),
            closed=(rng.random() < 0.2),
            stage_bw=rng.choice([10e6, 100e6, 1e9]),
        ))
    return specs
