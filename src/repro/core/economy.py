"""Computational economy (paper §3) and the GRACE market machinery
(paper §7): owner-set time-varying prices, per-user multipliers,
budget/deadline containers, sealed-bid tendering, and advance
reservations.

Prices are in "grid dollars" (G$) per chip-hour, exactly the paper's
artificial-cost setting; owners control their schedule, users see a quote
that can differ per user (the paper: "the cost can vary from one user to
another").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.resources import ResourceDirectory, ResourceSpec

HOUR = 3600.0


@dataclasses.dataclass(frozen=True)
class UserRequirements:
    """What the client hands the scheduler: the paper's two knobs."""
    deadline: float                 # absolute virtual time by which to finish
    budget: float                   # G$ the user is willing to pay
    strategy: str = "cost"          # cost | time | conservative
    user: str = "rajkumar"


class PriceSchedule:
    """Owner-set price: base * peak-hours multiplier * per-user factor,
    plus optional spot-style fluctuation (deterministic in virtual time)
    and a demand-responsive multiplier (GRACE's supply-and-demand knob:
    a busy queue raises the quote, an idle one relaxes it).

    With ``discovery_gain > 0`` the owner also *learns* from the market:
    every auction clearing round it trades in EMA-nudges the posted
    ``base_price`` toward the base the clearing price implies, with
    drift bounded to ``discovery_band`` around the original base —
    auction price discovery feeding the posted-price schedule back."""

    def __init__(self, spec: ResourceSpec,
                 user_factors: Optional[Dict[str, float]] = None,
                 spot_amplitude: float = 0.0, spot_period: float = 5 * HOUR,
                 phase: float = 0.0, demand_elasticity: float = 0.0,
                 discovery_gain: float = 0.0, discovery_band: float = 0.5):
        if not 0.0 <= discovery_gain <= 1.0:
            raise ValueError("discovery_gain must be in [0, 1]")
        if discovery_band < 0.0:
            raise ValueError("discovery_band must be >= 0")
        self.spec = spec
        self.user_factors = user_factors or {}
        self.spot_amplitude = spot_amplitude
        self.spot_period = spot_period
        self.phase = phase
        self.demand_elasticity = demand_elasticity
        self.discovery_gain = discovery_gain
        self.discovery_band = discovery_band
        # the posted base the owner actually quotes: equals the spec's
        # base forever when discovery is off, drifts (bounded) toward
        # clearing prices when it is on
        self.base_price = spec.base_price
        # monotone stamp bumped whenever the posted base drifts: batched
        # quote rows re-key on it, mirroring book_version for the book
        self.version = 0

    def chip_hour_price(self, t: float, user: str = "",
                        utilization: float = 0.0) -> float:
        day = (t / HOUR + self.phase) % 24.0
        peak = self.spec.peak_multiplier if 8.0 <= day < 20.0 else 1.0
        spot = 1.0
        if self.spot_amplitude:
            spot = 1.0 + self.spot_amplitude * math.sin(
                2 * math.pi * (t + self.phase * HOUR) / self.spot_period)
        uf = self.user_factors.get(user, 1.0)
        demand = 1.0 + self.demand_elasticity * max(0.0, min(1.0, utilization))
        return self.base_price * peak * spot * uf * demand

    def observe_clearing(self, t: float, clearing_price: float) -> None:
        """A trade on this resource cleared at ``clearing_price``.  The
        clearing quote carries the same time-of-day/spot factors as the
        posted one, so the implied *base* is backed out by ratio before
        the EMA step — an off-peak trade never drags the peak schedule
        around.  Deterministic: driven only by clearing events, which
        fire on the virtual clock."""
        if self.discovery_gain <= 0.0 or clearing_price <= 0.0:
            return
        posted = self.chip_hour_price(t)
        if posted <= 0.0:
            return
        implied = self.base_price * (clearing_price / posted)
        lo = self.spec.base_price * (1.0 - self.discovery_band)
        hi = self.spec.base_price * (1.0 + self.discovery_band)
        target = min(max(implied, lo), hi)
        self.base_price += self.discovery_gain * (target - self.base_price)
        self.version += 1

    def job_cost(self, t: float, duration: float, user: str = "",
                 utilization: float = 0.0) -> float:
        """Cost of occupying the whole slice for ``duration`` seconds."""
        return (self.chip_hour_price(t, user, utilization) * self.spec.chips
                * duration / HOUR)


@dataclasses.dataclass
class Reservation:
    resource: str
    user: str
    start: float
    end: float
    locked_price: float             # chip-hour price honored in the window
    reservation_id: int = 0


@dataclasses.dataclass(frozen=True)
class Bid:
    resource: str
    chip_hour_price: float
    available_slots: int
    est_rate: float                 # jobs/hour this resource can sustain
    valid_until: float
    # non-zero = this bid is a rival's resale listing (the reservation
    # id on the book).  It prices like any other bid, but locking it in
    # means BUYING the listing (SecondaryMarket.buy), never reserving
    # fresh capacity at the all-in rate — the premium belongs to the
    # seller, not the owner
    resale_rid: int = 0


class AdmissionError(Exception):
    """Reservation refused: resource window full or user over quota."""


class TradeServer:
    """GRACE bid-server + trade-manager: quotes, sealed bids, reservations.

    One per administrative domain (``site``) — or, with ``site=None``,
    one for the whole grid (the single-server shape the early tests and
    examples use).  With many brokers sharing the grid, quotes reflect
    live demand (queue utilization feeds each owner's ``PriceSchedule``)
    and reservations go through admission control: a window can hold at
    most ``slots`` overlapping reservations, and optionally at most
    ``max_reservations_per_user`` per user across the domain.

    A sealed bid's price is honored for ``bid_validity`` seconds; a
    settlement arriving later must re-quote (``honored_price``).  If a
    ``GridBank`` is attached, owners may extend the per-user reservation
    quota for proven patrons (realized revenue drives admission).
    """

    def __init__(self, directory: ResourceDirectory,
                 schedules: Dict[str, PriceSchedule],
                 max_reservations_per_user: Optional[int] = None,
                 site: Optional[str] = None,
                 bid_validity: float = HOUR,
                 bank=None,
                 patron_spend_threshold: float = math.inf,
                 patron_quota_bonus: int = 0):
        self.directory = directory
        self.schedules = schedules
        self.max_reservations_per_user = max_reservations_per_user
        self.site = site
        self.bid_validity = bid_validity
        self.bank = bank
        self.patron_spend_threshold = patron_spend_threshold
        self.patron_quota_bonus = patron_quota_bonus
        self.reservations: List[Reservation] = []
        # resale book this domain's server quotes from (attached by the
        # marketplace when the secondary market is enabled): listings
        # merge into solicit_bids as just another price source
        self.secondary = None
        self._next_rid = 1
        self._rid_step = 1       # federation strides this for unique ids
        # monotone stamp bumped on every reservation-book mutation:
        # broker-side quote caches key on it, so an effective price is
        # recomputed exactly when a reservation could have changed it
        self.book_version = 0
        # a lone server never changes membership; the attribute exists
        # so the quote board stamps servers and federations uniformly
        self.membership_version = 0
        self._board = None

    def price_version(self, resource: str) -> int:
        """Stamp of everything (besides time and queue utilization) a
        quote for ``resource`` depends on.  Equal stamps at equal t and
        equal ``ResourceStatus.version`` ⇒ ``effective_price`` is
        unchanged — the invariant the per-tick broker cache relies on."""
        return self.book_version

    def _prune(self, t: float) -> None:
        """Drop expired reservations so long market runs never degrade
        into O(total-reservations-ever) scans.  An expired reservation
        can no longer price a query (``start <= t < end`` fails) nor
        block admission for windows at/after ``t``."""
        if any(r.end <= t for r in self.reservations):
            self.reservations = [r for r in self.reservations if r.end > t]
            self.book_version += 1

    def resources(self) -> List[str]:
        """Names this server trades (its domain's slice of the grid)."""
        return [n for n in self.directory.all_names()
                if self.site is None
                or self.directory.spec(n).site == self.site]

    def resource_up(self, resource: str) -> bool:
        """Domain-local liveness ground truth.  Cross-domain consumers
        (auction books, brokers) ask the owning server rather than
        reading the directory — across a process boundary the directory
        is a mirror, and only the domain knows its own machines."""
        return self.directory.status(resource).up

    def find_reservation(self, reservation_id: int) -> Optional[Reservation]:
        """Look one reservation up by its federation-unique id (the
        secondary market's locate path — a seam, so a remote book can
        answer without shipping its whole reservation list)."""
        for r in self.reservations:
            if r.reservation_id == reservation_id:
                return r
        return None

    def utilization(self, resource: str) -> float:
        return self.directory.status(resource).utilization(
            self.directory.spec(resource))

    def quote(self, resource: str, t: float, user: str = "") -> float:
        sched = self.schedules[resource]
        util = self.utilization(resource) if sched.demand_elasticity else 0.0
        return sched.chip_hour_price(t, user, utilization=util)

    def forward_quote(self, resource: str, t: float, user: str = "") -> float:
        """The owner's posted price for *future* window capacity: the
        schedule without the instantaneous demand premium.  A queue that
        is crowded right now says nothing about the slots it will have
        free over the next contract window, so negotiated trades price
        off this, not the spot quote."""
        return self.schedules[resource].chip_hour_price(t, user,
                                                        utilization=0.0)

    def solicit_bids(self, t: float, user: str,
                     est_job_seconds: Callable[[ResourceSpec], float]
                     ) -> List[Bid]:
        """Open-market tender: each authorized, up resource returns a
        sealed bid (price honored until valid_until)."""
        bids = []
        for spec in self.directory.discover(user, site=self.site):
            st = self.directory.status(spec.name)
            dur = est_job_seconds(spec)
            rate = (HOUR / dur) * spec.slots if dur > 0 else 0.0
            bids.append(Bid(
                resource=spec.name,
                chip_hour_price=self.quote(spec.name, t, user),
                available_slots=st.free_slots(spec),
                est_rate=rate,
                valid_until=t + self.bid_validity,
            ))
        if self.secondary is not None:
            # rival brokers' live resale listings answer the tender too:
            # one slot each, priced at the buyer's true all-in rate
            # (owner usage at the locked price + the seller's premium)
            for lst in self.secondary.offers_at_site(self.site, t,
                                                     exclude=user):
                if lst.resource not in self.directory:
                    continue
                spec = self.directory.spec(lst.resource)
                dur = est_job_seconds(spec)
                bids.append(Bid(
                    resource=lst.resource,
                    chip_hour_price=lst.all_in_rate,
                    available_slots=1,
                    est_rate=(HOUR / dur) if dur > 0 else 0.0,
                    valid_until=min(t + self.bid_validity, lst.end),
                    resale_rid=lst.reservation_id,
                ))
        return sorted(bids, key=lambda b: (b.chip_hour_price, b.resource))

    def _user_quota(self, user: str) -> Optional[int]:
        if self.max_reservations_per_user is None:
            return None
        quota = self.max_reservations_per_user
        if (self.bank is not None and self.patron_quota_bonus
                and self.site is not None
                and self.bank.pair_spend(user, self.site)
                >= self.patron_spend_threshold):
            quota += self.patron_quota_bonus
        return quota

    def reservable_slots(self, resource: str, start: float, end: float
                         ) -> int:
        """Slots not yet promised to anyone over [start, end) — the
        capacity an owner can put up for auction without overbooking."""
        spec = self.directory.spec(resource)
        overlapping = sum(1 for r in self.reservations
                          if r.resource == resource
                          and r.start < end and start < r.end)
        return max(0, spec.slots - overlapping)

    def reserve(self, resource: str, user: str, start: float, end: float,
                t: float, locked_price: Optional[float] = None
                ) -> Reservation:
        """Advance reservation.  ``locked_price`` overrides the live
        quote — a negotiated (auction/tender) contract locks the struck
        price, not whatever the owner happens to post at signing time."""
        self._prune(t)
        spec = self.directory.spec(resource)
        overlapping = sum(1 for r in self.reservations
                          if r.resource == resource
                          and r.start < end and start < r.end)
        if overlapping >= spec.slots:
            raise AdmissionError(
                f"{resource}: {overlapping} reservations already overlap "
                f"[{start}, {end}) (capacity {spec.slots})")
        quota = self._user_quota(user)
        if quota is not None:
            active = sum(1 for r in self.reservations
                         if r.user == user and r.end > t)
            if active >= quota:
                raise AdmissionError(
                    f"user {user!r} holds {active} active reservations "
                    f"(quota {quota})")
        r = Reservation(resource=resource, user=user, start=start, end=end,
                        locked_price=(locked_price if locked_price is not None
                                      else self.quote(resource, t, user)),
                        reservation_id=self._next_rid)
        self._next_rid += self._rid_step
        self.reservations.append(r)
        self.book_version += 1
        return r

    def cancel(self, reservation_id: int) -> bool:
        n = len(self.reservations)
        self.reservations = [r for r in self.reservations
                             if r.reservation_id != reservation_id]
        if len(self.reservations) < n:
            self.book_version += 1
            return True
        return False

    def transfer(self, reservation_id: int, buyer: str, t: float
                 ) -> Optional[Reservation]:
        """Secondary-market fill: the reservation changes hands but not
        shape — same window, same resource, same locked price, so the
        owner's capacity promise is untouched.  The buyer must clear the
        same per-user admission quota a fresh reservation would (a
        resale must never be a quota side-door).  Returns the
        transferred reservation, or None if it expired/was cancelled."""
        self._prune(t)
        for r in self.reservations:
            if r.reservation_id != reservation_id:
                continue
            if r.user == buyer:
                return r
            quota = self._user_quota(buyer)
            if quota is not None:
                active = sum(1 for x in self.reservations
                             if x.user == buyer and x.end > t)
                if active >= quota:
                    raise AdmissionError(
                        f"user {buyer!r} holds {active} active reservations "
                        f"(quota {quota}) — transfer refused")
            r.user = buyer
            self.book_version += 1
            return r
        return None

    def reserved_price(self, resource: str, user: str, t: float
                       ) -> Optional[float]:
        self._prune(t)
        for r in self.reservations:
            if (r.resource == resource and r.user == user
                    and r.start <= t < r.end):
                return r.locked_price
        return None

    def reserved_slots(self, resource: str, user: str, t: float) -> int:
        """How many slots the user's live reservations cover on this
        resource — the cap on how many concurrent jobs may draw the
        locked price (the rest pay spot)."""
        return len(self.reserved_price_list(resource, user, t))

    def reserved_price_list(self, resource: str, user: str, t: float
                            ) -> List[float]:
        """Locked prices of ALL the user's live reservations on this
        resource, in book order — one entry per reserved slot.  Each
        entry prices exactly one concurrent job; overlapping contracts
        struck at different prices each bill their own slot."""
        self._prune(t)
        return [r.locked_price for r in self.reservations
                if r.resource == resource and r.user == user
                and r.start <= t < r.end]

    def effective_price(self, resource: str, user: str, t: float) -> float:
        locked = self.reserved_price(resource, user, t)
        return locked if locked is not None else self.quote(resource, t, user)

    def honored_price(self, resource: str, user: str, sealed_price: float,
                      sealed_at: float, t: float) -> float:
        """Price a settlement may use at time ``t`` for a quote sealed at
        ``sealed_at``: the sealed price while it is still valid, a fresh
        effective price (re-quote) once it has expired.  A dispatch that
        settles after its sealed bid lapsed must not silently honor the
        stale price."""
        if t <= sealed_at + self.bid_validity + 1e-9:
            return sealed_price
        return self.effective_price(resource, user, t)


class TradeFederation:
    """Directory of per-site trade servers (GRACE: one trade server per
    administrative domain) presenting the single-server interface.

    Brokers talk to the federation exactly as they talked to the single
    ``TradeServer``; under the hood every call routes to the owning
    domain's server.  ``solicit_bids`` merges all domains' sealed bids
    price-sorted — the cross-domain arbitrage view: a broker sees at a
    glance that ISI's idle machines undercut ANL's crowded ones and
    routes its jobs there."""

    def __init__(self, servers: Dict[str, TradeServer]):
        if not servers:
            raise ValueError("federation needs at least one trade server")
        self.servers = dict(sorted(servers.items()))
        self.directory = next(iter(self.servers.values())).directory
        self.bid_validity = max(s.bid_validity for s in self.servers.values())
        # domains that left the grid (churn): their servers stay behind
        # as read-only price boards — a broker holding a stale view can
        # still COMPUTE against the departed domain's posted schedule,
        # it just can't trade there anymore
        self._departed: Dict[str, TradeServer] = {}
        # high-water mark over every reservation id EVER issued under
        # this federation, surviving server replacement: a site that
        # rejoins with a fresh server must never reissue an id that
        # lives on in voided contracts or audit trails
        self._rid_floor = 1
        # bumped on add_server/remove_server: the quote board re-derives
        # its resource -> server rows when federation membership moves
        self.membership_version = 0
        self._board = None
        self._restride()

    def _restride(self) -> None:
        # stride the per-server reservation counters so ids are unique
        # federation-wide (cancel() must never hit a rival domain's
        # book).  Counters only move FORWARD into distinct residue
        # classes: a server that already issued ids before federation
        # (or before a membership change) keeps them below every id
        # issued afterwards — departed/replaced servers' history counts
        # too, via the floor.
        n = len(self.servers)
        if n == 0:
            return
        start = max([self._rid_floor]
                    + [s._next_rid for s in self.servers.values()]
                    + [s._next_rid for s in self._departed.values()])
        self._rid_floor = start
        for i, server in enumerate(self.servers.values()):
            server._rid_step = n
            server._next_rid = start + (i + 1 - start) % n

    # -- membership churn ----------------------------------------------
    def remove_server(self, site: str) -> TradeServer:
        """The domain left the grid.  Its server is demoted to a
        read-only price board (quotes on stale views keep working);
        reserving or bidding there is over."""
        server = self.servers.pop(site)
        self._departed[site] = server
        self.membership_version += 1
        # mirror add_server: the federation-wide validity window is the
        # max over LIVE members.  Without this, a departed long-validity
        # domain kept stretching how long the federation honored sealed
        # bids — stale state from a site that can no longer trade.
        if self.servers:
            self.bid_validity = max(s.bid_validity
                                    for s in self.servers.values())
        return server

    def add_server(self, site: str, server: TradeServer) -> None:
        """A domain joined (or rejoined, with a FRESH server — its old
        book died with it).  Counters re-stride forward so the new
        membership keeps issuing federation-unique reservation ids."""
        if site in self.servers:
            raise ValueError(f"trade server for {site!r} already federated")
        old = self._departed.pop(site, None)
        if old is not None:
            # the replaced server's issued ids must stay retired forever
            self._rid_floor = max(self._rid_floor, old._next_rid)
        self.servers[site] = server
        self.servers = dict(sorted(self.servers.items()))
        self.bid_validity = max(s.bid_validity for s in self.servers.values())
        self.membership_version += 1
        self._restride()

    @classmethod
    def from_directory(cls, directory: ResourceDirectory,
                       schedules: Dict[str, PriceSchedule],
                       **server_kw) -> "TradeFederation":
        """One server per administrative domain found in the directory."""
        by_site: Dict[str, Dict[str, PriceSchedule]] = {}
        for name, sched in schedules.items():
            by_site.setdefault(directory.spec(name).site, {})[name] = sched
        return cls({site: TradeServer(directory, scheds, site=site,
                                      **server_kw)
                    for site, scheds in sorted(by_site.items())})

    # -- routing -------------------------------------------------------
    def sites(self) -> List[str]:
        return list(self.servers)

    def departed_sites(self) -> List[str]:
        return sorted(self._departed)

    def server_for(self, resource: str) -> TradeServer:
        site = self.directory.spec(resource).site
        if site in self.servers:
            return self.servers[site]
        return self._departed[site]

    # -- single-server interface (delegated) ---------------------------
    def price_version(self, resource: str) -> int:
        return self.server_for(resource).book_version

    def utilization(self, resource: str) -> float:
        return self.server_for(resource).utilization(resource)

    def quote(self, resource: str, t: float, user: str = "") -> float:
        return self.server_for(resource).quote(resource, t, user)

    def forward_quote(self, resource: str, t: float, user: str = "") -> float:
        board = self._board
        if board is not None:
            v = board.forward(resource, user, t)
            if v is not None:
                return v
        return self.server_for(resource).forward_quote(resource, t, user)

    def solicit_bids(self, t: float, user: str,
                     est_job_seconds: Callable[[ResourceSpec], float]
                     ) -> List[Bid]:
        bids: List[Bid] = []
        for server in self.servers.values():
            bids.extend(server.solicit_bids(t, user, est_job_seconds))
        return sorted(bids, key=lambda b: (b.chip_hour_price, b.resource))

    def reserve(self, resource: str, user: str, start: float, end: float,
                t: float, locked_price: Optional[float] = None
                ) -> Reservation:
        site = self.directory.spec(resource).site
        if site not in self.servers:
            raise AdmissionError(
                f"{resource}: domain {site!r} has left the grid — "
                f"no reservations until it rejoins")
        return self.servers[site].reserve(
            resource, user, start, end, t, locked_price=locked_price)

    def cancel(self, reservation_id: int) -> bool:
        # departed servers included: voiding a dying domain's contracts
        # must find the reservations wherever the book went
        return any(s.cancel(reservation_id)
                   for s in list(self.servers.values())
                   + list(self._departed.values()))

    def reserved_price(self, resource: str, user: str, t: float
                       ) -> Optional[float]:
        return self.server_for(resource).reserved_price(resource, user, t)

    def reserved_slots(self, resource: str, user: str, t: float) -> int:
        return self.server_for(resource).reserved_slots(resource, user, t)

    def reserved_price_list(self, resource: str, user: str, t: float
                            ) -> List[float]:
        return self.server_for(resource).reserved_price_list(
            resource, user, t)

    def effective_price(self, resource: str, user: str, t: float) -> float:
        return self.server_for(resource).effective_price(resource, user, t)

    def honored_price(self, resource: str, user: str, sealed_price: float,
                      sealed_at: float, t: float) -> float:
        return self.server_for(resource).honored_price(
            resource, user, sealed_price, sealed_at, t)

    @property
    def reservations(self) -> List[Reservation]:
        """Federation-wide reservation book (read-only convenience)."""
        out: List[Reservation] = []
        for server in self.servers.values():
            out.extend(server.reservations)
        return out


@dataclasses.dataclass
class BudgetLedger:
    """Tracks spend against the user's budget (committed vs settled)."""
    budget: float
    settled: float = 0.0
    committed: float = 0.0

    def can_commit(self, amount: float) -> bool:
        return self.settled + self.committed + amount <= self.budget + 1e-9

    def commit(self, amount: float) -> None:
        self.committed += amount

    def settle(self, committed: float, actual: float) -> None:
        self.committed = max(0.0, self.committed - committed)
        self.settled += actual

    @property
    def remaining(self) -> float:
        return self.budget - self.settled - self.committed
