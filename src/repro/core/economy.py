"""Computational economy (paper §3) and the GRACE market machinery
(paper §7): owner-set time-varying prices, per-user multipliers,
budget/deadline containers, sealed-bid tendering, and advance
reservations.

Prices are in "grid dollars" (G$) per chip-hour, exactly the paper's
artificial-cost setting; owners control their schedule, users see a quote
that can differ per user (the paper: "the cost can vary from one user to
another").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.resources import ResourceDirectory, ResourceSpec

HOUR = 3600.0


@dataclasses.dataclass(frozen=True)
class UserRequirements:
    """What the client hands the scheduler: the paper's two knobs."""
    deadline: float                 # absolute virtual time by which to finish
    budget: float                   # G$ the user is willing to pay
    strategy: str = "cost"          # cost | time | conservative
    user: str = "rajkumar"


class PriceSchedule:
    """Owner-set price: base * peak-hours multiplier * per-user factor,
    plus optional spot-style fluctuation (deterministic in virtual time)
    and a demand-responsive multiplier (GRACE's supply-and-demand knob:
    a busy queue raises the quote, an idle one relaxes it)."""

    def __init__(self, spec: ResourceSpec,
                 user_factors: Optional[Dict[str, float]] = None,
                 spot_amplitude: float = 0.0, spot_period: float = 5 * HOUR,
                 phase: float = 0.0, demand_elasticity: float = 0.0):
        self.spec = spec
        self.user_factors = user_factors or {}
        self.spot_amplitude = spot_amplitude
        self.spot_period = spot_period
        self.phase = phase
        self.demand_elasticity = demand_elasticity

    def chip_hour_price(self, t: float, user: str = "",
                        utilization: float = 0.0) -> float:
        day = (t / HOUR + self.phase) % 24.0
        peak = self.spec.peak_multiplier if 8.0 <= day < 20.0 else 1.0
        spot = 1.0
        if self.spot_amplitude:
            spot = 1.0 + self.spot_amplitude * math.sin(
                2 * math.pi * (t + self.phase * HOUR) / self.spot_period)
        uf = self.user_factors.get(user, 1.0)
        demand = 1.0 + self.demand_elasticity * max(0.0, min(1.0, utilization))
        return self.spec.base_price * peak * spot * uf * demand

    def job_cost(self, t: float, duration: float, user: str = "",
                 utilization: float = 0.0) -> float:
        """Cost of occupying the whole slice for ``duration`` seconds."""
        return (self.chip_hour_price(t, user, utilization) * self.spec.chips
                * duration / HOUR)


@dataclasses.dataclass
class Reservation:
    resource: str
    user: str
    start: float
    end: float
    locked_price: float             # chip-hour price honored in the window
    reservation_id: int = 0


@dataclasses.dataclass(frozen=True)
class Bid:
    resource: str
    chip_hour_price: float
    available_slots: int
    est_rate: float                 # jobs/hour this resource can sustain
    valid_until: float


class AdmissionError(Exception):
    """Reservation refused: resource window full or user over quota."""


class TradeServer:
    """GRACE bid-server + trade-manager: quotes, sealed bids, reservations.

    One per grid (in reality one per domain; a single instance keeps the
    simulation simple while preserving the protocol shape).  With many
    brokers sharing the grid, quotes reflect live demand (queue
    utilization feeds each owner's ``PriceSchedule``) and reservations go
    through admission control: a window can hold at most ``slots``
    overlapping reservations, and optionally at most
    ``max_reservations_per_user`` per user across the grid.
    """

    def __init__(self, directory: ResourceDirectory,
                 schedules: Dict[str, PriceSchedule],
                 max_reservations_per_user: Optional[int] = None):
        self.directory = directory
        self.schedules = schedules
        self.max_reservations_per_user = max_reservations_per_user
        self.reservations: List[Reservation] = []
        self._next_rid = 1

    def utilization(self, resource: str) -> float:
        return self.directory.status(resource).utilization(
            self.directory.spec(resource))

    def quote(self, resource: str, t: float, user: str = "") -> float:
        sched = self.schedules[resource]
        util = self.utilization(resource) if sched.demand_elasticity else 0.0
        return sched.chip_hour_price(t, user, utilization=util)

    def solicit_bids(self, t: float, user: str,
                     est_job_seconds: Callable[[ResourceSpec], float]
                     ) -> List[Bid]:
        """Open-market tender: each authorized, up resource returns a
        sealed bid (price honored until valid_until)."""
        bids = []
        for spec in self.directory.discover(user):
            st = self.directory.status(spec.name)
            dur = est_job_seconds(spec)
            rate = (HOUR / dur) * spec.slots if dur > 0 else 0.0
            bids.append(Bid(
                resource=spec.name,
                chip_hour_price=self.quote(spec.name, t, user),
                available_slots=st.free_slots(spec),
                est_rate=rate,
                valid_until=t + HOUR,
            ))
        return sorted(bids, key=lambda b: b.chip_hour_price)

    def reserve(self, resource: str, user: str, start: float, end: float,
                t: float) -> Reservation:
        spec = self.directory.spec(resource)
        overlapping = sum(1 for r in self.reservations
                          if r.resource == resource
                          and r.start < end and start < r.end)
        if overlapping >= spec.slots:
            raise AdmissionError(
                f"{resource}: {overlapping} reservations already overlap "
                f"[{start}, {end}) (capacity {spec.slots})")
        if self.max_reservations_per_user is not None:
            active = sum(1 for r in self.reservations
                         if r.user == user and r.end > t)
            if active >= self.max_reservations_per_user:
                raise AdmissionError(
                    f"user {user!r} holds {active} active reservations "
                    f"(quota {self.max_reservations_per_user})")
        r = Reservation(resource=resource, user=user, start=start, end=end,
                        locked_price=self.quote(resource, t, user),
                        reservation_id=self._next_rid)
        self._next_rid += 1
        self.reservations.append(r)
        return r

    def cancel(self, reservation_id: int) -> bool:
        n = len(self.reservations)
        self.reservations = [r for r in self.reservations
                             if r.reservation_id != reservation_id]
        return len(self.reservations) < n

    def reserved_price(self, resource: str, user: str, t: float
                       ) -> Optional[float]:
        for r in self.reservations:
            if (r.resource == resource and r.user == user
                    and r.start <= t < r.end):
                return r.locked_price
        return None

    def effective_price(self, resource: str, user: str, t: float) -> float:
        locked = self.reserved_price(resource, user, t)
        return locked if locked is not None else self.quote(resource, t, user)


@dataclasses.dataclass
class BudgetLedger:
    """Tracks spend against the user's budget (committed vs settled)."""
    budget: float
    settled: float = 0.0
    committed: float = 0.0

    def can_commit(self, amount: float) -> bool:
        return self.settled + self.committed + amount <= self.budget + 1e-9

    def commit(self, amount: float) -> None:
        self.committed += amount

    def settle(self, committed: float, actual: float) -> None:
        self.committed = max(0.0, self.committed - committed)
        self.settled += actual

    @property
    def remaining(self) -> float:
        return self.budget - self.settled - self.committed
