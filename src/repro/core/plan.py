"""The declarative parametric modeling language (paper §1/§2; Clustor
"plan file" lineage [13]).

Grammar (line oriented; ``#`` comments)::

    parameter <name> float   range from <a> to <b> step <s>
    parameter <name> integer range from <a> to <b> step <s>
    parameter <name> <type>  select anyof <v1> <v2> ...
    parameter <name> <type>  default <v>
    task <name>
        copy <src> node:<dst>
        execute <command ... $param ...>
        copy node:<src> <dst>
    endtask

Expansion is the full cross product of parameter values — the paper's
"task farm".  ``$name`` / ``${name}`` / ``$jobname`` substitute into task
steps.
"""
from __future__ import annotations

import dataclasses
import itertools
import re
import shlex
from typing import Any, Dict, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Parameter:
    name: str
    ptype: str                   # float | integer | text
    values: Tuple[Any, ...]


@dataclasses.dataclass(frozen=True)
class TaskStep:
    op: str                      # copy | execute
    args: Tuple[str, ...]

    @property
    def is_stage_in(self) -> bool:
        return self.op == "copy" and not self.args[0].startswith("node:")

    @property
    def is_stage_out(self) -> bool:
        return self.op == "copy" and self.args[0].startswith("node:")


@dataclasses.dataclass(frozen=True)
class Plan:
    parameters: Tuple[Parameter, ...]
    task: Tuple[TaskStep, ...]
    task_name: str = "main"

    def n_jobs(self) -> int:
        n = 1
        for p in self.parameters:
            n *= len(p.values)
        return n

    def points(self) -> List[Dict[str, Any]]:
        names = [p.name for p in self.parameters]
        vals = [p.values for p in self.parameters]
        return [dict(zip(names, combo))
                for combo in itertools.product(*vals)]


class PlanError(ValueError):
    pass


def _coerce(ptype: str, tok: str) -> Any:
    if ptype == "integer":
        return int(tok)
    if ptype == "float":
        return float(tok)
    return tok.strip('"')


def _frange(a: float, b: float, s: float) -> List[float]:
    if s <= 0:
        raise PlanError(f"step must be positive, got {s}")
    out, x, i = [], a, 0
    while x <= b + 1e-9:
        out.append(round(x, 12))
        i += 1
        x = a + i * s
    return out


def parse_plan(text: str) -> Plan:
    params: List[Parameter] = []
    steps: List[TaskStep] = []
    task_name = "main"
    in_task = False
    seen_task = False

    for ln, raw in enumerate(text.splitlines(), start=1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        toks = shlex.split(line, posix=False)
        head = toks[0].lower()

        if head == "parameter":
            if in_task:
                raise PlanError(f"line {ln}: parameter inside task block")
            if len(toks) < 4:
                raise PlanError(f"line {ln}: malformed parameter")
            name, ptype = toks[1], toks[2].lower()
            if ptype not in ("float", "integer", "text"):
                raise PlanError(f"line {ln}: unknown type {ptype!r}")
            mode = toks[3].lower()
            if mode == "range":
                if ptype == "text" or len(toks) != 10 or \
                        (toks[4].lower(), toks[6].lower(),
                         toks[8].lower()) != ("from", "to", "step"):
                    raise PlanError(f"line {ln}: malformed range")
                a, b, s = (float(toks[5]), float(toks[7]), float(toks[9]))
                vals = _frange(a, b, s)
                if ptype == "integer":
                    vals = [int(round(v)) for v in vals]
                params.append(Parameter(name, ptype, tuple(vals)))
            elif mode == "select":
                if len(toks) < 6 or toks[4].lower() != "anyof":
                    raise PlanError(f"line {ln}: malformed select")
                vals = tuple(_coerce(ptype, t) for t in toks[5:])
                params.append(Parameter(name, ptype, vals))
            elif mode == "default":
                params.append(Parameter(name, ptype,
                                        (_coerce(ptype, toks[4]),)))
            else:
                raise PlanError(f"line {ln}: unknown parameter mode {mode!r}")
        elif head == "task":
            if seen_task:
                raise PlanError(f"line {ln}: only one task block supported")
            in_task, seen_task = True, True
            if len(toks) > 1:
                task_name = toks[1]
        elif head == "endtask":
            if not in_task:
                raise PlanError(f"line {ln}: endtask outside task")
            in_task = False
        elif head in ("copy", "execute"):
            if not in_task:
                raise PlanError(f"line {ln}: {head} outside task block")
            steps.append(TaskStep(head, tuple(toks[1:])))
        else:
            raise PlanError(f"line {ln}: unknown directive {head!r}")

    if in_task:
        raise PlanError("unterminated task block")
    if not seen_task:
        raise PlanError("plan has no task block")
    if not params:
        raise PlanError("plan declares no parameters")
    names = [p.name for p in params]
    if len(set(names)) != len(names):
        raise PlanError("duplicate parameter names")
    return Plan(tuple(params), tuple(steps), task_name)


_SUB = re.compile(r"\$\{(\w+)\}|\$(\w+)")


def substitute(step: TaskStep, point: Dict[str, Any], jobname: str
               ) -> TaskStep:
    env = {**{k: str(v) for k, v in point.items()}, "jobname": jobname}

    def rep(m: re.Match) -> str:
        key = m.group(1) or m.group(2)
        if key not in env:
            raise PlanError(f"undefined plan variable ${key}")
        return env[key]

    return TaskStep(step.op, tuple(_SUB.sub(rep, a) for a in step.args))
