"""Grid Information Service (the paper's Globus MDS, taken seriously).

Nimrod/G discovers resources "scattered geographically at various levels
(department, enterprise, or worldwide)" through a directory service — it
never enjoys perfect global knowledge.  This module replaces the
omniscient ``ResourceDirectory.discover`` path with that information
layer, modeled after GridSim's GIS (cs/0203019):

* a **hierarchical registry** — one department registry per
  ``site/department``, rolled up into one enterprise registry per
  administrative domain, rolled up into the global registry (the
  abstract's three levels).  Resources and per-domain trade servers
  *register with and deregister from* it on the virtual clock; queries
  can be scoped to any level;
* **heartbeat liveness** — registered resources beat every
  ``heartbeat_interval`` seconds while they are actually up; the GIS
  only *suspects* a silent resource after ``suspect_after`` missed
  beats.  Death is detected, never observed: between the failure and
  the suspicion the GIS happily advertises a corpse;
* **attribute queries** — ``query(t, user=..., min_chips=...,
  max_price=..., level=..., within=...)`` filters on the *advertised*
  (heartbeat-stale) attributes, exactly the MDS search a broker's
  discovery phase runs;
* **cached broker views** — ``GISClient`` gives each broker a snapshot
  with a TTL.  Between refreshes the broker schedules against stale
  membership: it will dispatch to a machine that died or left since the
  snapshot and must survive the fast-fail (requeue without burning an
  attempt, suspect locally, retry elsewhere);
* **repair ETAs** — ``eta_back_up`` surfaces the
  ``ResourceStatus.next_transition`` that ``FailureProcess`` and
  ``ChurnProcess`` publish, so a scheduler can ask "when is it back?"
  instead of polling a corpse.

Everything is driven by the shared virtual clock and iterates in sorted
order — GIS runs are exactly as deterministic as the simulator under
them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.resources import ResourceDirectory, ResourceSpec
from repro.core.simulator import Simulator

HOUR = 3600.0

LEVELS = ("department", "enterprise", "global")


@dataclasses.dataclass
class GISRecord:
    """One resource's registration: the attributes the GIS *advertises*,
    which lag the ground truth by up to a heartbeat."""
    spec: ResourceSpec
    department: str                  # "<site>/<dept>" (level-1 registry)
    enterprise: str                  # "<site>"        (level-2 registry)
    registered_at: float
    last_heartbeat: float
    advertised_price: float          # chip-hour price at the last beat

    @property
    def name(self) -> str:
        return self.spec.name


@dataclasses.dataclass(frozen=True)
class GISEntry:
    """What a query returns: the record's attributes frozen at query
    time, plus the GIS's *suspicion* (not knowledge) of liveness."""
    spec: ResourceSpec
    department: str
    enterprise: str
    advertised_price: float
    last_heartbeat: float
    suspected: bool

    @property
    def name(self) -> str:
        return self.spec.name

    def to_wire(self) -> Dict[str, object]:
        """The dynamic attributes a GIS query answer ships across a
        domain boundary (the spec itself is mirrored once at sync time,
        keyed by name — re-shipping it per query would be the bulk of
        every answer)."""
        return {"name": self.spec.name, "site": self.spec.site,
                "department": self.department,
                "enterprise": self.enterprise,
                "chips": self.spec.chips,
                "advertised_price": self.advertised_price,
                "last_heartbeat": self.last_heartbeat,
                "suspected": self.suspected}

    @classmethod
    def from_wire(cls, d: Dict[str, object],
                  spec: ResourceSpec) -> "GISEntry":
        """Rebuild an entry broker-side from its wire row plus the
        mirrored spec (which must be the row's resource)."""
        if spec.name != d["name"]:
            raise ValueError(f"wire row {d['name']!r} does not match "
                             f"spec {spec.name!r}")
        return cls(spec=spec, department=str(d["department"]),
                   enterprise=str(d["enterprise"]),
                   advertised_price=float(d["advertised_price"]),
                   last_heartbeat=float(d["last_heartbeat"]),
                   suspected=bool(d["suspected"]))


class GISRegistry:
    """One node of the hierarchy.  Department registries hold the
    records; enterprise and global registries hold *references* to the
    same records (registration propagates upward), so a heartbeat at the
    leaf is instantly visible at every level — the hierarchy partitions
    the namespace, it does not add propagation delay."""

    def __init__(self, name: str, level: str,
                 parent: Optional["GISRegistry"] = None):
        assert level in LEVELS
        self.name = name
        self.level = level
        self.parent = parent
        self.children: Dict[str, "GISRegistry"] = {}
        self.members: Dict[str, GISRecord] = {}

    def child(self, name: str, level: str) -> "GISRegistry":
        if name not in self.children:
            self.children[name] = GISRegistry(name, level, parent=self)
        return self.children[name]

    def _add(self, rec: GISRecord) -> None:
        node: Optional[GISRegistry] = self
        while node is not None:
            node.members[rec.name] = rec
            node = node.parent

    def _remove(self, name: str) -> None:
        node: Optional[GISRegistry] = self
        while node is not None:
            node.members.pop(name, None)
            node = node.parent


def department_of(spec: ResourceSpec) -> str:
    """Level-1 registry key: ``site/department`` (a spec with no
    department lands in its site's ``main`` department)."""
    return f"{spec.site}/{spec.department or 'main'}"


class GridInformationService:
    """The discovery substrate: register, beat, query — never peek.

    ``price_fn(name, t)`` supplies the chip-hour price a resource
    advertises at each heartbeat (the marketplace passes the trade
    federation's posted forward quote); queries filter on this
    *advertised* price, which can be a full heartbeat stale.
    """

    def __init__(self, directory: ResourceDirectory, *,
                 heartbeat_interval: float = 300.0,
                 suspect_after: int = 2,
                 price_fn: Optional[Callable[[str, float], float]] = None):
        if heartbeat_interval <= 0:
            raise ValueError("heartbeat_interval must be positive")
        if suspect_after < 1:
            raise ValueError("suspect_after must be >= 1 missed beats")
        self.directory = directory
        self.heartbeat_interval = heartbeat_interval
        self.suspect_after = suspect_after
        self.price_fn = price_fn
        self.root = GISRegistry("grid", "global")
        self._records: Dict[str, GISRecord] = {}
        self._trade_servers: Dict[str, object] = {}
        self.registrations = 0
        self.deregistrations = 0
        self.heartbeats = 0
        self.tracer = None              # set by bind_telemetry
        # monotone stamp bumped on every mutation that can change a
        # query answer (register/deregister/heartbeat); keys the
        # single-entry query cache below.  With N brokers sharing one
        # GIS they all refresh their TTL snapshots at the same virtual
        # instant — the first pays for the registry walk, the rest hit
        self.version = 0
        self._qcache_key = None
        self._qcache_val: List[GISEntry] = []
        # registered specs with a non-empty authorized_users list: while
        # zero, the ``user`` argument cannot change a query answer and
        # collapses out of the cache key
        self._n_restricted = 0

    def bind_telemetry(self, tracer) -> None:
        """Attach a ``repro.core.telemetry.Tracer``: heartbeat pumps and
        (de)registrations emit ``gis`` instants (one per pump, not one
        per beat — a per-beat instant would be all flood, no signal),
        and the registry gains gauges over the service counters."""
        self.tracer = tracer
        m = tracer.metrics
        m.gauge("gis.heartbeats", fn=lambda: float(self.heartbeats))
        m.gauge("gis.registrations",
                fn=lambda: float(self.registrations))
        m.gauge("gis.deregistrations",
                fn=lambda: float(self.deregistrations))
        m.gauge("gis.registered",
                fn=lambda: float(len(self._records)))

    # -- registration (resources / owners) -----------------------------
    def register(self, spec: ResourceSpec, t: float) -> GISRecord:
        if spec.name in self._records:
            raise ValueError(f"{spec.name!r} already registered with GIS")
        dept = department_of(spec)
        node = (self.root.child(spec.site, "enterprise")
                .child(dept, "department"))
        price = (self.price_fn(spec.name, t) if self.price_fn is not None
                 else spec.base_price)
        rec = GISRecord(spec=spec, department=dept, enterprise=spec.site,
                        registered_at=t, last_heartbeat=t,
                        advertised_price=price)
        node._add(rec)
        self._records[spec.name] = rec
        self.registrations += 1
        self.version += 1
        if spec.authorized_users:
            self._n_restricted += 1
        if self.tracer is not None:
            self.tracer.instant(t, "gis", "gis", "register",
                                resource=spec.name, site=spec.site,
                                department=dept, price=price)
        return rec

    def deregister(self, name: str, t: float) -> bool:
        rec = self._records.pop(name, None)
        if rec is None:
            return False
        node = (self.root.child(rec.enterprise, "enterprise")
                .child(rec.department, "department"))
        node._remove(name)
        self.deregistrations += 1
        self.version += 1
        if rec.spec.authorized_users:
            self._n_restricted -= 1
        if self.tracer is not None:
            self.tracer.instant(t, "gis", "gis", "deregister",
                                resource=name, site=rec.enterprise)
        return True

    def is_registered(self, name: str) -> bool:
        return name in self._records

    # -- trade-server membership (per-domain GRACE servers) ------------
    def register_trade_server(self, site: str, server: object) -> None:
        self._trade_servers[site] = server

    def deregister_trade_server(self, site: str) -> bool:
        return self._trade_servers.pop(site, None) is not None

    def trade_servers(self) -> Dict[str, object]:
        """Live per-domain trade servers, sorted by site — the
        federation membership is *this* map, not a hardcoded list."""
        return dict(sorted(self._trade_servers.items()))

    # -- heartbeats ----------------------------------------------------
    def start(self, sim: Simulator, until: float = math.inf):
        """Pump heartbeats on the virtual clock: every interval, each
        registered resource that is genuinely up refreshes its record
        (liveness + advertised price).  Down or departed resources go
        silent — the only way the GIS ever finds out.  Returns the
        recurring-timer handle so a driver can cancel the pump once
        nobody is left listening."""
        def _pump() -> None:
            # NB: sim.every stops on a truthy return — swallow the count
            self.pump_heartbeats(sim.now)

        return sim.every(self.heartbeat_interval, _pump, until=until)

    def pump_heartbeats(self, t: float) -> int:
        beat = 0
        for name in sorted(self._records):
            if name not in self.directory:
                continue
            st = self.directory.status(name)
            if st.up and not st.departed:
                self.heartbeat(name, t)
                beat += 1
        if self.tracer is not None:
            # one instant per pump (not per beat): the pump cadence is
            # the signal; per-resource beats would drown the gis track.
            # The suspected count rides along so stream consumers (the
            # live monitor's site-reliability rollup) track grid liveness
            # without polling the registry
            sus = sum(1 for name in self._records
                      if self.suspected(name, t))
            self.tracer.instant(t, "gis", "gis", "heartbeat_pump",
                                beats=beat, suspects=sus,
                                registered=len(self._records))
        return beat

    def heartbeat(self, name: str, t: float) -> None:
        rec = self._records[name]
        rec.last_heartbeat = t
        if self.price_fn is not None:
            rec.advertised_price = self.price_fn(name, t)
        self.heartbeats += 1
        self.version += 1

    def suspected(self, name: str, t: float) -> bool:
        """True once ``suspect_after`` heartbeats have gone missing.
        Between the actual death and this flipping, the GIS advertises
        the resource as alive — that window is the detection latency
        every consumer of this service must survive."""
        rec = self._records.get(name)
        if rec is None:
            return True              # deregistered = certainly gone
        grace = self.suspect_after * self.heartbeat_interval
        return t - rec.last_heartbeat > grace + 1e-9

    def eta_back_up(self, name: str, t: float) -> Optional[float]:
        """The published repair/rejoin time for a suspected resource
        (``FailureProcess``/``ChurnProcess`` write it), or None if the
        resource is not suspected or no ETA was published."""
        if not self.suspected(name, t):
            return None
        if name not in self.directory:
            return None
        eta = self.directory.status(name).next_transition
        return eta if math.isfinite(eta) else None

    # -- queries (schedulers) ------------------------------------------
    def _scope(self, level: str, within: Optional[str]) -> GISRegistry:
        if level == "global":
            return self.root
        if within is None:
            raise ValueError(f"level={level!r} needs within=<registry name>")
        if level == "enterprise":
            return self.root.child(within, "enterprise")
        if level == "department":
            site = within.split("/", 1)[0]
            return self.root.child(site, "enterprise").child(within,
                                                             "department")
        raise ValueError(f"unknown level {level!r}; pick one of {LEVELS}")

    def query(self, t: float, *, user: str = "",
              level: str = "global", within: Optional[str] = None,
              min_chips: int = 0, max_price: float = math.inf,
              include_suspected: bool = False) -> List[GISEntry]:
        """MDS-style attribute search over the chosen registry.  Filters
        run on *advertised* attributes (price as of the last heartbeat),
        and — unlike ``ResourceDirectory.discover`` — liveness means "no
        missed heartbeats", not ground truth."""
        # single-entry answer cache: N per-broker TTL clients refreshing
        # at the same virtual instant ask the same question N times.
        # The returned list is shared — entries are frozen, callers must
        # not mutate it.  ``suspected`` depends on t, so t is in the key
        ckey = (self.version, t, level, within, min_chips, max_price,
                include_suspected,
                user if self._n_restricted else "")
        if ckey == self._qcache_key:
            return self._qcache_val
        node = self._scope(level, within)
        out = []
        for name in sorted(node.members):
            rec = node.members[name]
            spec = rec.spec
            if (spec.authorized_users and user
                    and user not in spec.authorized_users):
                continue
            if spec.chips < min_chips:
                continue
            if rec.advertised_price > max_price:
                continue
            sus = self.suspected(name, t)
            if sus and not include_suspected:
                continue
            out.append(GISEntry(
                spec=spec, department=rec.department,
                enterprise=rec.enterprise,
                advertised_price=rec.advertised_price,
                last_heartbeat=rec.last_heartbeat, suspected=sus))
        self._qcache_key = ckey
        self._qcache_val = out
        return out

    def levels(self) -> Dict[str, List[str]]:
        """The registry tree, for reports: enterprise -> departments."""
        return {site: sorted(node.children)
                for site, node in sorted(self.root.children.items())}


# ---------------------------------------------------------------------------
# broker-side cached views
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GISSnapshot:
    """One broker's frozen picture of the grid: everything it believes
    until the next refresh, however wrong the world has become."""
    taken_at: float
    entries: Dict[str, GISEntry]
    # monotone per-client refresh counter: consumers that diff snapshots
    # (membership discovery) can skip the work while this is unchanged —
    # same generation ⇒ identical membership and advertised attributes
    generation: int = 0

    def alive(self) -> List[GISEntry]:
        return [e for _, e in sorted(self.entries.items())
                if not e.suspected]


class GISClient:
    """Per-broker cached view with a TTL (the paper's scheduler caches
    MDS answers between discovery phases).

    Between refreshes the broker plans against the snapshot; a resource
    that died or left since ``taken_at`` still looks healthy, and the
    broker only learns otherwise by burning a dispatch against it.
    ``suspect()`` is that feedback path: a fast-failed dispatch marks
    the resource suspect *locally* until the next refresh — the client
    never writes to the GIS (suspicion is an opinion, not a fact).
    """

    def __init__(self, gis: GridInformationService, user: str,
                 ttl: float = 600.0):
        if ttl < 0:
            raise ValueError("ttl must be >= 0")
        self.gis = gis
        self.user = user
        self.ttl = ttl
        self.refreshes = 0
        # monotone count of suspect() calls — with the snapshot
        # generation it stamps the client's belief state: unchanged
        # (generation, burns) ⇒ identical membership AND suspicion
        self.burns = 0
        self._snapshot: Optional[GISSnapshot] = None
        self._local_suspects: set = set()
        # run-lifetime tally of dispatch-burn suspicions per resource:
        # unlike _local_suspects it is never cleared on refresh — it is
        # the broker's memory of how often this resource's advertised
        # state turned out to be a lie (reputation strategies read it)
        self._suspicion_counts: Dict[str, int] = {}

    def view(self, t: float) -> GISSnapshot:
        if (self._snapshot is None
                or t - self._snapshot.taken_at > self.ttl + 1e-9):
            entries = {e.name: e for e in self.gis.query(
                t, user=self.user, include_suspected=True)}
            self._snapshot = GISSnapshot(taken_at=t, entries=entries,
                                         generation=self.refreshes + 1)
            # a fresh snapshot supersedes dispatch-time suspicions: the
            # GIS's (possibly still wrong) answer gets another chance
            self._local_suspects.clear()
            self.refreshes += 1
        return self._snapshot

    def suspect(self, name: str) -> None:
        self._local_suspects.add(name)
        self.burns += 1
        self._suspicion_counts[name] = self._suspicion_counts.get(name,
                                                                  0) + 1

    def suspicion_count(self, name: str) -> int:
        """How many dispatches this broker has burned on ``name`` over
        the whole run — observed churn/failure history, as distinct
        from the current (refresh-scoped) suspicion."""
        return self._suspicion_counts.get(name, 0)

    def suspected_set(self) -> set:
        """Bulk form of :meth:`is_suspected` for the advisor's per-tick
        reassertion loop: every name the broker believes suspected among
        the last snapshot's entries, plus dispatch burns since.  A name
        absent from the snapshot entirely is ALSO believed down — pair
        this set with a membership test on ``view(t).entries``."""
        if self._snapshot is None:
            return set()
        out = set(self._local_suspects)
        for name, entry in self._snapshot.entries.items():
            if entry.suspected:
                out.add(name)
        return out

    def is_suspected(self, name: str) -> bool:
        """The broker's *belief* about ``name``: absent from the last
        snapshot (departed), advertised-suspected in it, or burned by a
        dispatch since."""
        if self._snapshot is None:
            return False
        if name in self._local_suspects:
            return True
        entry = self._snapshot.entries.get(name)
        return entry is None or entry.suspected

    def snapshot_age(self, t: float) -> Optional[float]:
        """Seconds the current snapshot has been stale at ``t`` (None
        before the first fetch)."""
        if self._snapshot is None:
            return None
        return max(0.0, t - self._snapshot.taken_at)
