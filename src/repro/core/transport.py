"""Transport layer for the sharded grid: loopback and per-domain
OS processes, both speaking ``repro.core.protocol``.

Two implementations of one contract (``request(msg) -> reply``):

* :class:`LoopbackTransport` — in-process, delivered synchronously on
  the sim clock.  Every message still round-trips through the full
  ``encode -> stable_dumps -> parse`` codec, so the loopback proves the
  wire encoding is lossless while default-knob runs stay byte-identical
  to the direct-call goldens (canonical JSON floats are exact).

* :class:`DomainProcess` — one OS process per administrative domain
  (trade server + its resource slice + its GIS branch), spoken to over
  a pipe carrying the same canonical bytes.  The domain journals every
  state-mutating message; SIGKILL it mid-run, restart it on the same
  journal, and the book (and every booked settlement) is rebuilt
  exactly — reservation awards and settlements are keyed, so replays
  and retries are idempotent.

Broker-side, :class:`RemoteTradeServer` and :class:`WireFederation`
present the exact ``TradeServer``/``TradeFederation`` surface, so the
scheduler (``negotiate_contract``), the auction house and the GIS
client run unchanged whether their counterparty is an object, a
loopback endpoint, or another process.
"""
from __future__ import annotations

import dataclasses
import math
import multiprocessing
import os
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import protocol as P
from repro.core.accounting import GridBank
from repro.core.economy import (AdmissionError, Bid, PriceSchedule,
                                Reservation, TradeServer)
from repro.core.gis import GISEntry, GridInformationService
from repro.core.persistence import Journal, replay
from repro.core.resources import ResourceDirectory, ResourceSpec

HOUR = 3600.0


class TransportError(ConnectionError):
    """The counterparty is gone (crashed domain, closed pipe)."""


def _spec_to_wire(spec: ResourceSpec) -> P.WireSpec:
    return P.WireSpec(**dataclasses.asdict(spec))


def _spec_from_wire(w: P.WireSpec) -> ResourceSpec:
    return ResourceSpec(**dataclasses.asdict(w))


def _res_to_wire(r: Reservation) -> P.WireReservation:
    return P.WireReservation(resource=r.resource, user=r.user,
                             start=r.start, end=r.end,
                             locked_price=r.locked_price,
                             reservation_id=r.reservation_id)


def _res_from_wire(w: P.WireReservation) -> Reservation:
    return Reservation(resource=w.resource, user=w.user, start=w.start,
                       end=w.end, locked_price=w.locked_price,
                       reservation_id=w.reservation_id)


# ---------------------------------------------------------------------------
# domain endpoint: the server side of the protocol
# ---------------------------------------------------------------------------

class DomainEndpoint:
    """One administrative domain's protocol handler.

    Wraps a real ``TradeServer`` (and optionally that domain's GIS
    branch): every wire message lowers to the same method call the
    in-process grid makes, so domain behavior is identical under every
    transport.  With a ``journal_path``, every state-mutating message
    (reserve / cancel / transfer / restride / settle) is journaled
    after it applies; constructing an endpoint on an existing journal
    replays it — the crash/recovery story."""

    def __init__(self, server: TradeServer,
                 gis: Optional[GridInformationService] = None,
                 journal_path: Optional[str] = None):
        self.server = server
        self.gis = gis
        self.requests = 0
        # exactly-once keys: awarded reservations by request_id and a
        # domain-local revenue book keyed by settlement_id
        self._awards: Dict[str, Reservation] = {}
        self.bank = GridBank()
        self._revenue_rows: List[Tuple[str, str, str, float, str, float]] \
            = []
        self.journal: Optional[Journal] = None
        if journal_path is not None:
            self._replay(journal_path)
            self.journal = Journal(journal_path)

    # -- crash/recovery -------------------------------------------------
    def _replay(self, path: str) -> None:
        """Rebuild the reservation book and the settlement ledger from
        the journal — admission checks are NOT re-run (the journal
        records what was admitted), and rid counters resume exactly."""
        server = self.server
        for ev in replay(path):
            kind = ev.get("kind")
            if kind == "reserve":
                r = Reservation(resource=ev["resource"], user=ev["user"],
                                start=ev["start"], end=ev["end"],
                                locked_price=ev["locked_price"],
                                reservation_id=ev["rid"])
                server.reservations.append(r)
                server._next_rid = ev["next_rid"]
                server.book_version += 1
                self._awards[ev["request_id"]] = r
            elif kind == "cancel":
                server.cancel(ev["rid"])
            elif kind == "transfer":
                r = server.find_reservation(ev["rid"])
                if r is not None:
                    r.user = ev["buyer"]
                    server.book_version += 1
            elif kind == "restride":
                server._next_rid = ev["next_rid"]
                server._rid_step = ev["rid_step"]
            elif kind == "settle":
                if self.bank.record_once(
                        ev["settlement_id"], t=ev["t"], user=ev["user"],
                        owner=ev["owner"], resource=ev["resource"],
                        amount=ev["amount"], kind=ev["entry_kind"]):
                    self._revenue_rows.append(
                        (ev["settlement_id"], ev["user"], ev["resource"],
                         ev["amount"], ev["entry_kind"], ev["t"]))

    def _log(self, kind: str, **fields: Any) -> None:
        if self.journal is not None:
            self.journal.append(kind, **fields)

    def close(self) -> None:
        if self.journal is not None:
            self.journal.close()

    # -- dispatch --------------------------------------------------------
    def handle(self, msg: P.Message) -> P.Message:
        self.requests += 1
        try:
            return self._dispatch(msg)
        except AdmissionError as e:
            return P.ErrorReply(error=str(e), admission=True)
        except P.ProtocolError:
            raise
        except Exception as e:                    # surface, don't kill
            return P.ErrorReply(error=f"{type(e).__name__}: {e}")

    def _dispatch(self, msg: P.Message) -> P.Message:
        s = self.server
        if isinstance(msg, P.QuoteRequest):
            price = (s.forward_quote(msg.resource, msg.t, msg.user)
                     if msg.forward else s.quote(msg.resource, msg.t,
                                                 msg.user))
            return P.PriceReply(price=price, book_version=s.book_version)
        if isinstance(msg, P.SolicitRequest):
            est = msg.est_seconds
            bids = s.solicit_bids(
                msg.t, msg.user,
                lambda spec: est.get(spec.name, msg.default_est))
            return P.BidsReply(
                bids=tuple(P.WireBid(**dataclasses.asdict(b))
                           for b in bids),
                book_version=s.book_version)
        if isinstance(msg, P.ReserveRequest):
            prior = self._awards.get(msg.request_id)
            if prior is not None:       # replayed/retried award
                return P.ReserveReply(ok=True,
                                      reservation=_res_to_wire(prior),
                                      book_version=s.book_version)
            r = s.reserve(msg.resource, msg.user, msg.start, msg.end,
                          msg.t, locked_price=msg.locked_price)
            self._awards[msg.request_id] = r
            self._log("reserve", request_id=msg.request_id,
                      rid=r.reservation_id, resource=r.resource,
                      user=r.user, start=r.start, end=r.end,
                      locked_price=r.locked_price, next_rid=s._next_rid)
            return P.ReserveReply(ok=True, reservation=_res_to_wire(r),
                                  book_version=s.book_version)
        if isinstance(msg, P.CancelRequest):
            ok = s.cancel(msg.reservation_id)
            if ok:
                self._log("cancel", rid=msg.reservation_id)
            return P.OkReply(ok=ok, book_version=s.book_version)
        if isinstance(msg, P.TransferRequest):
            r = s.transfer(msg.reservation_id, msg.buyer, msg.t)
            if r is None:
                return P.TransferReply(ok=False, error="gone",
                                       book_version=s.book_version)
            self._log("transfer", rid=msg.reservation_id, buyer=msg.buyer)
            return P.TransferReply(ok=True, reservation=_res_to_wire(r),
                                   book_version=s.book_version)
        if isinstance(msg, P.FindRequest):
            r = s.find_reservation(msg.reservation_id)
            return P.ReserveReply(
                ok=r is not None,
                reservation=None if r is None else _res_to_wire(r),
                book_version=s.book_version)
        if isinstance(msg, P.BookRequest):
            return self._book(msg)
        if isinstance(msg, P.StatusRequest):
            st = s.directory.status(msg.resource)
            return P.StatusReply(up=st.up, running=st.running,
                                 queued=st.queued, version=st.version)
        if isinstance(msg, P.SyncRequest):
            return P.SyncReply(
                site=s.site or "",
                specs=tuple(_spec_to_wire(s.directory.spec(n))
                            for n in s.resources()),
                bid_validity=s.bid_validity,
                book_version=s.book_version,
                membership_version=s.membership_version,
                next_rid=s._next_rid,
                rid_step=s._rid_step)
        if isinstance(msg, P.RestrideRequest):
            s._next_rid = msg.next_rid
            s._rid_step = msg.rid_step
            self._log("restride", next_rid=msg.next_rid,
                      rid_step=msg.rid_step)
            return P.OkReply(ok=True, book_version=s.book_version)
        if isinstance(msg, P.SettleRequest):
            fresh = self.bank.record_once(
                msg.settlement_id, t=msg.t, user=msg.user,
                owner=msg.owner, resource=msg.resource,
                amount=msg.amount, kind=msg.kind)
            if fresh:
                self._revenue_rows.append(
                    (msg.settlement_id, msg.user, msg.resource,
                     msg.amount, msg.kind, msg.t))
                self._log("settle", settlement_id=msg.settlement_id,
                          t=msg.t, user=msg.user, owner=msg.owner,
                          resource=msg.resource, amount=msg.amount,
                          entry_kind=msg.kind)
            return P.SettleReply(ok=True, duplicate=not fresh)
        if isinstance(msg, P.RevenueRequest):
            return P.RevenueReply(entries=tuple(self._revenue_rows))
        if self.gis is not None:
            reply = self._gis(msg)
            if reply is not None:
                return reply
        return P.ErrorReply(
            error=f"unhandled message {msg.wire_kind!r} at domain "
                  f"{s.site!r}")

    def _book(self, msg: P.BookRequest) -> P.Message:
        s = self.server
        op = msg.op
        if op == "reserved_price":
            p = s.reserved_price(msg.resource, msg.user, msg.t)
            return P.BookReply(price=p, book_version=s.book_version)
        if op == "reserved_price_list":
            ps = s.reserved_price_list(msg.resource, msg.user, msg.t)
            return P.BookReply(prices=tuple(ps),
                               book_version=s.book_version)
        if op == "reserved_slots":
            n = s.reserved_slots(msg.resource, msg.user, msg.t)
            return P.BookReply(slots=n, book_version=s.book_version)
        if op == "effective_price":
            return P.BookReply(price=s.effective_price(msg.resource,
                                                       msg.user, msg.t),
                               book_version=s.book_version)
        if op == "honored_price":
            return P.BookReply(
                price=s.honored_price(msg.resource, msg.user,
                                      msg.sealed_price, msg.sealed_at,
                                      msg.t),
                book_version=s.book_version)
        if op == "reservable_slots":
            return P.BookReply(slots=s.reservable_slots(msg.resource,
                                                        msg.start,
                                                        msg.end),
                               book_version=s.book_version)
        if op == "utilization":
            return P.BookReply(price=s.utilization(msg.resource),
                               book_version=s.book_version)
        if op == "resource_up":
            return P.BookReply(slots=int(s.resource_up(msg.resource)),
                               book_version=s.book_version)
        if op == "version":
            return P.BookReply(book_version=s.book_version)
        return P.ErrorReply(error=f"unknown book op {op!r}")

    def _gis(self, msg: P.Message) -> Optional[P.Message]:
        g = self.gis
        if isinstance(msg, P.GISRegister):
            g.register(_spec_from_wire(msg.spec), msg.t)
            return P.OkReply(ok=True)
        if isinstance(msg, P.GISDeregister):
            g.deregister(msg.name, msg.t)
            return P.OkReply(ok=True)
        if isinstance(msg, P.GISHeartbeat):
            g.heartbeat(msg.name, msg.t)
            return P.OkReply(ok=True)
        if isinstance(msg, P.GISPump):
            g.pump_heartbeats(msg.t)
            return P.OkReply(ok=True)
        if isinstance(msg, P.GISQuery):
            entries = g.query(
                msg.t, user=msg.user, level=msg.level, within=msg.within,
                min_chips=msg.min_chips, max_price=msg.max_price,
                include_suspected=msg.include_suspected)
            return P.GISQueryReply(
                entries=tuple(P.WireGISEntry(**e.to_wire())
                              for e in entries),
                version=g.version)
        return None


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------

class LoopbackTransport:
    """Synchronous in-process delivery on the sim clock.

    Every message (and reply) still crosses the full canonical-JSON
    codec, so a loopback run certifies the protocol encoding while
    behaving — byte-for-byte — like the direct-call grid."""

    def __init__(self, endpoint: DomainEndpoint, codec: bool = True):
        self.endpoint = endpoint
        self.codec = codec
        self.messages = 0
        self.bytes_out = 0
        self.bytes_in = 0

    def request(self, msg: P.Message) -> P.Message:
        self.messages += 1
        if self.codec:
            wire = P.dumps(msg)
            self.bytes_out += len(wire)
            reply = self.endpoint.handle(P.loads(wire))
            back = P.dumps(reply)
            self.bytes_in += len(back)
            return P.loads(back)
        return self.endpoint.handle(msg)

    def close(self) -> None:
        self.endpoint.close()


@dataclasses.dataclass(frozen=True)
class DomainConfig:
    """Everything a domain process needs to build its world: picklable,
    and sufficient to REBUILD it identically after a crash (plus the
    journal, which carries the state the config cannot)."""
    site: str
    specs: Tuple[ResourceSpec, ...]
    journal_path: Optional[str] = None
    demand_elasticity: float = 0.0
    spot_amplitude: float = 0.0
    max_reservations_per_user: Optional[int] = None
    bid_validity: float = HOUR
    heartbeat_interval: float = 300.0
    gis_suspect_after: int = 2
    run_gis: bool = True


def build_domain(cfg: DomainConfig) -> DomainEndpoint:
    """Construct one administrative domain from its config: directory
    slice, price schedules, trade server, GIS branch — the same objects
    the in-process marketplace builds, owned by one process."""
    directory = ResourceDirectory()
    for spec in cfg.specs:
        directory.register(spec)
    schedules = {spec.name: PriceSchedule(
        spec, demand_elasticity=cfg.demand_elasticity,
        spot_amplitude=cfg.spot_amplitude) for spec in cfg.specs}
    server = TradeServer(
        directory, schedules, site=cfg.site,
        max_reservations_per_user=cfg.max_reservations_per_user,
        bid_validity=cfg.bid_validity)
    gis = None
    if cfg.run_gis:
        gis = GridInformationService(
            directory, heartbeat_interval=cfg.heartbeat_interval,
            suspect_after=cfg.gis_suspect_after,
            price_fn=lambda name, t: server.forward_quote(name, t))
        for spec in cfg.specs:
            gis.register(spec, 0.0)
    return DomainEndpoint(server, gis=gis,
                          journal_path=cfg.journal_path)


def _domain_serve(conn, cfg: DomainConfig) -> None:
    """Domain process main loop: canonical bytes in, canonical bytes
    out, until shutdown or the pipe dies."""
    endpoint = build_domain(cfg)
    try:
        while True:
            try:
                data = conn.recv_bytes()
            except (EOFError, OSError):
                break
            try:
                msg = P.loads(data.decode("utf-8"))
            except P.ProtocolError as e:
                conn.send_bytes(P.dumps(P.ErrorReply(
                    error=f"protocol: {e}")).encode("utf-8"))
                continue
            if isinstance(msg, P.ShutdownRequest):
                conn.send_bytes(P.dumps(P.OkReply(ok=True))
                                .encode("utf-8"))
                break
            reply = endpoint.handle(msg)
            conn.send_bytes(P.dumps(reply).encode("utf-8"))
    finally:
        endpoint.close()
        conn.close()


class DomainProcess:
    """One administrative domain as its own OS process.

    ``request`` sends canonical bytes down a pipe and blocks for the
    reply.  ``kill`` is a real SIGKILL (the crash test's hammer);
    ``restart`` spawns a fresh process on the SAME journal, which
    replays it — reservations, rid counters and booked settlements come
    back exactly."""

    def __init__(self, cfg: DomainConfig,
                 ctx: Optional[multiprocessing.context.BaseContext] = None):
        self.cfg = cfg
        self._ctx = ctx or multiprocessing.get_context("fork")
        self._proc: Optional[multiprocessing.Process] = None
        self._conn = None
        self.restarts = -1
        self.start()

    @property
    def site(self) -> str:
        return self.cfg.site

    def start(self) -> None:
        if self._proc is not None and self._proc.is_alive():
            raise RuntimeError(f"domain {self.site!r} already running")
        parent, child = self._ctx.Pipe()
        self._proc = self._ctx.Process(
            target=_domain_serve, args=(child, self.cfg), daemon=True)
        self._proc.start()
        child.close()
        self._conn = parent
        self.restarts += 1

    def request(self, msg: P.Message) -> P.Message:
        if self._conn is None:
            raise TransportError(f"domain {self.site!r} is not running")
        try:
            self._conn.send_bytes(P.dumps(msg).encode("utf-8"))
            data = self._conn.recv_bytes()
        except (EOFError, OSError, BrokenPipeError) as e:
            raise TransportError(
                f"domain {self.site!r} died mid-request: {e}")
        return P.loads(data.decode("utf-8"))

    def alive(self) -> bool:
        return self._proc is not None and self._proc.is_alive()

    def kill(self) -> None:
        """SIGKILL — no goodbye, no flush beyond what fsync already
        guaranteed.  This is the crash the journal exists for."""
        if self._proc is not None:
            self._proc.kill()
            self._proc.join(timeout=10.0)
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def restart(self) -> None:
        self.kill()
        self._proc = None
        self.start()

    def stop(self) -> None:
        """Orderly shutdown (flush + close), falling back to kill."""
        if self._conn is not None and self.alive():
            try:
                self.request(P.ShutdownRequest(reason="stop"))
            except TransportError:
                pass
        self.kill()

    def close(self) -> None:
        self.stop()


# ---------------------------------------------------------------------------
# broker-side proxies: the TradeServer surface over a transport
# ---------------------------------------------------------------------------

class RemoteTradeServer:
    """The ``TradeServer`` public surface, spoken over a transport.

    Brokers, the auction house and the secondary market call the same
    methods with the same types; each lowers to one protocol message.
    The broker's ``directory`` is a spec mirror fetched at sync time
    (shared across proxies, so the federation sees one namespace)."""

    def __init__(self, transport,
                 directory: Optional[ResourceDirectory] = None):
        self._transport = transport
        sync = self._req(P.SyncRequest())
        self.site: Optional[str] = sync.site or None
        self.bid_validity = sync.bid_validity
        self.book_version = sync.book_version
        self.membership_version = sync.membership_version
        self._next_rid = sync.next_rid
        self._rid_step = sync.rid_step
        self.directory = directory if directory is not None \
            else ResourceDirectory()
        for w in sync.specs:
            spec = _spec_from_wire(w)
            if spec.name not in self.directory:
                self.directory.register(spec)
        # loopback endpoints share the process: schedules stay readable
        # (the auction house's discovery nudge); across a real process
        # boundary they live domain-side and this mapping is empty
        ep = getattr(transport, "endpoint", None)
        self.schedules = ep.server.schedules if ep is not None else {}
        self._secondary = None

    # the resale book is read domain-side (TradeServer.solicit_bids
    # merges its listings into tenders): attaching it to a loopback
    # proxy must attach it to the real server behind the endpoint
    @property
    def secondary(self):
        ep = getattr(self._transport, "endpoint", None)
        return ep.server.secondary if ep is not None else self._secondary

    @secondary.setter
    def secondary(self, value) -> None:
        ep = getattr(self._transport, "endpoint", None)
        if ep is not None:
            ep.server.secondary = value
        self._secondary = value

    # -- plumbing --------------------------------------------------------
    def _req(self, msg: P.Message) -> P.Message:
        reply = self._transport.request(msg)
        if isinstance(reply, P.ErrorReply):
            if reply.admission:
                raise AdmissionError(reply.error)
            raise TransportError(reply.error)
        bv = getattr(reply, "book_version", None)
        if bv is not None:
            self.book_version = bv
        return reply

    # -- TradeServer surface ----------------------------------------------
    def resources(self) -> List[str]:
        return [n for n in self.directory.all_names()
                if self.site is None
                or self.directory.spec(n).site == self.site]

    def resource_up(self, resource: str) -> bool:
        r = self._req(P.BookRequest(op="resource_up", resource=resource,
                                    user="", t=0.0))
        return bool(r.slots)

    def price_version(self, resource: str) -> int:
        # always a wire read: broker quote caches key on this, and only
        # the domain knows whether a rival moved the book since
        self._req(P.BookRequest(op="version", resource=resource,
                                user="", t=0.0))
        return self.book_version

    def utilization(self, resource: str) -> float:
        return self._req(P.BookRequest(op="utilization",
                                       resource=resource, user="",
                                       t=0.0)).price

    def quote(self, resource: str, t: float, user: str = "") -> float:
        return self._req(P.QuoteRequest(resource=resource, t=t,
                                        user=user)).price

    def forward_quote(self, resource: str, t: float,
                      user: str = "") -> float:
        return self._req(P.QuoteRequest(resource=resource, t=t, user=user,
                                        forward=True)).price

    def solicit_bids(self, t: float, user: str,
                     est_job_seconds: Callable[[ResourceSpec], float]
                     ) -> List[Bid]:
        # the callable can't cross the wire: evaluate it against the
        # spec mirror and ship per-resource estimates
        est = {n: est_job_seconds(self.directory.spec(n))
               for n in self.resources()}
        reply = self._req(P.SolicitRequest(t=t, user=user,
                                           est_seconds=est))
        return [Bid(**dataclasses.asdict(w)) for w in reply.bids]

    def reservable_slots(self, resource: str, start: float, end: float
                         ) -> int:
        return self._req(P.BookRequest(op="reservable_slots",
                                       resource=resource, user="", t=0.0,
                                       start=start, end=end)).slots

    def reserve(self, resource: str, user: str, start: float, end: float,
                t: float, locked_price: Optional[float] = None
                ) -> Reservation:
        self._reqseq = getattr(self, "_reqseq", 0) + 1
        reply = self._req(P.ReserveRequest(
            request_id=f"{user}:{self.site}:{self._reqseq}",
            resource=resource, user=user, start=start, end=end, t=t,
            locked_price=locked_price))
        r = _res_from_wire(reply.reservation)
        # mirror the rid stream (the federation's restride arithmetic
        # reads it, exactly as it reads a local server's counter)
        self._next_rid = r.reservation_id + self._rid_step
        return r

    def cancel(self, reservation_id: int) -> bool:
        return self._req(P.CancelRequest(
            reservation_id=reservation_id)).ok

    def transfer(self, reservation_id: int, buyer: str, t: float
                 ) -> Optional[Reservation]:
        reply = self._req(P.TransferRequest(reservation_id=reservation_id,
                                            buyer=buyer, t=t))
        return _res_from_wire(reply.reservation) if reply.ok else None

    def find_reservation(self, reservation_id: int
                         ) -> Optional[Reservation]:
        reply = self._req(P.FindRequest(reservation_id=reservation_id))
        return _res_from_wire(reply.reservation) if reply.ok else None

    def reserved_price(self, resource: str, user: str, t: float
                       ) -> Optional[float]:
        return self._req(P.BookRequest(op="reserved_price",
                                       resource=resource, user=user,
                                       t=t)).price

    def reserved_slots(self, resource: str, user: str, t: float) -> int:
        return self._req(P.BookRequest(op="reserved_slots",
                                       resource=resource, user=user,
                                       t=t)).slots

    def reserved_price_list(self, resource: str, user: str, t: float
                            ) -> List[float]:
        return list(self._req(P.BookRequest(op="reserved_price_list",
                                            resource=resource, user=user,
                                            t=t)).prices)

    def effective_price(self, resource: str, user: str, t: float) -> float:
        return self._req(P.BookRequest(op="effective_price",
                                       resource=resource, user=user,
                                       t=t)).price

    def honored_price(self, resource: str, user: str, sealed_price: float,
                      sealed_at: float, t: float) -> float:
        return self._req(P.BookRequest(op="honored_price",
                                       resource=resource, user=user, t=t,
                                       sealed_price=sealed_price,
                                       sealed_at=sealed_at)).price

    def settle(self, settlement_id: str, *, t: float, user: str,
               resource: str, amount: float,
               kind: str = "settle") -> P.SettleReply:
        """GridBank settlement pushed to the owning domain's ledger —
        idempotent under ``settlement_id``."""
        return self._transport.request(P.SettleRequest(
            settlement_id=settlement_id, t=t, user=user,
            owner=self.site or "", resource=resource, amount=amount,
            kind=kind))

    def revenue_rows(self) -> List[Tuple]:
        """The domain's booked settlement rows — the producer side of
        the exact reconciliation audit."""
        return [tuple(r) for r in
                self._req(P.RevenueRequest(owner=self.site or "")).entries]

    def restride(self, next_rid: int, rid_step: int) -> None:
        self._req(P.RestrideRequest(next_rid=next_rid, rid_step=rid_step))
        self._next_rid = next_rid
        self._rid_step = rid_step

    @property
    def reservations(self) -> List[Reservation]:
        raise NotImplementedError(
            "a remote book is not enumerable; use find_reservation "
            "(the secondary market's locate path) or reserved_* reads")


class WireFederation:
    """``TradeFederation``'s public surface over remote servers.

    The broker-facing contract — sorted ``servers``, merged price-sorted
    ``solicit_bids``, routed ``reserve``/``cancel``/price reads,
    federation-unique rid striding, membership churn with departed
    read-only boards — is re-implemented over proxies, so scheduler and
    auction code cannot tell the difference."""

    # batched quote boards read schedules/status objects directly;
    # a wire federation quotes through messages instead
    supports_board = False

    def __init__(self, servers: Dict[str, RemoteTradeServer],
                 directory: Optional[ResourceDirectory] = None,
                 restride: bool = True):
        if not servers:
            raise ValueError("federation needs at least one trade server")
        self.servers: Dict[str, RemoteTradeServer] = dict(sorted(
            servers.items()))
        self.directory = directory if directory is not None \
            else next(iter(self.servers.values())).directory
        self.bid_validity = max(s.bid_validity
                                for s in self.servers.values())
        self._departed: Dict[str, RemoteTradeServer] = {}
        self._rid_floor = 1
        self.membership_version = 0
        self._board = None
        # restride=False: the domains were already strided (a wrapped
        # in-process federation) — re-striding would move the counters
        # forward and the wire grid would issue different ids than the
        # direct one
        if restride:
            self._restride()

    def _restride(self) -> None:
        # identical arithmetic to TradeFederation._restride, pushed to
        # each domain as an explicit protocol message (and journaled
        # there, so a crashed domain resumes its residue class exactly)
        n = len(self.servers)
        if n == 0:
            return
        start = max([self._rid_floor]
                    + [s._next_rid for s in self.servers.values()]
                    + [s._next_rid for s in self._departed.values()])
        self._rid_floor = start
        for i, server in enumerate(self.servers.values()):
            server.restride(start + (i + 1 - start) % n, n)

    # -- membership churn ----------------------------------------------
    def remove_server(self, site: str) -> RemoteTradeServer:
        server = self.servers.pop(site)
        self._departed[site] = server
        self.membership_version += 1
        if self.servers:
            self.bid_validity = max(s.bid_validity
                                    for s in self.servers.values())
        return server

    def add_server(self, site: str, server) -> None:
        """A domain (re)joined.  Accepts a ready proxy, or a plain
        ``TradeServer`` which is wrapped in a loopback endpoint — the
        marketplace's churn rejoin path stays a one-liner."""
        if site in self.servers:
            raise ValueError(f"trade server for {site!r} already federated")
        if not isinstance(server, RemoteTradeServer):
            server = RemoteTradeServer(
                LoopbackTransport(DomainEndpoint(server)),
                directory=self.directory)
        old = self._departed.pop(site, None)
        if old is not None:
            self._rid_floor = max(self._rid_floor, old._next_rid)
        self.servers[site] = server
        self.servers = dict(sorted(self.servers.items()))
        self.bid_validity = max(s.bid_validity
                                for s in self.servers.values())
        self.membership_version += 1
        self._restride()

    # -- routing ---------------------------------------------------------
    def sites(self) -> List[str]:
        return list(self.servers)

    def departed_sites(self) -> List[str]:
        return sorted(self._departed)

    def server_for(self, resource: str) -> RemoteTradeServer:
        site = self.directory.spec(resource).site
        if site in self.servers:
            return self.servers[site]
        return self._departed[site]

    # -- single-server interface (delegated) ------------------------------
    def price_version(self, resource: str) -> int:
        return self.server_for(resource).price_version(resource)

    def utilization(self, resource: str) -> float:
        return self.server_for(resource).utilization(resource)

    def quote(self, resource: str, t: float, user: str = "") -> float:
        return self.server_for(resource).quote(resource, t, user)

    def forward_quote(self, resource: str, t: float,
                      user: str = "") -> float:
        return self.server_for(resource).forward_quote(resource, t, user)

    def solicit_bids(self, t: float, user: str,
                     est_job_seconds: Callable[[ResourceSpec], float]
                     ) -> List[Bid]:
        bids: List[Bid] = []
        for server in self.servers.values():
            bids.extend(server.solicit_bids(t, user, est_job_seconds))
        return sorted(bids, key=lambda b: (b.chip_hour_price, b.resource))

    def reserve(self, resource: str, user: str, start: float, end: float,
                t: float, locked_price: Optional[float] = None
                ) -> Reservation:
        site = self.directory.spec(resource).site
        if site not in self.servers:
            raise AdmissionError(
                f"{resource}: domain {site!r} has left the grid — "
                f"no reservations until it rejoins")
        return self.servers[site].reserve(
            resource, user, start, end, t, locked_price=locked_price)

    def cancel(self, reservation_id: int) -> bool:
        return any(s.cancel(reservation_id)
                   for s in list(self.servers.values())
                   + list(self._departed.values()))

    def find_reservation(self, reservation_id: int
                         ) -> Optional[Reservation]:
        for s in list(self.servers.values()) \
                + list(self._departed.values()):
            r = s.find_reservation(reservation_id)
            if r is not None:
                return r
        return None

    def reserved_price(self, resource: str, user: str, t: float
                       ) -> Optional[float]:
        return self.server_for(resource).reserved_price(resource, user, t)

    def reserved_slots(self, resource: str, user: str, t: float) -> int:
        return self.server_for(resource).reserved_slots(resource, user, t)

    def reserved_price_list(self, resource: str, user: str, t: float
                            ) -> List[float]:
        return self.server_for(resource).reserved_price_list(
            resource, user, t)

    def effective_price(self, resource: str, user: str, t: float) -> float:
        return self.server_for(resource).effective_price(resource, user, t)

    def honored_price(self, resource: str, user: str, sealed_price: float,
                      sealed_at: float, t: float) -> float:
        return self.server_for(resource).honored_price(
            resource, user, sealed_price, sealed_at, t)


class RemoteGIS:
    """Broker-side GIS over domain transports: each administrative
    domain answers for its own branch; queries merge the branches into
    the one global view ``GISClient`` expects.  Spec objects come from
    the shared mirror, so entries are real ``GISEntry`` values and the
    client's snapshot machinery runs unchanged."""

    def __init__(self, transports: Dict[str, Any],
                 directory: ResourceDirectory):
        self.transports = dict(sorted(transports.items()))
        self.directory = directory
        self.version = 0
        self.queries = 0

    def query(self, t: float, *, user: str = "", level: str = "global",
              within: Optional[str] = None, min_chips: int = 0,
              max_price: float = math.inf,
              include_suspected: bool = False) -> List[GISEntry]:
        self.queries += 1
        entries: List[GISEntry] = []
        for site, tr in self.transports.items():
            if level != "global" and within is not None \
                    and not str(within).startswith(site):
                continue
            try:
                reply = tr.request(P.GISQuery(
                    t=t, user=user, level=level, within=within,
                    min_chips=min_chips, max_price=max_price,
                    include_suspected=include_suspected))
            except TransportError:
                continue        # a dead domain answers no queries
            if isinstance(reply, P.ErrorReply):
                continue
            self.version = max(self.version, reply.version)
            for w in reply.entries:
                if w.name in self.directory:
                    entries.append(GISEntry.from_wire(
                        dataclasses.asdict(w),
                        self.directory.spec(w.name)))
        return sorted(entries, key=lambda e: e.name)

    def pump(self, t: float) -> int:
        """Ask every live domain to beat its branch's heartbeats —
        liveness is now a real network phenomenon: a crashed domain
        simply goes silent and its resources age into suspicion."""
        n = 0
        for tr in self.transports.values():
            try:
                tr.request(P.GISPump(t=t))
                n += 1
            except TransportError:
                continue
        return n


# ---------------------------------------------------------------------------
# wiring helpers
# ---------------------------------------------------------------------------

def wrap_federation_loopback(fed, codec: bool = True) -> WireFederation:
    """Re-plumb an in-process ``TradeFederation`` through the protocol:
    every server gets a loopback endpoint + proxy, and the federation
    surface is rebuilt over them.  Same objects, same clock, same
    directory — but every trade now crosses the canonical codec.  This
    is the transport the default marketplace runs when asked for
    ``wire="loopback"`` (and must stay byte-identical to direct)."""
    proxies = {}
    for site, server in fed.servers.items():
        proxies[site] = RemoteTradeServer(
            LoopbackTransport(DomainEndpoint(server), codec=codec),
            directory=fed.directory)
    # the wrapped federation already strided its counters: carry its
    # id arithmetic over verbatim instead of striding a second time
    wf = WireFederation(proxies, directory=fed.directory, restride=False)
    wf._rid_floor = fed._rid_floor
    wf.membership_version = fed.membership_version
    return wf


def spawn_domains(configs: List[DomainConfig]
                  ) -> Tuple[Dict[str, DomainProcess], WireFederation,
                             RemoteGIS]:
    """Launch one OS process per administrative domain and return the
    broker-side view: the process handles, a wire federation over them,
    and the merged remote GIS."""
    procs = {cfg.site: DomainProcess(cfg) for cfg in configs}
    directory = ResourceDirectory()
    servers = {site: RemoteTradeServer(proc, directory=directory)
               for site, proc in procs.items()}
    fed = WireFederation(servers, directory=directory)
    gis = RemoteGIS({site: proc for site, proc in procs.items()},
                    directory)
    return procs, fed, gis
