"""Append-only experiment journal (the paper: "the parametric engine ...
ensures that the state is recorded in persistent storage. This allows the
experiment to be restarted if the node running Nimrod goes down").

Events are JSON lines, fsync'd on write.  Restart = replay.  A torn final
line (crash mid-write) is detected and dropped.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterator, List, Optional


def stable_dumps(obj: Any) -> str:
    """Canonical JSON for journals and trace exports: sorted keys and
    exact (shortest round-trip) float reprs, so two same-seed runs
    serialize byte-identically.  The journal has always written this
    format; the telemetry JSONL exporter shares it."""
    return json.dumps(obj, sort_keys=True)


class Journal:
    def __init__(self, path: str, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "a", encoding="utf-8")
        self._seq = self._count_existing()

    def _count_existing(self) -> int:
        n = 0
        if os.path.exists(self.path):
            for _ in replay(self.path):
                n += 1
        return n

    def append(self, kind: str, **fields: Any) -> Dict[str, Any]:
        ev = {"seq": self._seq, "kind": kind, **fields}
        self._f.write(stable_dumps(ev) + "\n")
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self._seq += 1
        return ev

    def close(self) -> None:
        self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def replay(path: str) -> Iterator[Dict[str, Any]]:
    """Yield events; silently drop a torn trailing line."""
    if not os.path.exists(path):
        return
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                yield json.loads(line)
            except json.JSONDecodeError:
                return  # torn tail — crash mid-write; ignore the fragment


def load_events(path: str) -> List[Dict[str, Any]]:
    return list(replay(path))
