"""Append-only experiment journal (the paper: "the parametric engine ...
ensures that the state is recorded in persistent storage. This allows the
experiment to be restarted if the node running Nimrod goes down").

Events are JSON lines, fsync'd on write.  Restart = replay.  A torn final
line (crash mid-write) is detected and dropped.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterator, List, Optional


def stable_dumps(obj: Any) -> str:
    """Canonical JSON for journals and trace exports: sorted keys and
    exact (shortest round-trip) float reprs, so two same-seed runs
    serialize byte-identically.  The journal has always written this
    format; the telemetry JSONL exporter shares it."""
    return json.dumps(obj, sort_keys=True)


# tail window read when recovering ``seq`` on reopen; grows geometrically
# if the last well-formed line is longer than this (rare: one event)
_TAIL_BLOCK = 64 * 1024


def _recover_tail(path: str) -> int:
    """Next sequence number, recovered from the LAST well-formed journal
    line — O(tail), not O(file): a month-long experiment's restart must
    not re-parse every event ever written just to learn one integer.

    A torn trailing fragment (crash mid-write) is truncated here, so the
    next append starts a fresh line instead of gluing onto the fragment
    and corrupting an otherwise-good event."""
    try:
        size = os.path.getsize(path)
    except OSError:
        return 0
    if size == 0:
        return 0
    with open(path, "r+b") as f:
        # drop an unterminated trailing fragment first (no final "\n")
        block = min(_TAIL_BLOCK, size)
        while True:
            f.seek(size - block)
            data = f.read(block)
            if b"\n" in data or block == size:
                break
            block = min(block * 2, size)
        if not data.endswith(b"\n"):
            body, nl, _frag = data.rpartition(b"\n")
            if nl:
                size = size - block + len(body) + 1
            else:                       # whole file is one torn fragment
                size = 0
            f.truncate(size)
        if size == 0:
            return 0
        # walk complete lines backwards until one parses with a seq
        block = min(_TAIL_BLOCK, size)
        while True:
            start = size - block
            f.seek(start)
            data = f.read(block)
            lines = data.split(b"\n")
            # the window's first chunk may be a mid-line cut: only trust
            # it when the window starts at the top of the file
            trusted = lines if start == 0 else lines[1:]
            for line in reversed(trusted):
                line = line.strip()
                if not line:
                    continue
                try:
                    ev = json.loads(line)
                except json.JSONDecodeError:
                    continue            # torn-but-terminated line: skip
                if isinstance(ev, dict) and isinstance(ev.get("seq"), int):
                    return ev["seq"] + 1
            if start == 0:
                return 0                # nothing well-formed anywhere
            block = min(block * 2, size)


class Journal:
    def __init__(self, path: str, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._seq = self._count_existing()
        self._f = open(path, "a", encoding="utf-8")

    def _count_existing(self) -> int:
        # recover from the last well-formed line (and clip a torn tail)
        # BEFORE opening the append handle — O(tail) however large the
        # journal has grown
        if os.path.exists(self.path):
            return _recover_tail(self.path)
        return 0

    def append(self, kind: str, **fields: Any) -> Dict[str, Any]:
        ev = {"seq": self._seq, "kind": kind, **fields}
        self._f.write(stable_dumps(ev) + "\n")
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self._seq += 1
        return ev

    def close(self) -> None:
        self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def replay(path: str) -> Iterator[Dict[str, Any]]:
    """Yield events; silently drop a torn trailing line."""
    if not os.path.exists(path):
        return
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                yield json.loads(line)
            except json.JSONDecodeError:
                return  # torn tail — crash mid-write; ignore the fragment


def load_events(path: str) -> List[Dict[str, Any]]:
    return list(replay(path))
