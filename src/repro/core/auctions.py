"""GRACE auction house: negotiated resource trading (paper §7).

Nimrod/G's economy is not just posted prices.  The GRACE follow-up
papers (cs/0111048, cs/0203019) spell out the negotiation protocols a
computational market needs beyond take-it-or-leave-it quotes:

* a **double auction** — brokers submit sealed bids for slot capacity,
  owners submit asks for their idle queues, and periodic clearing rounds
  on the virtual clock cross them at a uniform price, producing
  price-locked ``Contract``s for slot-hours;
* a **contract-net / tender** path — a broker issues a call for
  tenders, every domain's owners counter-offer (price valid for a
  window), and the broker accepts or lets the offer lapse
  (``NegotiationTimeout`` forces a re-solicit, never a stale price).

Trading happens *across per-site trade servers*: each administrative
domain runs its own book, all rounds share one clock, and brokers
arbitrage price differences between domains by steering their bids at
whichever site currently clears cheapest.  Struck contracts are locked
in as advance reservations on the owning domain's trade server, so the
whole settlement path (``TradeServer.effective_price`` →
``NimrodG._handle_done``) automatically charges the negotiated price,
not the spot quote.

Everything is deterministic in virtual time: books iterate in sorted
order, ties break lexically, and no wall clock or RNG is consulted.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

try:
    import numpy as np
except ImportError:              # pragma: no cover - numpy is a CI dep
    np = None

from repro.core.economy import (AdmissionError, TradeFederation, TradeServer)
from repro.core.resources import ResourceDirectory
from repro.core.simulator import Simulator

HOUR = 3600.0


class NegotiationTimeout(Exception):
    """A counter-offer was accepted after its validity window closed."""


@dataclasses.dataclass(frozen=True)
class AuctionBid:
    """A broker's sealed bid into one site's double auction: up to
    ``slots`` queue slots for the next contract window, at no more than
    ``chip_hour_price`` G$ per chip-hour."""
    user: str
    chip_hour_price: float          # limit price (max the broker pays)
    slots: int
    valid_until: float

    def valid_at(self, t: float) -> bool:
        return t <= self.valid_until + 1e-9


@dataclasses.dataclass(frozen=True)
class Ask:
    """An owner's offer into the book: ``slots`` uncommitted slots on
    ``resource`` for the window, at no less than ``chip_hour_price``."""
    resource: str
    site: str
    chip_hour_price: float          # reserve price (min the owner takes)
    slots: int


@dataclasses.dataclass(frozen=True)
class CounterOffer:
    """An owner's reply to a call for tenders (contract-net leg)."""
    resource: str
    site: str
    chip_hour_price: float
    slots: int
    start: float
    end: float
    valid_until: float


@dataclasses.dataclass
class Contract:
    """A struck trade: ``user`` holds ``slots`` on ``resource`` over
    [start, end) at the locked ``chip_hour_price``.  Settlement is
    usage-based (pay for chip time actually held), the lock is carried
    by the advance reservations created at signing."""
    contract_id: int
    user: str
    resource: str
    site: str
    chip_hour_price: float
    slots: int
    start: float
    end: float
    via: str                        # "auction" | "tender"
    reservation_ids: Tuple[int, ...] = ()
    voided_at: Optional[float] = None   # owner broke it (site departed)

    def active_at(self, t: float) -> bool:
        return self.start <= t < self.end and self.voided_at is None

    def max_commitment(self, directory: ResourceDirectory,
                       t: Optional[float] = None) -> float:
        """Worst-case G$ this contract can still cost if every remaining
        slot-hour is consumed — the number budget guards must respect."""
        left = self.end - (self.start if t is None else max(self.start, t))
        if left <= 0:
            return 0.0
        chips = directory.spec(self.resource).chips
        return self.chip_hour_price * chips * self.slots * left / HOUR


@dataclasses.dataclass(frozen=True)
class ClearingRound:
    """Audit record of one site's clearing: what crossed and at what
    uniform price."""
    t: float
    site: str
    clearing_price: float
    matched_slots: int
    n_bids: int
    n_asks: int


class DoubleAuctionBook:
    """One administrative domain's order book.

    Brokers replace (not stack) their standing bid between rounds; asks
    are generated fresh at each clearing from the domain's live state —
    an owner offers exactly the slots not yet promised to anyone over
    the coming window, at a reserve price that discounts the posted
    quote in proportion to idleness (an empty queue earns nothing, so
    its owner sells below the posted price rather than not at all)."""

    def __init__(self, server: TradeServer, *, idle_discount: float = 0.25):
        self.server = server
        self.idle_discount = idle_discount
        self.bids: Dict[str, AuctionBid] = {}

    def submit(self, bid: AuctionBid) -> None:
        self.bids[bid.user] = bid

    def make_asks(self, t: float, window: float) -> List[Ask]:
        asks = []
        for name in self.server.resources():
            # liveness through the server (resource_up), not the
            # directory: across a process boundary only the owning
            # domain knows whether its machine is really up
            if not self.server.resource_up(name):
                continue
            slots = self.server.reservable_slots(name, t, t + window)
            if slots <= 0:
                continue
            # forward capacity is priced off the posted schedule (the
            # spot demand premium is transient), then discounted in
            # proportion to idleness: an empty queue earns nothing, so
            # its owner would rather sell below list than not at all
            util = self.server.utilization(name)
            price = self.server.forward_quote(name, t) * (
                1.0 - self.idle_discount * (1.0 - util))
            asks.append(Ask(resource=name, site=self.server.site or "",
                            chip_hour_price=price, slots=slots))
        return asks

    def clear(self, t: float, window: float
              ) -> Tuple[List[Tuple[str, str, int]], float,
                         ClearingRound]:
        """Uniform-price double auction (k = 1/2).

        Sort bids descending and asks ascending by limit price and
        match the longest unit prefix where demand still out-prices
        supply.  All matched units trade at one clearing price — the
        midpoint of the marginal matched pair, which by construction
        lies within every matched bid's and ask's limits.

        The crossing runs on flat price/cumulative-quantity arrays
        (``clear_book_arrays``); ``clear_book_reference`` is the
        retained unit-expansion clearer, byte-equivalent by the
        differential tests and used when numpy is absent.

        Returns ([(user, resource, slots)], clearing_price, audit).
        """
        live_bids = [b for b in self.bids.values()
                     if b.valid_at(t) and b.slots > 0]
        asks = self.make_asks(t, window)
        self.bids.clear()            # bids are per-round: re-bid or drop out

        clearer = clear_book_arrays if np is not None else \
            clear_book_reference
        trades, price, k, nb, na = clearer(live_bids, asks)
        audit = ClearingRound(t=t, site=self.server.site or "",
                              clearing_price=price, matched_slots=k,
                              n_bids=nb, n_asks=na)
        return trades, price, audit


def clear_book_reference(bids: List[AuctionBid], asks: List[Ask]
                         ) -> Tuple[List[Tuple[str, str, int]], float,
                                    int, int, int]:
    """The scalar reference clearer: expand every order into single-slot
    units and walk the prefix.  O(units) — kept as the behavioral oracle
    for the array clearer (and the no-numpy fallback).

    Returns (trades, clearing_price, matched_units, bid_units, ask_units).
    """
    live_bids = sorted(bids, key=lambda b: (-b.chip_hour_price, b.user))
    bid_units: List[AuctionBid] = []
    for b in live_bids:
        bid_units.extend([b] * b.slots)
    ask_units: List[Ask] = []
    for a in sorted(asks, key=lambda a: (a.chip_hour_price, a.resource)):
        ask_units.extend([a] * a.slots)

    k = 0
    while (k < len(bid_units) and k < len(ask_units)
           and bid_units[k].chip_hour_price
           >= ask_units[k].chip_hour_price - 1e-12):
        k += 1
    if k == 0:
        return [], 0.0, 0, len(bid_units), len(ask_units)
    price = 0.5 * (bid_units[k - 1].chip_hour_price
                   + ask_units[k - 1].chip_hour_price)
    matched: Dict[Tuple[str, str], int] = {}
    for i in range(k):
        key = (bid_units[i].user, ask_units[i].resource)
        matched[key] = matched.get(key, 0) + 1
    trades = sorted((u, r, n) for (u, r), n in matched.items())
    return trades, price, k, len(bid_units), len(ask_units)


def clear_book_arrays(bids: List[AuctionBid], asks: List[Ask]
                      ) -> Tuple[List[Tuple[str, str, int]], float,
                                 int, int, int]:
    """Array-program clearer: argsort + cumulative-quantity crossing.

    No unit expansion — orders stay one row each.  Bids argsort by the
    same ``(-price, user)`` key the scalar clearer uses (numpy string
    comparison is the same code-point lexicographic order as Python's,
    and ``lexsort`` is stable, so exact-tie books order identically);
    asks by ``(price, resource)``.  The crossing point is found on the
    cumulative-quantity breakpoints: within a segment between two
    breakpoints the (bid, ask) pair is constant, and bid prices
    non-increasing against ask prices non-decreasing makes the match
    condition a prefix property — the first failing segment ends it.
    Matched units are re-aggregated per (user, resource) by a
    two-pointer walk over the same breakpoints, so the trade list is
    element-for-element the reference clearer's.  All returned scalars
    are Python ints/floats (nothing numpy leaks into contracts or
    journals); the midpoint price is computed in CPython float
    arithmetic on the two marginal limits, bit-identical to the scalar
    path.
    """
    nb_units = sum(b.slots for b in bids)
    na_units = sum(a.slots for a in asks)
    if nb_units == 0 or na_units == 0:
        return [], 0.0, 0, nb_units, na_units

    nb, na = len(bids), len(asks)
    pb = np.fromiter((b.chip_hour_price for b in bids),
                     dtype=np.float64, count=nb)
    ob = np.lexsort((np.array([b.user for b in bids]), -pb))
    pb = pb[ob]
    cb = np.cumsum(np.fromiter((bids[i].slots for i in ob),
                               dtype=np.int64, count=nb))
    users = [bids[i].user for i in ob]

    pa = np.fromiter((a.chip_hour_price for a in asks),
                     dtype=np.float64, count=na)
    oa = np.lexsort((np.array([a.resource for a in asks]), pa))
    pa = pa[oa]
    ca = np.cumsum(np.fromiter((asks[i].slots for i in oa),
                               dtype=np.int64, count=na))
    resources = [asks[i].resource for i in oa]

    lim = int(min(cb[-1], ca[-1]))
    # segment starts: 0 plus every cumulative-quantity breakpoint below
    # the unit limit; each segment maps to one constant (bid, ask) pair
    bounds = np.union1d(cb, ca)
    starts = np.concatenate(
        (np.zeros(1, dtype=np.int64), bounds[bounds < lim]))
    bi = np.searchsorted(cb, starts, side="right")
    ai = np.searchsorted(ca, starts, side="right")
    ok = pb[bi] >= pa[ai] - 1e-12
    k = lim if bool(ok.all()) else int(starts[int(np.argmin(ok))])
    if k == 0:
        return [], 0.0, 0, nb_units, na_units

    bj = int(np.searchsorted(cb, k - 1, side="right"))
    aj = int(np.searchsorted(ca, k - 1, side="right"))
    price = 0.5 * (float(pb[bj]) + float(pa[aj]))

    cbl = cb.tolist()
    cal = ca.tolist()
    matched: Dict[Tuple[str, str], int] = {}
    pos, bj, aj = 0, 0, 0
    while pos < k:
        while cbl[bj] <= pos:        # skip exhausted (or 0-slot) rows
            bj += 1
        while cal[aj] <= pos:
            aj += 1
        end = min(cbl[bj], cal[aj], k)
        key = (users[bj], resources[aj])
        matched[key] = matched.get(key, 0) + (end - pos)
        pos = end
    trades = sorted((u, r, n) for (u, r), n in matched.items())
    return trades, price, k, nb_units, na_units


class AuctionHouse:
    """Federates one ``DoubleAuctionBook`` per site and runs the
    negotiation protocols on the shared virtual clock.

    Double-auction leg: ``start(sim)`` schedules a clearing round every
    ``round_interval`` seconds; each round clears every site's book
    (sites in sorted order) and converts matches into ``Contract``s
    backed by price-locked reservations on the owning trade server.

    Contract-net leg: ``call_for_tenders`` collects counter-offers from
    every domain (price-sorted — the arbitrage view), ``accept`` strikes
    a contract while the offer is still valid and raises
    ``NegotiationTimeout`` after it lapses.
    """

    def __init__(self, federation: TradeFederation, *,
                 round_interval: float = HOUR,
                 window: float = 2 * HOUR,
                 idle_discount: float = 0.25,
                 tender_discount: float = 0.15,
                 tender_validity: float = 0.5 * HOUR,
                 history=None):
        self.federation = federation
        self.round_interval = round_interval
        self.window = window
        self.idle_discount = idle_discount
        self.tender_discount = tender_discount
        self.tender_validity = tender_validity
        # per-resource ClearingHistory (see repro.core.secondary): every
        # clearing round's matched resources append their uniform price,
        # and owners' PriceSchedules get the observation — the discovery
        # loop that lets posted prices track what capacity clears at
        self.history = history
        self.books: Dict[str, DoubleAuctionBook] = {
            site: DoubleAuctionBook(server, idle_discount=idle_discount)
            for site, server in federation.servers.items()}
        self.contracts: List[Contract] = []       # full audit trail
        self._live: Dict[str, List[Contract]] = {}  # per-user, pruned
        self.rounds: List[ClearingRound] = []
        self._next_cid = 1
        self._subscribers: Dict[str, Callable[[Contract], None]] = {}
        self._sim: Optional[Simulator] = None
        self.tracer = None              # set by bind_telemetry

    def bind_telemetry(self, tracer) -> None:
        """Attach a ``repro.core.telemetry.Tracer``: clearing rounds,
        struck contracts and price-discovery nudges emit ``auction``
        instants on the owning site's track, and the registry gains
        derived gauges over the audit trails."""
        self.tracer = tracer
        m = tracer.metrics
        m.gauge("auction.rounds", fn=lambda: float(len(self.rounds)))
        m.gauge("auction.contracts",
                fn=lambda: float(len(self.contracts)))
        m.gauge("auction.matched_slots",
                fn=lambda: float(sum(r.matched_slots for r in self.rounds)))

    # -- wiring --------------------------------------------------------
    def register(self, user: str,
                 on_contract: Callable[[Contract], None]) -> None:
        self._subscribers[user] = on_contract

    def start(self, sim: Simulator):
        """Begin periodic clearing rounds on the simulator clock.
        Returns the recurring-timer handle (cancel it to end trading)."""
        self._sim = sim
        return sim.every(self.round_interval, self._run_round,
                         start_delay=self.round_interval)

    def _run_round(self) -> None:
        assert self._sim is not None
        self.clear_all(self._sim.now)

    # -- double auction ------------------------------------------------
    def submit_bid(self, site: str, bid: AuctionBid) -> None:
        self.books[site].submit(bid)

    def clear_all(self, t: float) -> List[Contract]:
        struck: List[Contract] = []
        for site in sorted(self.books):
            server = self.books[site].server
            trades, price, audit = self.books[site].clear(t, self.window)
            self.rounds.append(audit)
            if self.tracer is not None:
                self.tracer.instant(
                    t, f"site:{site}", "auction", "clearing_round",
                    price=audit.clearing_price,
                    matched=audit.matched_slots, bids=audit.n_bids,
                    asks=audit.n_asks)
            # record the round and feed the owners' discovery loop
            # BEFORE striking: the posted quote logged is the one the
            # round actually cleared against, not an already-nudged one
            for resource in sorted({r for _, r, _ in trades}):
                # a remote (wire-proxy) server keeps its schedules on
                # the domain side; the discovery nudge then happens
                # there and this broker-side hook is a no-op
                sched = getattr(server, "schedules", {}).get(resource)
                if self.history is not None:
                    posted = server.forward_quote(resource, t)
                    self.history.append(t, resource, price, posted,
                                        "auction")
                if sched is not None:
                    base_before = sched.base_price
                    sched.observe_clearing(t, price)
                    if (self.tracer is not None
                            and sched.base_price != base_before):
                        self.tracer.instant(
                            t, f"site:{site}", "auction",
                            "discovery_nudge", resource=resource,
                            base_from=base_before,
                            base_to=sched.base_price, clearing=price)
            for user, resource, slots in trades:
                c = self._strike(user, resource, site, price, slots,
                                 t, t + self.window, via="auction")
                if c is not None:
                    struck.append(c)
        return struck

    # -- contract-net / tender -----------------------------------------
    def call_for_tenders(self, t: float, user: str, *,
                         window: Optional[float] = None
                         ) -> List[CounterOffer]:
        """Broker solicits; every domain's owners counter-offer.  The
        tender discount beats the idle-auction discount only modestly —
        a direct negotiation skips the auction's price discovery, so
        owners concede less."""
        window = self.window if window is None else window
        offers: List[CounterOffer] = []
        for site in sorted(self.books):
            server = self.books[site].server
            for spec in server.directory.discover(user, site=site):
                name = spec.name
                slots = server.reservable_slots(name, t, t + window)
                if slots <= 0:
                    continue
                util = server.utilization(name)
                price = server.quote(name, t, user) * (
                    1.0 - self.tender_discount * (1.0 - util))
                offers.append(CounterOffer(
                    resource=name, site=site, chip_hour_price=price,
                    slots=slots, start=t, end=t + window,
                    valid_until=t + self.tender_validity))
        return sorted(offers, key=lambda o: (o.chip_hour_price, o.resource))

    def accept(self, offer: CounterOffer, user: str, t: float,
               slots: Optional[int] = None) -> Contract:
        """Accept a counter-offer inside its validity window.  Late
        acceptance is a protocol violation: the owner's price has moved
        on, the broker must re-solicit."""
        if t > offer.valid_until + 1e-9:
            raise NegotiationTimeout(
                f"offer on {offer.resource} expired at "
                f"{offer.valid_until:.0f}s, acceptance attempted at "
                f"{t:.0f}s — re-solicit tenders")
        want = offer.slots if slots is None else min(slots, offer.slots)
        c = self._strike(user, offer.resource, offer.site,
                         offer.chip_hour_price, want, offer.start,
                         offer.end, via="tender")
        if c is None:
            raise AdmissionError(
                f"{offer.resource}: capacity gone before acceptance")
        return c

    def decline(self, offer: CounterOffer) -> None:
        """Contract-net completeness: declining is free and stateless."""

    # -- common --------------------------------------------------------
    def _strike(self, user: str, resource: str, site: str, price: float,
                slots: int, start: float, end: float, *, via: str
                ) -> Optional[Contract]:
        # asks are user-agnostic, so authorization is enforced at
        # signing: a restricted resource never contracts to a stranger
        spec = self.federation.directory.spec(resource)
        if spec.authorized_users and user not in spec.authorized_users:
            return None
        server = self.federation.servers.get(site)
        if server is None:
            return None         # domain departed mid-negotiation
        rids = []
        for _ in range(slots):
            try:
                r = server.reserve(resource, user, start, end, start,
                                   locked_price=price)
            except AdmissionError:
                break               # capacity raced away mid-signing
            rids.append(r.reservation_id)
        if not rids:
            return None
        c = Contract(contract_id=self._next_cid, user=user,
                     resource=resource, site=site, chip_hour_price=price,
                     slots=len(rids), start=start, end=end, via=via,
                     reservation_ids=tuple(rids))
        self._next_cid += 1
        self.contracts.append(c)
        self._live.setdefault(user, []).append(c)
        if self.tracer is not None:
            self.tracer.instant(start, f"site:{site}", "auction",
                                "contract", cid=c.contract_id, user=user,
                                resource=resource, price=price,
                                slots=c.slots, via=via)
        sub = self._subscribers.get(user)
        if sub is not None:
            sub(c)
        return c

    # -- membership churn ----------------------------------------------
    def add_site(self, site: str, server: TradeServer) -> None:
        """A (re)joined domain opens a fresh order book."""
        self.books[site] = DoubleAuctionBook(server,
                                             idle_discount=self.idle_discount)

    def remove_site(self, site: str, t: float
                    ) -> List[Tuple[str, Contract, float]]:
        """The domain left: close its book and VOID every live contract
        on it — the owner can no longer deliver the promised slot-hours.
        Backing reservations are cancelled and each voided contract's
        still-undelivered value is returned as ``(user, contract,
        remaining_value)`` so the driver can route breach refunds
        through the bank.  Iterates users sorted — deterministic."""
        self.books.pop(site, None)
        voided: List[Tuple[str, Contract, float]] = []
        for user in sorted(self._live):
            keep = []
            for c in self._live[user]:
                if c.site == site and c.end > t and c.voided_at is None:
                    remaining = c.max_commitment(self.federation.directory, t)
                    for rid in c.reservation_ids:
                        self.federation.cancel(rid)
                    c.voided_at = t
                    voided.append((user, c, remaining))
                else:
                    keep.append(c)
            self._live[user] = keep
        return voided

    def contracts_for(self, user: str) -> List[Contract]:
        return [c for c in self.contracts if c.user == user]

    def outstanding_commitment(self, user: str, t: float) -> float:
        """Worst-case G$ of the user's not-yet-elapsed contracted
        slot-hours — what budget guards must subtract from headroom.
        Scans a per-user live index pruned on access (``contracts``
        keeps the full history for audits), so broker ticks stay O(live)
        however long the market has been trading."""
        live = self._live.get(user)
        if not live:
            return 0.0
        if any(c.end <= t for c in live):
            live = [c for c in live if c.end > t]
            self._live[user] = live
        return sum(c.max_commitment(self.federation.directory, t)
                   for c in live)


class AuctionBroker:
    """The bidding policy one engine runs when its user chose
    ``strategy="auction"``.

    Each scheduling tick it (re)places a sealed bid at the site that is
    currently cheapest *per job* for it (cross-domain arbitrage), priced
    just under the best posted quote — the broker only wants the auction
    to beat the price board, never to outbid it.  Bid size is capped so
    that worst-case contracted commitments can never exceed the
    remaining budget.
    """

    def __init__(self, house: AuctionHouse, user: str, *,
                 bid_discount: float = 1.0,
                 commit_fraction: float = 0.8,
                 secondary=None,
                 site_penalty: Optional[Callable[[str, float],
                                                 float]] = None):
        self.house = house
        self.user = user
        self.bid_discount = bid_discount
        self.commit_fraction = commit_fraction
        # optional risk markup per (site, t): reputation-aware bidders
        # inflate a flaky domain's effective cost-per-job when steering
        # the bid and shade the limit price accordingly (None = the
        # historical behavior, exactly)
        self.site_penalty = site_penalty
        # secondary market (repro.core.secondary): idle contracted
        # windows are listed for resale (or released for the commitment
        # fee) instead of silently cancelled
        self.secondary = secondary
        self.contracts: List[Contract] = []      # full history (audit)
        self._live: List[Contract] = []          # pruned on access
        house.register(user, self._on_contract)

    def _on_contract(self, c: Contract) -> None:
        self.contracts.append(c)
        self._live.append(c)

    def withdraw(self, t: float = 0.0) -> None:
        """Leave the market (the experiment is over): pull all standing
        bids so no further contract can be struck, and cancel the
        reservations behind contracts that have not yet elapsed — a
        finished broker must not keep blocking capacity rivals could
        trade for."""
        for book in self.house.books.values():
            book.bids.pop(self.user, None)
        for c in self._live:
            # a contract voided by a departing site already had its
            # reservations cancelled — after the site rejoins its old
            # ids are retired, never ours to cancel again
            if c.end > t and c.voided_at is None:
                for rid in c.reservation_ids:
                    if self.secondary is not None:
                        # resell the unexpired window (or pay the
                        # commitment fee) rather than tear it up free
                        self.secondary.shed(rid, self.user, t)
                    else:
                        self.house.federation.cancel(rid)
        self._live = []

    def shed_idle(self, t: float, keep) -> List[int]:
        """Hand off contracted windows the re-plan left idle: any live
        contract on a resource outside ``keep`` (the advisor's current
        allocation) that has survived at least one full clearing round
        unused goes to the secondary market — listed for resale, or
        released for the fee when resale is off.  Returns the shed
        reservation ids.  The grace round keeps a contract struck this
        tick from bouncing straight back onto the book."""
        if self.secondary is None:
            return []
        shed: List[int] = []
        kept: List[Contract] = []
        for c in self._live:
            idle = (c.end > t and c.voided_at is None
                    and c.resource not in keep
                    and c.start + self.house.round_interval <= t)
            if not idle:
                kept.append(c)
                continue
            for rid in c.reservation_ids:
                if self.secondary.shed(rid, self.user, t) != "gone":
                    shed.append(rid)
        self._live = kept
        return shed

    def active_contracts(self, t: float) -> List[Contract]:
        """Contracts covering ``t``, scanning only the not-yet-elapsed
        list (dropped on access — every-tick calls stay O(live))."""
        if any(c.end <= t for c in self._live):
            self._live = [c for c in self._live if c.end > t]
        return [c for c in self._live if c.active_at(t)]

    def contracted_resources(self, t: float) -> List[str]:
        return sorted({c.resource for c in self.active_contracts(t)})

    # ------------------------------------------------------------------
    def step(self, t: float, est_job_seconds: Dict[str, float],
             remaining_jobs: int, ledger) -> Optional[AuctionBid]:
        """Place (or refresh) this round's sealed bid.  Returns the bid
        for observability, or None when there is nothing to bid for."""
        if remaining_jobs <= 0:
            return None
        fed = self.house.federation
        directory = fed.directory

        # arbitrage: score each site by its cheapest forward
        # cost-per-job — the posted price the broker would otherwise pay
        # for window capacity there
        best_site, best_cpj, site_floor = "", math.inf, math.inf
        best_markup = 0.0
        for site, server in fed.servers.items():
            markup = (max(0.0, self.site_penalty(site, t))
                      if self.site_penalty is not None else 0.0)
            for name in server.resources():
                if name not in est_job_seconds:
                    continue
                if not directory.status(name).up:
                    continue
                q = server.forward_quote(name, t, self.user)
                cpj = q * directory.spec(name).chips \
                    * est_job_seconds[name] / HOUR * (1.0 + markup)
                if cpj < best_cpj - 1e-12 or (abs(cpj - best_cpj) <= 1e-12
                                              and site < best_site):
                    best_site, best_cpj = site, cpj
                    site_floor = q
                    best_markup = markup
        if not best_site or not math.isfinite(best_cpj):
            return None

        # bid the spot-equivalent value (truthful for a uniform-price
        # auction): the clearing midpoint, not the limit, sets the
        # actual price, so wins always come in at-or-under spot — shaded
        # down by the site's risk markup (capacity on a domain likely to
        # void its contracts is worth less than its posted quote)
        price = self.bid_discount * site_floor / (1.0 + best_markup)
        if price <= 0.0:
            return None

        # demand: enough slots to retire the backlog within the window
        server = fed.servers[best_site]
        ests = [est_job_seconds[n] for n in server.resources()
                if n in est_job_seconds]
        est = min(ests) if ests else HOUR
        wanted = max(1, math.ceil(remaining_jobs * est / self.house.window))

        # budget cap: worst-case cost of everything contracted so far
        # plus this bid must fit inside the remaining budget
        max_chips = max((directory.spec(n).chips
                         for n in server.resources()), default=1)
        unit_cost = price * max_chips * self.house.window / HOUR
        already = self.house.outstanding_commitment(self.user, t)
        headroom = ledger.remaining * self.commit_fraction - already
        affordable = int(headroom / unit_cost) if unit_cost > 0 else 0
        slots = min(wanted, affordable)
        if slots <= 0:
            return None
        bid = AuctionBid(user=self.user, chip_hour_price=price, slots=slots,
                         valid_until=t + self.house.round_interval + 1.0)
        self.house.submit_bid(best_site, bid)
        return bid
