"""Architecture registry: ``--arch <id>`` resolution + smoke reductions."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (MLACfg, ModelConfig, MoECfg, RGLRUCfg,
                                RWKVCfg, SHAPES, ShapeCfg, shape_applicable)

_ARCH_MODULES = {
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def get_shape(name: str) -> ShapeCfg:
    return SHAPES[name]


def cells(include_skipped: bool = False):
    """Yield (arch_id, shape, applicable) for the 40-cell table."""
    for a in ARCH_IDS:
        for s in SHAPES.values():
            ok = shape_applicable(a, s)
            if ok or include_skipped:
                yield a, s, ok


# ---------------------------------------------------------------------------
# Smoke reductions: same family / same layer pattern / same sub-configs,
# tiny widths, so one fwd+train step runs on CPU in a test.
# ---------------------------------------------------------------------------

def smoke_config(arch_id: str) -> ModelConfig:
    cfg = get_config(arch_id)
    d = 64
    heads = 4
    kv = min(cfg.num_kv_heads, heads) if cfg.num_kv_heads > 1 else 1
    kw: dict = dict(
        num_layers=min(cfg.num_layers, 2 * cfg.period + cfg.prologue_layers + 1),
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        window_size=min(cfg.window_size, 16) if cfg.window_size else 0,
        param_dtype="float32",
        dtype="float32",
        remat="none",
    )
    if cfg.moe is not None:
        kw["moe"] = MoECfg(num_experts=8, top_k=2, d_ff_expert=32,
                           num_shared=min(cfg.moe.num_shared, 1),
                           d_ff_dense=128, first_k_dense=cfg.moe.first_k_dense,
                           capacity_factor=2.0)
    if cfg.mla is not None:
        kw["mla"] = MLACfg(q_lora_rank=32, kv_lora_rank=16,
                           qk_nope_dim=16, qk_rope_dim=8, v_dim=16)
    if cfg.rglru is not None:
        kw["rglru"] = RGLRUCfg(lru_width=d, conv_width=4, num_blocks=4)
    if cfg.rwkv is not None:
        kw["rwkv"] = RWKVCfg(head_dim=16, decay_lora=8, mix_lora=8)
        kw["num_heads"] = d // 16
        kw["num_kv_heads"] = d // 16
    return cfg.replace(**kw)


SMOKE_SHAPE = ShapeCfg("smoke", "train", 32, 2)
