"""stablelm-1.6b — dense decoder, partial rotary (25%).

[hf:stabilityai/stablelm-2-1_6b] 24L d_model=2048 32H (kv=32 -> MHA,
head_dim=64) d_ff=5632 (SwiGLU) vocab=100352, rope_fraction=0.25.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100_352,
    layer_pattern=("full",),
    rope_theta=10_000.0,
    rope_fraction=0.25,
    mlp="swiglu",
    tie_embeddings=False,
    remat="full",
)
