"""deepseek-v2-236b — MoE with multi-head latent attention (MLA).

[arXiv:2405.04434; hf] 60L d_model=5120 128H, MLA kv_lora=512
(q_lora=1536, qk_nope=128, qk_rope=64, v=128), MoE: 2 shared + 160
routed experts, top-6, expert d_ff=1536, first layer dense (d_ff=12288),
vocab=102400.
"""
from repro.configs.base import MLACfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,          # MLA: effectively MHA over latent KV
    head_dim=128,
    d_ff=1536,                 # routed-expert width
    vocab_size=102_400,
    layer_pattern=("full",),
    prologue_layers=1,         # first layer dense FFN, outside the scan
    rope_theta=10_000.0,
    mlp="swiglu",
    tie_embeddings=False,
    mla=MLACfg(q_lora_rank=1536, kv_lora_rank=512,
               qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
    moe=MoECfg(num_experts=160, top_k=6, d_ff_expert=1536,
               num_shared=2, d_ff_dense=12288, first_k_dense=1),
    param_dtype="bfloat16",
    remat="full",
)
