"""gemma3-27b — dense, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-*-pt] 62L d_model=5376 32H (GQA kv=16, head_dim=128)
d_ff=21504 (GeGLU) vocab=262144, qk-norm, window=1024,
rope theta 10k local / 1M global.  62 = 10*6 + 2 remainder (local).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262_144,
    layer_pattern=("local", "local", "local", "local", "local", "full"),
    window_size=1024,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    sandwich_norm=True,
    mlp="geglu",
    embed_scale=True,
    tie_embeddings=True,
    remat="full",
)
