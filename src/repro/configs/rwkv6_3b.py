"""rwkv6-3b (Finch) — attention-free, data-dependent decay.

[arXiv:2404.05892; hf] 32L d_model=2560 d_ff=8960 vocab=65536,
head_dim=64 (40 wkv heads), decay LoRA 64, ddlerp mix LoRA 32.
"""
from repro.configs.base import ModelConfig, RWKVCfg

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,              # wkv heads = d_model / head_dim
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65_536,
    layer_pattern=("rwkv",),
    mlp="rwkv_cm",             # rwkv channel-mix (squared-relu k, sigmoid-r gate)
    tie_embeddings=False,
    rwkv=RWKVCfg(head_dim=64, decay_lora=64, mix_lora=32),
    remat="full",
)
