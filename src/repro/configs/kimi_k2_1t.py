"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config).

[arXiv:2501.kimi2] 61L d_model=7168 64H (GQA kv=8, head_dim=128),
MoE 384 routed experts top-8 + 1 shared, expert d_ff=2048, vocab=163840,
first layer dense (d_ff=18432).

NOTE (DESIGN.md §4): the released Kimi K2 uses MLA (DeepSeek-V3
lineage); the assignment table specifies "GQA kv=8", which we follow —
the MLA path is exercised by deepseek-v2-236b.
"""
from repro.configs.base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,                 # routed-expert width
    vocab_size=163_840,
    layer_pattern=("full",),
    prologue_layers=1,
    rope_theta=50_000.0,
    mlp="swiglu",
    tie_embeddings=False,
    moe=MoECfg(num_experts=384, top_k=8, d_ff_expert=2048,
               num_shared=1, d_ff_dense=18432, first_k_dense=1),
    param_dtype="bfloat16",
    remat="full",
)
