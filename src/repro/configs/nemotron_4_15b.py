"""nemotron-4-15b — dense decoder, GQA, squared-ReLU MLP (no gating).

[arXiv:2402.16819] 32L d_model=6144 48H (GQA kv=8, head_dim=128)
d_ff=24576 vocab=256000, rope (partial 50% in the paper; we keep 1.0
full-rotary as the assignment table gives no fraction).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256_000,
    layer_pattern=("full",),
    rope_theta=10_000.0,
    mlp="sq_relu",
    tie_embeddings=False,
    remat="full",
)
