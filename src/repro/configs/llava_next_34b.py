"""llava-next-34b — VLM; we build the 34B-class LM backbone only.

[hf:llava-hf/llava-v1.6-*] 60L d_model=7168 56H (GQA kv=8, head_dim=128)
d_ff=20480 (SwiGLU) vocab=64000.

Per the assignment spec the vision frontend (anyres tiling + CLIP
encoder + projector) is a STUB: ``input_specs()`` delivers precomputed
patch/text embeddings of shape (B, S, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64_000,
    layer_pattern=("full",),
    rope_theta=5_000_000.0,
    mlp="swiglu",
    input_kind="embeddings",
    tie_embeddings=False,
    remat="full",
)
