from repro.configs.base import (LONG_CONTEXT_OK, MLACfg, ModelConfig, MoECfg,
                                RGLRUCfg, RWKVCfg, SHAPES, ShapeCfg,
                                shape_applicable)
from repro.configs.registry import (ARCH_IDS, all_configs, cells, get_config,
                                    get_shape, smoke_config, SMOKE_SHAPE)

__all__ = [
    "ARCH_IDS", "LONG_CONTEXT_OK", "MLACfg", "ModelConfig", "MoECfg",
    "RGLRUCfg", "RWKVCfg", "SHAPES", "ShapeCfg", "SMOKE_SHAPE",
    "all_configs", "cells", "get_config", "get_shape", "shape_applicable",
    "smoke_config",
]
