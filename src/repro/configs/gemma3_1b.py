"""gemma3-1b — dense, 5:1 local:global, 128k (32k trained) context.

[hf:google/gemma-3-1b-pt] 26L d_model=1152 4H (GQA kv=1, head_dim=256)
d_ff=6912 (GeGLU) vocab=262144, qk-norm, window=512.
26 = 4*6 + 2 remainder (local).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    layer_pattern=("local", "local", "local", "local", "local", "full"),
    window_size=512,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    sandwich_norm=True,
    mlp="geglu",
    embed_scale=True,
    tie_embeddings=True,
    remat="full",
)
