"""musicgen-medium — decoder-only backbone over EnCodec tokens.

[arXiv:2306.05284; hf] 48L d_model=1536 24H (kv=24 -> MHA, head_dim=64)
d_ff=6144 (GELU) vocab=2048.

Per the assignment spec the modality frontend (EnCodec + codebook
delay-pattern interleaving) is a STUB: ``input_specs()`` delivers
precomputed frame embeddings of shape (B, S, d_model); the backbone
predicts next-frame codes over the 2048-entry codebook vocabulary.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    layer_pattern=("full",),
    rope_theta=10_000.0,
    mlp="gelu",
    input_kind="embeddings",
    tie_embeddings=False,
    remat="full",
)
