"""recurrentgemma-2b — Griffin-style hybrid: RG-LRU + local attention, 1:2.

[arXiv:2402.19427; hf] 26L d_model=2560 10H (GQA kv=1, head_dim=256)
d_ff=7680 (GeGLU) vocab=256000, window=2048.
Pattern: (rglru, rglru, local) repeated; 26 = 8*3 + 2 remainder.
"""
from repro.configs.base import ModelConfig, RGLRUCfg

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    layer_pattern=("rglru", "rglru", "local"),
    window_size=2048,
    rope_theta=10_000.0,
    mlp="geglu",
    embed_scale=True,
    tie_embeddings=True,
    rglru=RGLRUCfg(lru_width=2560, conv_width=4, num_blocks=10),
    remat="full",
)
