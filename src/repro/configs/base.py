"""Model / experiment configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``.  Configs are
pure data (no jax import) so they can be loaded by the scheduler, the
launcher, and the dry-run without touching device state.

Layer stacking
--------------
``layer_pattern`` is the repeating *period* of layer kinds, e.g.
``("local", "local", "local", "local", "local", "full")`` for gemma3's
5:1 local:global mix, or ``("rglru", "rglru", "local")`` for
recurrentgemma.  The stack is laid out as::

    [prologue layers] + [n_periods x layer_pattern (lax.scan)] + [epilogue]

``prologue_layers`` pins the leading layers outside the scan (used by the
MoE archs whose first layer(s) use a dense FFN).  The epilogue holds the
remainder when ``num_layers`` is not a multiple of the period.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

LayerKind = str  # "full" | "local" | "rglru" | "rwkv"


@dataclass(frozen=True)
class MoECfg:
    """Mixture-of-experts FFN configuration (DeepSeek-style shared+routed)."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    d_ff_dense: int = 0          # FFN width of the leading dense layers
    first_k_dense: int = 0       # how many leading layers use a dense FFN
    capacity_factor: float = 1.25
    eval_capacity_factor: float = 2.0
    aux_loss_weight: float = 1e-3
    router_dtype: str = "float32"

    @property
    def d_ff_shared(self) -> int:
        return self.num_shared * self.d_ff_expert


@dataclass(frozen=True)
class MLACfg:
    """Multi-head latent attention (DeepSeek-V2)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class RGLRUCfg:
    """Real-Gated Linear Recurrent Unit block (Griffin / RecurrentGemma)."""

    lru_width: int = 0           # 0 -> same as d_model
    conv_width: int = 4
    num_blocks: int = 0          # block-diagonal gate heads; 0 -> num_heads
    c_exponent: float = 8.0      # the fixed "c" scaling exponent from Griffin


@dataclass(frozen=True)
class RWKVCfg:
    """RWKV-6 (Finch) time-mix / channel-mix configuration."""

    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense|moe|hybrid|ssm|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    layer_pattern: Tuple[LayerKind, ...] = ("full",)
    prologue_layers: int = 0

    # attention
    window_size: int = 0              # sliding window for "local" layers
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None   # distinct theta on "full" layers
    rope_fraction: float = 1.0        # partial rotary (stablelm: 0.25)
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    sandwich_norm: bool = False       # gemma-style post-block norms

    # mlp
    mlp: str = "swiglu"               # swiglu|geglu|gelu|sq_relu
    # embeddings
    tie_embeddings: bool = True
    input_kind: str = "tokens"        # tokens | embeddings (audio/vlm stub frontends)
    embed_scale: bool = False         # gemma multiplies embeddings by sqrt(d)

    # sub-architectures
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    rglru: Optional[RGLRUCfg] = None
    rwkv: Optional[RWKVCfg] = None

    norm_eps: float = 1e-6

    # systems knobs
    dtype: str = "bfloat16"           # activation dtype
    param_dtype: str = "float32"      # master parameter dtype
    remat: str = "none"               # none | dots | full
    scan_layers: bool = True
    attn_impl: str = "blockwise"      # reference | blockwise | pallas
    moe_impl: str = "ep"              # dense | ep | ep_a2a
    # perf-loop knobs (EXPERIMENTS.md §Perf)
    seq_shard: bool = False           # context parallelism: seq over "model"
    cast_params_bf16: bool = False    # cast f32 masters to bf16 pre-forward
    chunked_ce: bool = False          # never materialize full (B,S,V) logits

    # ------------------------------------------------------------------
    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    def stack_plan(self) -> tuple[tuple[LayerKind, ...], int, tuple[LayerKind, ...]]:
        """Return (prologue_kinds, n_periods, epilogue_kinds)."""
        body = self.num_layers - self.prologue_layers
        n_periods = body // self.period if self.scan_layers else 0
        pro = tuple(self.expanded_kinds()[: self.prologue_layers])
        epi_len = body - n_periods * self.period
        epi = self.layer_pattern[:epi_len] if epi_len else ()
        if not self.scan_layers:
            # everything unrolled: prologue covers all layers
            return tuple(self.expanded_kinds()), 0, ()
        return pro, n_periods, epi

    def expanded_kinds(self) -> Tuple[LayerKind, ...]:
        """Per-layer kinds for the full stack (pattern tiled)."""
        kinds = []
        for i in range(self.num_layers):
            if i < self.prologue_layers:
                kinds.append(self.layer_pattern[i % self.period])
            else:
                kinds.append(self.layer_pattern[(i - self.prologue_layers) % self.period])
        return tuple(kinds)

    def layer_uses_moe(self, layer_idx: int) -> bool:
        return self.moe is not None and layer_idx >= self.moe.first_k_dense

    # -- parameter counting (analytic; used by the economy scheduler) ----
    def param_count(self) -> int:
        d, H, K, hd, f, V = (self.d_model, self.num_heads, self.num_kv_heads,
                             self.head_dim, self.d_ff, self.vocab_size)
        total = V * d                      # embedding
        if not self.tie_embeddings:
            total += V * d
        counts = {k: 0 for k in ("full", "local", "rglru", "rwkv")}
        for k in self.expanded_kinds():
            counts[k] += 1
        n_attn = counts["full"] + counts["local"]

        if self.mla is not None:
            m = self.mla
            attn_p = (d * m.q_lora_rank + m.q_lora_rank * H * (m.qk_nope_dim + m.qk_rope_dim)
                      + d * (m.kv_lora_rank + m.qk_rope_dim)
                      + m.kv_lora_rank * H * (m.qk_nope_dim + m.v_dim)
                      + H * m.v_dim * d)
        else:
            attn_p = d * H * hd + 2 * d * K * hd + H * hd * d
        total += n_attn * attn_p

        # mlp per layer
        gated = self.mlp in ("swiglu", "geglu")
        dense_mlp = (3 if gated else 2) * d * f
        if self.moe is None:
            total += self.num_layers * dense_mlp
        else:
            mo = self.moe
            fd = mo.d_ff_dense or f
            dense_p = (3 if gated else 2) * d * fd
            exp_p = 3 * d * mo.d_ff_expert            # gate/up/down per expert
            shared_p = 3 * d * mo.d_ff_shared if mo.num_shared else 0
            router_p = d * mo.num_experts
            n_moe = self.num_layers - mo.first_k_dense
            total += mo.first_k_dense * dense_p
            total += n_moe * (mo.num_experts * exp_p + shared_p + router_p)

        if self.rglru is not None:
            g = self.rglru
            lw = g.lru_width or d
            nb = g.num_blocks or self.num_heads
            blk = 2 * nb * (lw // nb) ** 2            # block-diag input & rec gates
            rg_p = 2 * d * lw + g.conv_width * lw + lw + blk + lw * d
            total += counts["rglru"] * rg_p           # MLP counted above
        if self.rwkv is not None:
            r = self.rwkv
            tm = 4 * d * d + d * r.decay_lora + r.decay_lora * d + 6 * d \
                + 5 * (d * r.mix_lora + r.mix_lora * d) + d * d  # r,k,v,g,out + w-lora + mus + ddlerp loras
            cm_extra = d * d                          # channel-mix receptance
            total += counts["rwkv"] * (tm + cm_extra)  # 2*d*f counted above
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        full = self.param_count()
        exp_p = 3 * self.d_model * mo.d_ff_expert
        n_moe = self.num_layers - mo.first_k_dense
        inactive = n_moe * (mo.num_experts - mo.top_k) * exp_p
        return int(full - inactive)


@dataclass(frozen=True)
class ShapeCfg:
    """One assigned input-shape cell."""

    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int
    # decode shapes: seq_len is the KV-cache length, one new token generated

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeCfg("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeCfg("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeCfg("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeCfg("long_500k", "decode", 524288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# Archs allowed to run long_500k (sub-quadratic / hybrid attention only --
# see DESIGN.md §4).  Pure full-attention archs skip it.
LONG_CONTEXT_OK = frozenset(
    {"recurrentgemma-2b", "rwkv6-3b", "gemma3-1b", "gemma3-27b"}
)


def shape_applicable(arch_name: str, shape: ShapeCfg) -> bool:
    if shape.name == "long_500k":
        return arch_name in LONG_CONTEXT_OK
    return True
