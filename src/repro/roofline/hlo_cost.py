"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts each ``while`` body ONCE — for
scan-over-layers models that undercounts FLOPs/bytes/collectives by the
trip count (verified empirically: a 7-iteration scan reports exactly 1/7
of the dot FLOPs).  This module walks the HLO computation graph instead:

* ``while``  -> body cost x trip count (trip count recovered from the
  largest integer constant in the condition computation — the pattern
  ``lax.scan`` lowers to);
* ``fusion``/``call`` -> FLOPs of the called computation, but HBM bytes
  only for the fusion's operands/result (fusion internals stay in
  registers/VMEM — the TPU-faithful memory model, unlike the CPU
  backend's per-op accounting);
* ``dot``    -> 2 * prod(result_dims) * prod(lhs contracting dims);
* collectives -> wire bytes = max(operand, result) bytes, accumulated
  through loops, per collective kind.

Used by the roofline table; the raw XLA numbers are recorded alongside
for transparency.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_SCALAR_TYPE_RE = re.compile(r"^([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?"
                             r"(?:\s*S\(\d+\))?)")
_OPCODE_RE = re.compile(r"^([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_CONST_RE = re.compile(r"constant\((-?\d+)\)")
_TRIPCOUNT_HINT = re.compile(r"trip_count=(\d+)")


def _parse_rhs(rhs: str):
    """Split '<type> <opcode>(<rest>' — type may be a tuple containing
    '/*index=N*/' comments, so regexes over '=' fail; scan parens."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        rtype, rest = rhs[:end + 1], rhs[end + 1:].strip()
    else:
        m = _SCALAR_TYPE_RE.match(rhs)
        if not m:
            return None
        rtype, rest = m.group(1), rhs[m.end():].strip()
    m2 = _OPCODE_RE.match(rest)
    if not m2:
        return None
    return rtype, m2.group(1), m2.group(2)


def _operand_region(rest: str) -> str:
    """Text up to the matching close paren of the op's argument list."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i]
    return rest


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class OpLine:
    name: str
    rtype: str
    opcode: str
    rest: str            # everything after the opening paren


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_count: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def add(self, other: "Cost", times: float = 1.0) -> None:
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * times
            self.coll_count[k] += other.coll_count[k] * times

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[OpLine]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._cost_cache: Dict[str, Cost] = {}

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            mc = _COMP_RE.match(line.strip())
            if mc and line.strip().endswith("{"):
                cur = mc.group(1)
                self.computations[cur] = []
                if line.strip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            md = _DEF_RE.match(line)
            if not md:
                continue
            name, rhs = md.group(1), md.group(2)
            parsed = _parse_rhs(rhs)
            if parsed is None:
                continue
            rtype, opcode, rest = parsed
            self.computations[cur].append(OpLine(name, rtype, opcode, rest))

    # ------------------------------------------------------------------
    def _symbols(self, comp: str) -> Dict[str, str]:
        return {op.name: op.rtype for op in self.computations[comp]}

    def _operands(self, op: OpLine, syms: Dict[str, str]) -> List[str]:
        """Operand result-types (resolved through the local symbol table)."""
        out = []
        for m in re.finditer(r"%[\w.\-]+", _operand_region(op.rest)):
            t = syms.get(m.group(0))
            if t is not None:
                out.append(t)
        return out

    def _called(self, op: OpLine, attr: str) -> Optional[str]:
        m = re.search(attr + r"=(%[\w.\-]+)", op.rest)
        return m.group(1) if m else None

    def _trip_count(self, cond_comp: str) -> int:
        best = 1
        for op in self.computations.get(cond_comp, ()):
            for m in _CONST_RE.finditer(op.rtype + " " + op.rest):
                best = max(best, abs(int(m.group(1))))
            if op.opcode == "constant":
                mm = re.match(r"\s*\(?(-?\d+)", op.rest)
                if mm:
                    best = max(best, abs(int(mm.group(1))))
        return best

    def _fusion_dus_discount(self, comp: str) -> float:
        """Bytes to subtract for in-place dynamic-update-slices inside a
        fusion: the aliased full buffer appears both as operand and result
        of the fusion (2x buffer bytes counted) but true HBM traffic is
        ~2x the update slice."""
        key = f"dus|{comp}"
        if key in self._cost_cache:
            return self._cost_cache[key].bytes
        disc = 0.0
        syms = self._symbols(comp)
        for op in self.computations.get(comp, ()):
            if op.opcode != "dynamic-update-slice":
                continue
            ops_t = self._operands(op, syms)
            if not ops_t:
                continue
            buf = _type_bytes(ops_t[0])
            upd = _type_bytes(ops_t[1]) if len(ops_t) > 1 else 0
            if buf > 4 * max(upd, 1):
                disc += 2.0 * buf - 2.0 * upd
        out = Cost()
        out.bytes = disc
        self._cost_cache[key] = out
        return disc

    # ------------------------------------------------------------------
    def comp_cost(self, comp: str, count_bytes: bool = True) -> Cost:
        key = f"{comp}|{count_bytes}"
        if key in self._cost_cache:
            return self._cost_cache[key]
        total = Cost()
        syms = self._symbols(comp)
        for op in self.computations.get(comp, ()):
            total.add(self._op_cost(op, syms, count_bytes))
        self._cost_cache[key] = total
        return total

    def _op_cost(self, op: OpLine, syms: Dict[str, str],
                 count_bytes: bool) -> Cost:
        c = Cost()
        oc = op.opcode
        if oc in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all"):
            return c

        # collectives (handle async -start/-done pairs once)
        for k in _COLLECTIVES:
            if oc == k or oc.startswith(k + "-"):
                if oc.endswith("-done"):
                    return c
                rb = _type_bytes(op.rtype)
                ob = sum(_type_bytes(t) for t in self._operands(op, syms))
                c.coll[k] += max(rb, ob)
                c.coll_count[k] += 1
                if count_bytes:
                    c.bytes += rb + ob
                return c

        if oc == "while":
            body = self._called(op, "body")
            cond = self._called(op, "condition")
            trips = 1
            m = _TRIPCOUNT_HINT.search(op.rest)
            if m:
                trips = int(m.group(1))
            elif cond:
                trips = self._trip_count(cond)
            if body:
                c.add(self.comp_cost(body, count_bytes), times=trips)
            return c

        if oc in ("fusion", "call", "custom-call", "async-start"):
            called = self._called(op, "calls")
            dus_correction = 0.0
            if called:
                inner = self.comp_cost(called, count_bytes=False)
                c.flops += inner.flops
                for k in _COLLECTIVES:
                    c.coll[k] += inner.coll[k]
                    c.coll_count[k] += inner.coll_count[k]
                dus_correction = self._fusion_dus_discount(called)
            if count_bytes:
                b = _type_bytes(op.rtype)
                b += sum(_type_bytes(t) for t in self._operands(op, syms))
                # in-place dynamic-update-slice inside the fusion: the big
                # buffer is aliased, true traffic is the updated slice only
                b = max(b - dus_correction, _type_bytes(op.rtype) * 0.0)
                c.bytes += b
            return c

        if oc == "conditional":
            branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                  r"(?:true|false)_computation=(%[\w.\-]+))",
                                  op.rest)
            names: List[str] = []
            for grp in branches:
                if grp[0]:
                    names.extend(s.strip() for s in grp[0].split(","))
                if grp[1]:
                    names.append(grp[1])
            if names:
                costs = [self.comp_cost(n, count_bytes) for n in names
                         if n in self.computations]
                if costs:
                    worst = max(costs, key=lambda x: x.flops + x.bytes)
                    c.add(worst)
            return c

        if oc == "dot":
            result = _shape_dims(op.rtype)
            n_res = 1
            for d in result:
                n_res *= d
            ops_t = self._operands(op, syms)
            lhs_dims = _shape_dims(ops_t[0]) if ops_t else []
            mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
            kprod = 1
            if mc and mc.group(1) and lhs_dims:
                for d in mc.group(1).split(","):
                    di = int(d)
                    if di < len(lhs_dims):
                        kprod *= lhs_dims[di]
            c.flops += 2.0 * n_res * kprod
            if count_bytes:
                c.bytes += _type_bytes(op.rtype)
                c.bytes += sum(_type_bytes(t) for t in ops_t)
            return c

        if oc in ("dynamic-update-slice", "dynamic-slice"):
            # in-place slice ops touch the slice, not the whole buffer
            ops_t = self._operands(op, syms)
            if oc == "dynamic-update-slice":
                upd = _type_bytes(ops_t[1]) if len(ops_t) > 1 else 0
                c.bytes += 2.0 * upd if count_bytes else 0.0
            else:
                c.bytes += 2.0 * _type_bytes(op.rtype) if count_bytes else 0.0
            n = 1
            for d in _shape_dims(op.rtype):
                n *= d
            c.flops += 0.0
            return c

        # everything else: 1 flop per output element
        n = 1
        dims = _shape_dims(op.rtype)
        for d in dims:
            n *= d
        c.flops += n
        if count_bytes:
            c.bytes += _type_bytes(op.rtype)
            c.bytes += sum(_type_bytes(t) for t in self._operands(op, syms))
        return c

    # ------------------------------------------------------------------
    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloModule(hlo_text).entry_cost()
