"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, TPU v5e constants:

    compute    = HLO_FLOPs_global   / (chips * 197e12)
    memory     = HLO_bytes_global   / (chips * 819e9)
    collective = coll_bytes_global  / (chips * 50e9)

``compiled.cost_analysis()`` reports per-device numbers for the SPMD
program; we scale by chip count so the table shows global quantities (the
two conventions give identical *terms*).  Collective bytes are not in
cost_analysis: we parse the post-partitioning HLO and sum operand bytes of
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
ops (per-device, scaled to global the same way).
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / link (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^=]*?)"
    r"\s*([\w\-]+)\(", re.ASCII)


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective kind (result sizes)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        result_type, opname = m.group(1), m.group(2)
        # normalize fusions like "all-reduce-start"
        base = None
        for k in _COLLECTIVES:
            if opname == k or opname.startswith(k + "-"):
                if opname.endswith("-done"):
                    base = None   # avoid double count of async pairs
                else:
                    base = k
                break
        if base is None:
            continue
        out[base] += _shape_bytes(result_type)
        counts[base] += 1
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts}


@dataclasses.dataclass
class RooflineCell:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_global: float            # HLO flops, all chips
    bytes_global: float            # HLO bytes accessed, all chips
    coll_bytes_global: float
    coll_breakdown: Dict[str, int]
    model_flops: float             # 6*N*D (active params for MoE)
    peak_memory_per_chip: int = 0  # from memory_analysis
    argument_size_per_chip: int = 0
    output_size_per_chip: int = 0

    @property
    def t_compute(self) -> float:
        return self.flops_global / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_global / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_global / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lb(self) -> float:
        """Lower-bound step time (max of the three terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops / self.flops_global if self.flops_global else 0.0

    @property
    def mfu_upper_bound(self) -> float:
        """MODEL_FLOPS / (chips*peak*step_time_lb): roofline MFU."""
        t = self.step_time_lb
        return (self.model_flops / (self.chips * PEAK_FLOPS * t)) if t else 0.0

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_global": self.flops_global,
            "bytes_global": self.bytes_global,
            "coll_bytes_global": self.coll_bytes_global,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_upper_bound": self.mfu_upper_bound,
            "peak_memory_per_chip": self.peak_memory_per_chip,
            "argument_size_per_chip": self.argument_size_per_chip,
            "output_size_per_chip": self.output_size_per_chip,
        }


def model_flops_for(cfg, shape) -> float:
    """6*N*D training FLOPs / 2*N*D inference FLOPs (active params)."""
    n_active = cfg.active_param_count()
    d_tokens = shape.tokens_per_step
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * d_tokens


def cell_from_compiled(arch: str, shape, mesh_name: str, chips: int,
                       cfg, compiled) -> RooflineCell:
    from repro.roofline import hlo_cost
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    hlo = compiled.as_text()
    # loop-aware analysis (XLA's cost_analysis counts while bodies once)
    cost = hlo_cost.analyze(hlo)
    flops_dev = cost.flops
    bytes_dev = cost.bytes
    coll = {k: int(v) for k, v in cost.coll.items()}
    coll.update({f"n_{k}": int(v) for k, v in cost.coll_count.items()})
    coll["xla_raw_flops"] = float(ca.get("flops", 0.0))
    coll["xla_raw_bytes"] = float(ca.get("bytes accessed", 0.0))
    coll_dev = cost.coll_bytes
    ma = compiled.memory_analysis()
    peak = getattr(ma, "temp_size_in_bytes", 0) or 0
    argb = getattr(ma, "argument_size_in_bytes", 0) or 0
    outb = getattr(ma, "output_size_in_bytes", 0) or 0
    return RooflineCell(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_global=flops_dev * chips,
        bytes_global=bytes_dev * chips,
        coll_bytes_global=coll_dev * chips,
        coll_breakdown=coll,
        model_flops=model_flops_for(cfg, shape),
        peak_memory_per_chip=int(peak),
        argument_size_per_chip=int(argb),
        output_size_per_chip=int(outb),
    )


def fmt_seconds(s: float) -> str:
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.1f}us"


def table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':<20} {'shape':<12} {'mesh':<6} {'compute':>10} "
           f"{'memory':>10} {'collective':>10} {'bottleneck':>11} "
           f"{'useful':>7} {'MFU_ub':>7}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:<20} {r['shape']:<12} {r['mesh']:<6} "
            f"{fmt_seconds(r['t_compute_s']):>10} "
            f"{fmt_seconds(r['t_memory_s']):>10} "
            f"{fmt_seconds(r['t_collective_s']):>10} "
            f"{r['bottleneck']:>11} "
            f"{r['useful_flops_fraction']:>7.2f} "
            f"{r['mfu_upper_bound']:>7.2%}")
    return "\n".join(lines)
