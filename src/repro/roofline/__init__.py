from repro.roofline import analysis
from repro.roofline.analysis import (RooflineCell, cell_from_compiled,
                                     collective_bytes, model_flops_for, table)

__all__ = ["RooflineCell", "analysis", "cell_from_compiled",
           "collective_bytes", "model_flops_for", "table"]
