"""Step functions: train_step / prefill_step / decode_step builders.

Each builder returns a pure function suitable for ``jax.jit`` with explicit
in/out shardings derived from the logical-axis rules, plus the matching
abstract input specs (``input_specs``) for the dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCfg
from repro.distributed import sharding as shd
from repro.models import transformer as tfm
from repro.optim import (AdamWConfig, OptState, abstract_opt_state,
                         apply_updates, init_opt_state, linear_warmup_cosine)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, z_loss: float = 1e-4):
    """logits (B,S,V) fp32, labels (B,S) int32. Mean over tokens."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    loss = nll.mean()
    if z_loss:
        loss = loss + z_loss * jnp.square(lse).mean()
    return loss


def chunked_cross_entropy(cfg: ModelConfig, params, hidden, labels,
                          z_loss: float = 1e-4, n_chunks: int = 8):
    """CE without materializing (B,S,V): scan over sequence chunks, each
    chunk's logits recomputed in backward (jax.checkpoint)."""
    from repro.models.common import softcap as _softcap
    B, S, d = hidden.shape
    while S % n_chunks:
        n_chunks //= 2
    C = S // n_chunks
    dt = hidden.dtype
    w = (params["embed"].T if (cfg.tie_embeddings and
                               cfg.input_kind == "tokens")
         else params["unembed"]).astype(dt)

    @jax.checkpoint
    def chunk(h_c, y_c):
        logits = (h_c @ w).astype(jnp.float32)
        logits = _softcap(logits, cfg.final_logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - ll), jnp.sum(jnp.square(lse))

    hs = hidden.reshape(B, n_chunks, C, d).transpose(1, 0, 2, 3)
    ys = labels.reshape(B, n_chunks, C).transpose(1, 0, 2)

    def body(carry, xs):
        nll, zs = carry
        a, b = chunk(*xs)
        return (nll + a, zs + b), None

    (nll, zs), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                (hs, ys))
    loss = nll / (B * S)
    if z_loss:
        loss = loss + z_loss * zs / (B * S)
    return loss


def compute_params(cfg: ModelConfig, params):
    """Optionally cast fp32 master weights to the activation dtype before
    the forward pass — halves FSDP all-gather and weight-read traffic
    (§Perf knob ``cast_params_bf16``)."""
    if not cfg.cast_params_bf16:
        return params
    dt = jnp.dtype(cfg.dtype)

    def f(p):
        if hasattr(p, "dtype") and p.dtype == jnp.float32 and p.ndim >= 2:
            return p.astype(dt)
        return p
    return jax.tree.map(f, params)


def loss_fn(cfg: ModelConfig, params, batch, *, mesh=None):
    fwd_params = compute_params(cfg, params)
    labels = batch["labels"]
    if cfg.chunked_ce:
        hidden, _, aux = tfm.forward(cfg, fwd_params, batch, mode="train",
                                     mesh=mesh, return_hidden=True)
        loss = chunked_cross_entropy(cfg, fwd_params, hidden, labels)
    else:
        logits, _, aux = tfm.forward(cfg, fwd_params, batch, mode="train",
                                     mesh=mesh)
        loss = cross_entropy(logits, labels)
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux
    return loss, {"loss": loss, "aux": aux}


# ---------------------------------------------------------------------------
# abstract inputs (dry-run stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict[str, Any]:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.input_kind == "tokens":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.dtype(cfg.dtype)),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.input_kind == "tokens":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.dtype(cfg.dtype))}
    # decode: one new token; the KV cache of length S is a separate arg
    if cfg.input_kind == "tokens":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    return {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model),
                                           jnp.dtype(cfg.dtype))}


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *, mesh=None,
                    total_steps: int = 10_000, warmup: int = 100):
    def train_step(params, opt_state: OptState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, mesh=mesh), has_aux=True)(params)
        lr_scale = linear_warmup_cosine(opt_state.step + 1, warmup=warmup,
                                        total=total_steps)
        params, opt_state, om = apply_updates(params, grads, opt_state,
                                              opt_cfg, lr_scale)
        metrics = dict(metrics)
        metrics.update(om)
        return params, opt_state, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int, *, mesh=None):
    def prefill_step(params, batch, cache):
        logits, cache, _ = tfm.forward(cfg, compute_params(cfg, params),
                                       batch, mode="prefill", cache=cache,
                                       mesh=mesh)
        return logits[:, -1], cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, mesh=None):
    def decode_step(params, batch, cache):
        logits, cache, _ = tfm.forward(cfg, compute_params(cfg, params),
                                       batch, mode="decode", cache=cache,
                                       mesh=mesh)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache
    return decode_step


# ---------------------------------------------------------------------------
# sharding assembly for a (cfg, shape, mesh) cell
# ---------------------------------------------------------------------------

def cell_shardings(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh,
                   opt_cfg: Optional[AdamWConfig] = None):
    """Returns dict with param/opt/batch/cache shardings + abstract values."""
    rules = shd.base_rules(cfg, shape, mesh)
    axes = tfm.model_axes(cfg)
    aparams = tfm.abstract_model(cfg)
    pshard = shd.tree_shardings(axes, mesh, rules)

    out: Dict[str, Any] = {"rules": rules, "params": aparams,
                           "params_sharding": pshard}
    bsh = shd.batch_sharding(mesh, shape.global_batch, 2, rules)
    binputs = input_specs(cfg, shape)
    out["batch"] = binputs
    out["batch_sharding"] = jax.tree.map(lambda _: bsh, binputs)

    if shape.kind == "train" and opt_cfg is not None:
        aopt = abstract_opt_state(aparams, opt_cfg)
        # moments shard like their params; quantized moments are 2-D blocks
        # that follow the flattened layout -> shard rows if big.
        def opt_shard(ps):
            return ps
        mshard = jax.tree.map(lambda s: s, pshard)
        if opt_cfg.quantized_moments:
            def qshard(leaf):
                return NamedSharding(mesh, P())
            msh = jax.tree.map(qshard, aopt.m)
            vsh = jax.tree.map(qshard, aopt.v)
        else:
            msh, vsh = mshard, mshard
        out["opt"] = aopt
        out["opt_sharding"] = OptState(
            step=NamedSharding(mesh, P()), m=msh, v=vsh)
    if shape.kind in ("prefill", "decode"):
        acache = tfm.init_cache(cfg, shape.global_batch, shape.seq_len,
                                abstract=True)
        out["cache"] = acache
        out["cache_sharding"] = shd.cache_sharding(cfg, mesh, rules, acache)
    return out
