from repro.train import steps
from repro.train.steps import (cell_shardings, cross_entropy, input_specs,
                               loss_fn, make_decode_step, make_prefill_step,
                               make_train_step)

__all__ = ["cell_shardings", "cross_entropy", "input_specs", "loss_fn",
           "make_decode_step", "make_prefill_step", "make_train_step",
           "steps"]
