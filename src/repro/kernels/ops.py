"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (CPU validation per the build
environment) and False on TPU backends, where the kernels compile to
Mosaic.  Model code selects kernels through these wrappers only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.group_gemm import group_gemm as _group_gemm
from repro.kernels.rglru_scan import rglru_scan as _rglru
from repro.kernels.rwkv_wkv import wkv as _wkv


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128):
    """q: (B,H,Sq,D), k/v: (B,K,Sk,D) -> (B,H,Sq,D)."""
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k,
                  interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("block_t", "block_l"))
def rglru_scan(log_a, b, h0=None, *, block_t=128, block_l=256):
    """h_t = exp(log_a_t) * h_{t-1} + b_t over axis 1."""
    return _rglru(log_a, b, h0, block_t=block_t, block_l=block_l,
                  interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv(r, k, v, logw, u, state0=None, *, chunk=32):
    """RWKV-6 WKV. Returns (y, final_state)."""
    return _wkv(r, k, v, logw, u, state0, chunk=chunk,
                interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("block_c", "block_f"))
def group_gemm(x, w, n_valid, *, block_c=128, block_f=128):
    """Per-expert GEMM with padding-block skip."""
    return _group_gemm(x, w, n_valid, block_c=block_c, block_f=block_f,
                       interpret=_default_interpret())
