"""RWKV-6 WKV kernel for TPU (Pallas), chunked formulation.

Per (batch, head) grid cell the (N x N) recurrent state stays resident in
VMEM scratch for the whole sequence; each time-chunk is processed with
MXU matmuls (the chunked GLA trick):

    within-chunk:   att[t,s] = Σ_i r_t[i] k_s[i] exp(cum_{t-1}-cum_s), s<t
    diagonal bonus: u
    cross-chunk:    y += (r ⊙ exp(cum - logw)) @ S
    state update:   S <- exp(tot) ⊙ S + (k ⊙ exp(tot - cum))^T V

Grid = (B*H, time_chunks), time sequential.  Returns y and final state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, sout_ref,
                s_scr, *, num_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    f32 = jnp.float32
    r = r_ref[0].astype(f32)            # (C,N)
    k = k_ref[0].astype(f32)
    v = v_ref[0].astype(f32)
    lw = lw_ref[0].astype(f32)
    u = u_ref[0].astype(f32)            # (N,)

    cum = jnp.cumsum(lw, axis=0)
    tot = cum[-1]
    q = r * jnp.exp(cum - lw)
    kk = k * jnp.exp(-cum)
    att = jax.lax.dot_general(q, kk, (((1,), (1,)), ((), ())),
                              preferred_element_type=f32)    # (C,C)
    C = att.shape[0]
    ti_i = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    si_i = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    att = jnp.where(si_i < ti_i, att, 0.0)
    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=f32)
    diag = jnp.sum(r * u[None, :] * k, axis=1)               # (C,)
    y = y + diag[:, None] * v
    y = y + jax.lax.dot_general(q, s_scr[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=f32)
    y_ref[0] = y.astype(y_ref.dtype)

    kw = k * jnp.exp(tot[None, :] - cum)
    s_scr[...] = jnp.exp(tot)[:, None] * s_scr[...] + \
        jax.lax.dot_general(kw, v, (((0,), (0,)), ((), ())),
                            preferred_element_type=f32)

    @pl.when(ti == num_t - 1)
    def _finish():
        sout_ref[0] = s_scr[...].astype(sout_ref.dtype)


def wkv(r, k, v, logw, u, state0=None, *, chunk: int = 32,
        interpret: bool = True):
    """r,k,v,logw: (B,S,H,N); u: (H,N); state0: (B,H,N,N) or None.

    Returns (y (B,S,H,N), state (B,H,N,N)).  S is padded to a chunk
    multiple with identity steps (logw=0, k=0, r=0).
    """
    B, S, H, N = r.shape
    C = min(chunk, S)
    nt = -(-S // C)
    pad = nt * C - S

    def prep(x, fill=0.0):
        x = x.transpose(0, 2, 1, 3).reshape(B * H, S, N)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)),
                        constant_values=fill)
        return x

    rf, kf, vf = prep(r), prep(k), prep(v)
    lwf = prep(logw)
    uf = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, N)
    s0 = (jnp.zeros((B * H, N, N), jnp.float32) if state0 is None
          else state0.reshape(B * H, N, N))

    kernel = functools.partial(_wkv_kernel, num_t=nt)
    y, sout = pl.pallas_call(
        kernel,
        grid=(B * H, nt),
        in_specs=[
            pl.BlockSpec((1, C, N), lambda h, ti: (h, ti, 0)),
            pl.BlockSpec((1, C, N), lambda h, ti: (h, ti, 0)),
            pl.BlockSpec((1, C, N), lambda h, ti: (h, ti, 0)),
            pl.BlockSpec((1, C, N), lambda h, ti: (h, ti, 0)),
            pl.BlockSpec((1, N), lambda h, ti: (h, 0)),
            pl.BlockSpec((1, N, N), lambda h, ti: (h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, N), lambda h, ti: (h, ti, 0)),
            pl.BlockSpec((1, N, N), lambda h, ti: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, nt * C, N), r.dtype),
            jax.ShapeDtypeStruct((B * H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, lwf, uf, s0)
    y = y[:, :S].reshape(B, H, S, N).transpose(0, 2, 1, 3)
    return y, sout.reshape(B, H, N, N)
