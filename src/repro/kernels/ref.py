"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernels are validated against (interpret
mode on CPU, compiled on TPU).  They are deliberately naive — O(S^2)
attention, sequential scans — favouring obviousness over speed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# flash attention oracle
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0, scale=None):
    """q: (B,H,Sq,D), k/v: (B,K,Sk,D) with H % K == 0. fp32 math."""
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    scale = D ** -0.5 if scale is None else scale
    qf = q.astype(jnp.float32).reshape(B, K, G, Sq, D) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Sq)[:, None] + (Sk - Sq)      # align ends (decode-style)
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return o.reshape(B, H, Sq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# RG-LRU oracle: h_t = a_t h_{t-1} + b_t  (sequential scan, fp32)
# ---------------------------------------------------------------------------

def rglru_ref(log_a, b, h0=None):
    """log_a, b: (B,S,L) fp32; h0: (B,L) or None. Returns h (B,S,L)."""
    a = jnp.exp(log_a.astype(jnp.float32))
    bf = b.astype(jnp.float32)
    B, S, L = a.shape
    h = jnp.zeros((B, L), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h, (a.transpose(1, 0, 2), bf.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)


# ---------------------------------------------------------------------------
# RWKV6 WKV oracle (sequential, fp32)
# ---------------------------------------------------------------------------

def wkv_ref(r, k, v, logw, u, state0=None):
    """r,k,v,logw: (B,S,H,N); u: (H,N). Returns (y (B,S,H,N), state (B,H,N,N)).

    y_t = r_t · (S_{t-1} + u ⊙ k_t v_t^T);  S_t = w_t ⊙ S_{t-1} + k_t v_t^T
    (state indexed [key_dim, value_dim])
    """
    B, S, H, N = r.shape
    f32 = jnp.float32
    rf, kf, vf = (x.astype(f32).transpose(1, 0, 2, 3) for x in (r, k, v))
    wf = jnp.exp(logw.astype(f32)).transpose(1, 0, 2, 3)
    st = (jnp.zeros((B, H, N, N), f32) if state0 is None
          else state0.astype(f32))
    uf = u.astype(f32)

    def step(st, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)
        y = jnp.einsum("bhn,bhnm->bhm", rt, st + uf[None, :, :, None] * kv)
        st = wt[..., None] * st + kv
        return st, y

    st, ys = jax.lax.scan(step, st, (rf, kf, vf, wf))
    return ys.transpose(1, 0, 2, 3), st


# ---------------------------------------------------------------------------
# grouped (per-expert) GEMM oracle
# ---------------------------------------------------------------------------

def group_gemm_ref(x, w, n_valid):
    """x: (E,C,D), w: (E,D,F), n_valid: (E,) rows actually used.
    Rows >= n_valid[e] produce zeros."""
    E, C, D = x.shape
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32))
    mask = jnp.arange(C)[None, :] < n_valid[:, None]
    return (out * mask[..., None]).astype(x.dtype)
