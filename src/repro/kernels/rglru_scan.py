"""RG-LRU linear-recurrence kernel for TPU (Pallas).

h_t = a_t ⊙ h_{t-1} + b_t with a_t = exp(log_a_t), carried across
time-blocks in VMEM scratch.  Grid = (batch, lru_blocks, time_blocks) with
time innermost/sequential — the recurrence never leaves VMEM, while the
(batch x lru) dimensions parallelize across cores.

The gate computation (sigmoid projections producing log_a and the gated
input b) is done in plain JAX before the kernel: it is a dense matmul XLA
already fuses well; the kernel owns only the sequential part, which is
what XLA lowers poorly (a length-S while loop with HBM round-trips).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(loga_ref, b_ref, h0_ref, o_ref, h_scr, *, block_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    a = jnp.exp(loga_ref[0].astype(jnp.float32))       # (bt, bl)
    b = b_ref[0].astype(jnp.float32)

    def step(i, h):
        h = a[i] * h + b[i]
        o_ref[0, i] = h.astype(o_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, block_t, step, h_scr[...])


def rglru_scan(log_a, b, h0=None, *, block_t: int = 128, block_l: int = 256,
               interpret: bool = True):
    """log_a, b: (B,S,L) fp32; h0: (B,L) or None -> h (B,S,L) fp32."""
    B, S, L = log_a.shape
    if h0 is None:
        h0 = jnp.zeros((B, L), jnp.float32)
    bt = min(block_t, S)
    bl = min(block_l, L)
    nt = -(-S // bt)
    nl = -(-L // bl)
    pt, plx = nt * bt - S, nl * bl - L
    if pt or plx:
        # pad time with a=1,b=0 (identity steps); pad lru with zeros
        log_a = jnp.pad(log_a, ((0, 0), (0, pt), (0, plx)))
        b = jnp.pad(b, ((0, 0), (0, pt), (0, plx)))
        h0 = jnp.pad(h0, ((0, 0), (0, plx)))

    kernel = functools.partial(_rglru_kernel, block_t=bt)
    out = pl.pallas_call(
        kernel,
        grid=(B, nl, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bl), lambda bi, li, ti: (bi, ti, li)),
            pl.BlockSpec((1, bt, bl), lambda bi, li, ti: (bi, ti, li)),
            pl.BlockSpec((1, bl), lambda bi, li, ti: (bi, li)),
        ],
        out_specs=pl.BlockSpec((1, bt, bl), lambda bi, li, ti: (bi, ti, li)),
        out_shape=jax.ShapeDtypeStruct((B, nt * bt, nl * bl), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bl,), jnp.float32)],
        interpret=interpret,
    )(log_a, b, h0)
    return out[:, :S, :L]
