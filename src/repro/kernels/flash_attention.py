"""Flash attention for TPU (Pallas): causal + sliding-window + GQA + softcap.

TPU-native design (not a CUDA port):

* grid = (batch*q_heads, q_blocks, kv_blocks); the kv dimension is the
  innermost, *sequential* ("arbitrary") grid axis so the fp32 accumulators
  live in VMEM scratch across kv steps — the TPU analogue of a CUDA
  persistent-CTA inner loop.
* BlockSpec tiles are MXU-aligned: (block_q x head_dim) Q tiles against
  (block_k x head_dim) K/V tiles (head_dim multiples of 128 on real TPUs).
* causal / sliding-window block skipping happens at the *grid* level via
  ``pl.when`` — skipped blocks issue no DMA and no MXU work, so banded
  attention costs O(S·W) not O(S²).
* GQA: the K/V BlockSpec index map folds q-head -> kv-head (h // group).
* cross-length (decode/suffix) alignment via ``q_offset = Sk - Sq``.

Validated in interpret mode on CPU against ``ref.attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, window: int, softcap: float,
                block_q: int, block_k: int, seq_k: int, num_kb: int,
                q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_first = qi * block_q + q_offset     # global key-frame position
    q_last = q_first + block_q - 1
    k_first = ki * block_k
    k_last = k_first + block_k - 1

    live = jnp.asarray(True)
    if causal:
        live &= k_first <= q_last
    if window:
        live &= k_last > q_first - window

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qp = q_first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kp = k_first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kp < seq_k
        if causal:
            mask &= kp <= qp
        if window:
            mask &= kp > qp - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None]) * mask
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        m_scr[...] = m_new
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv

    @pl.when(ki == num_kb - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale=None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B,H,Sq,D), k/v: (B,K,Sk,D). Returns (B,H,Sq,D).

    When Sq != Sk the queries are suffix-aligned (query i sits at key
    position Sk - Sq + i) — the decode/chunked-prefill convention.
    """
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    scale = D ** -0.5 if scale is None else scale
    q_offset = Sk - Sq

    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Sk, 8))
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    qpad, kpad = nq * bq - Sq, nk * bk - Sk
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, qpad), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, kpad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, kpad), (0, 0)))

    qf = q.reshape(B * H, nq * bq, D)
    kf = k.reshape(B * K, nk * bk, D)
    vf = v.reshape(B * K, nk * bk, D)

    def kv_index(h, qi, ki):
        return ((h // H) * K + (h % H) // G, ki, 0)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_k=bk, seq_k=Sk, num_kb=nk,
        q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, nq * bq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(B, H, nq * bq, D)
    return out[:, :, :Sq]
