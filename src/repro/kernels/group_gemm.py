"""Grouped per-expert GEMM kernel for TPU (Pallas), MegaBlocks-style
simplified for the capacity-bucketed MoE dispatch.

x: (E, C, D) tokens bucketed per expert, w: (E, D, F) expert weights,
n_valid: (E,) number of real rows per expert.  Blocks whose rows are
entirely padding are *skipped at the grid level* (no DMA, no MXU) — with
load imbalance this saves (1 - load/capacity) of the work, which is the
dropless-MoE insight mapped onto static TPU grids.

Grid = (E, C/bc, F/bf), D contracted in full per block (expert D is the
small fine-grained-expert dim).  n_valid is staged through SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gg_kernel(n_ref, x_ref, w_ref, o_ref, *, block_c: int):
    e = pl.program_id(0)
    ci = pl.program_id(1)
    n = n_ref[0]
    row0 = ci * block_c

    @pl.when(row0 < n)
    def _compute():
        x = x_ref[0].astype(jnp.float32)          # (bc, D)
        w = w_ref[0].astype(jnp.float32)          # (D, bf)
        acc = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, acc.shape, 0)
        acc = jnp.where(rows < n, acc, 0.0)
        o_ref[0] = acc.astype(o_ref.dtype)

    @pl.when(row0 >= n)
    def _skip():
        o_ref[0] = jnp.zeros_like(o_ref[0])


def group_gemm(x, w, n_valid, *, block_c: int = 128, block_f: int = 128,
               interpret: bool = True):
    """x: (E,C,D) @ w: (E,D,F) with per-expert valid counts -> (E,C,F)."""
    E, C, D = x.shape
    F = w.shape[2]
    bc = min(block_c, C)
    bf = min(block_f, F)
    nc = -(-C // bc)
    nf = -(-F // bf)
    pc, pf = nc * bc - C, nf * bf - F
    if pc:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, 0)))
    if pf:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pf)))

    kernel = functools.partial(_gg_kernel, block_c=bc)
    out = pl.pallas_call(
        kernel,
        grid=(E, nc, nf),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM,
                         block_shape=(1,),
                         index_map=lambda e, ci, fi: (e,)),
            pl.BlockSpec((1, bc, D), lambda e, ci, fi: (e, ci, 0)),
            pl.BlockSpec((1, D, bf), lambda e, ci, fi: (e, 0, fi)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ci, fi: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, nc * bc, nf * bf), x.dtype),
        interpret=interpret,
    )(n_valid.astype(jnp.int32), x, w)
    return out[:, :C, :F]
