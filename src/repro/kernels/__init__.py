from repro.kernels import ops, ref
from repro.kernels.ops import flash_attention, group_gemm, rglru_scan, wkv

__all__ = ["ops", "ref", "flash_attention", "group_gemm", "rglru_scan",
           "wkv"]
