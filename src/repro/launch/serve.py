"""Serving driver: batched prefill + decode with KV caches.

Runs a real (smoke-scale on CPU) serving loop: a batch of requests is
prefilled, then decoded token-by-token with the per-arch cache structure
(ring-buffer local windows, MLA latent cache, RG-LRU/RWKV states).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as tfm
from repro.train.steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray           # (B, gen)
    prefill_s: float
    decode_s: float
    tokens_per_sec: float


def serve_batch(arch: str, *, smoke: bool = True, batch: int = 4,
                prompt_len: int = 64, gen: int = 32, max_len: int = 0,
                seed: int = 0, params=None, verbose: bool = True
                ) -> ServeResult:
    cfg = smoke_config(arch) if smoke else get_config(arch)
    mesh = make_local_mesh()
    max_len = max_len or (prompt_len + gen)
    if params is None:
        params = tfm.init_model(cfg, jax.random.PRNGKey(seed))

    rng = np.random.default_rng(seed)
    if cfg.input_kind == "tokens":
        prompts = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
    else:
        prompts = {"embeds": jnp.asarray(
            rng.standard_normal((batch, prompt_len, cfg.d_model)),
            jnp.float32)}

    prefill = jax.jit(make_prefill_step(cfg, max_len, mesh=mesh))
    decode = jax.jit(make_decode_step(cfg, mesh=mesh))

    cache = tfm.init_cache(cfg, batch, max_len)
    t0 = time.time()
    logits, cache = prefill(params, prompts, cache)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t1 = time.time()

    out: List[np.ndarray] = []
    for _ in range(gen):
        out.append(np.asarray(next_tok))
        if cfg.input_kind == "tokens":
            step_in = {"tokens": next_tok[:, None]}
        else:
            # embeddings-stub archs feed the frontend embedding of the token
            emb = jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(seed), int(out[-1][0])),
                (batch, 1, cfg.d_model))
            step_in = {"embeds": emb}
        next_tok, cache = decode(params, step_in, cache)
    t2 = time.time()
    toks = np.stack(out, axis=1)
    dec_s = max(t2 - t1, 1e-9)
    r = ServeResult(tokens=toks, prefill_s=t1 - t0, decode_s=dec_s,
                    tokens_per_sec=batch * gen / dec_s)
    if verbose:
        print(f"{arch}: prefill({batch}x{prompt_len})={r.prefill_s:.2f}s "
              f"decode {gen} steps={r.decode_s:.2f}s "
              f"({r.tokens_per_sec:.1f} tok/s)")
    return r


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    serve_batch(args.arch, smoke=not args.full, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
