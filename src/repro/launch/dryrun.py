import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST run before any other import pulls in jax —
# jax locks the device count on first backend initialization.

"""Multi-pod dry-run driver.

For every (architecture x input-shape) cell, lower + compile the right step
function (train_step / prefill_step / decode_step) against the production
mesh with ShapeDtypeStruct stand-ins (no allocation), record
memory_analysis / cost_analysis / collective bytes, and append the roofline
row to a JSONL cache.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all                 # 16x16
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod     # 2x16x16

Failures here (sharding mismatch, unsupported collective) are bugs in the
system — the run aborts loudly.
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs import (SHAPES, ShapeCfg, cells, get_config, get_shape,
                           shape_applicable)
from repro.launch.mesh import make_production_mesh
from repro.optim import AdamWConfig
from repro.roofline import analysis as ra
from repro.train import steps as steps_mod

CACHE = "benchmarks/results/dryrun_cells.jsonl"


def lower_cell(arch: str, shape: ShapeCfg, mesh, *, opt_cfg=None,
               cfg_override=None):
    """Returns (lowered, cfg). Pure lowering; no compile."""
    cfg = cfg_override or get_config(arch)
    opt_cfg = opt_cfg or AdamWConfig()
    cs = steps_mod.cell_shardings(cfg, shape, mesh, opt_cfg)

    if shape.kind == "train":
        fn = steps_mod.make_train_step(cfg, opt_cfg, mesh=mesh)
        jf = jax.jit(
            fn,
            in_shardings=(cs["params_sharding"], cs["opt_sharding"],
                          cs["batch_sharding"]),
            out_shardings=(cs["params_sharding"], cs["opt_sharding"], None),
        )
        lowered = jf.lower(cs["params"], cs["opt"], cs["batch"])
    elif shape.kind == "prefill":
        fn = steps_mod.make_prefill_step(cfg, shape.seq_len, mesh=mesh)
        jf = jax.jit(
            fn,
            in_shardings=(cs["params_sharding"], cs["batch_sharding"],
                          cs["cache_sharding"]),
            out_shardings=(None, cs["cache_sharding"]),
        )
        lowered = jf.lower(cs["params"], cs["batch"], cs["cache"])
    else:
        fn = steps_mod.make_decode_step(cfg, mesh=mesh)
        jf = jax.jit(
            fn,
            in_shardings=(cs["params_sharding"], cs["batch_sharding"],
                          cs["cache_sharding"]),
            out_shardings=(None, cs["cache_sharding"]),
        )
        lowered = jf.lower(cs["params"], cs["batch"], cs["cache"])
    return lowered, cfg


def run_cell(arch: str, shape: ShapeCfg, *, multi_pod: bool = False,
             verbose: bool = True, cfg_override=None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    t0 = time.time()
    with mesh:
        lowered, cfg = lower_cell(arch, shape, mesh,
                                  cfg_override=cfg_override)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    cell = ra.cell_from_compiled(arch, shape, mesh_name, chips, cfg, compiled)
    row = cell.row()
    row["t_lower_s"] = round(t_lower, 2)
    row["t_compile_s"] = round(t_compile, 2)
    if verbose:
        ma = compiled.memory_analysis()
        print(f"--- {arch} x {shape.name} on {mesh_name} ---")
        print(f"memory_analysis: {ma}")
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        keep = {k: v for k, v in ca.items()
                if k in ("flops", "bytes accessed", "transcendentals")}
        print(f"cost_analysis: {keep}")
        print(f"collectives: {row['coll_breakdown']}")
        print(f"terms: compute={ra.fmt_seconds(row['t_compute_s'])} "
              f"memory={ra.fmt_seconds(row['t_memory_s'])} "
              f"collective={ra.fmt_seconds(row['t_collective_s'])} "
              f"bottleneck={row['bottleneck']} "
              f"MFU_ub={row['mfu_upper_bound']:.2%}")
        print(f"lower={t_lower:.1f}s compile={t_compile:.1f}s", flush=True)
    return row


def _load_cache(path: str) -> dict:
    done = {}
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                done[(r["arch"], r["shape"], r["mesh"])] = r
    return done


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--cache", default=CACHE)
    ap.add_argument("--refresh", action="store_true",
                    help="recompute cells already in the cache")
    args = ap.parse_args(argv)

    os.makedirs(os.path.dirname(args.cache), exist_ok=True)
    done = {} if args.refresh else _load_cache(args.cache)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    todo = []
    if args.all:
        for arch, shape, ok in cells(include_skipped=True):
            for mp in meshes:
                todo.append((arch, shape, mp, ok))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        shape = get_shape(args.shape)
        for mp in meshes:
            todo.append((args.arch, shape,
                         mp, shape_applicable(args.arch, shape)))

    failures = []
    for arch, shape, mp, ok in todo:
        mesh_name = "2x16x16" if mp else "16x16"
        key = (arch, shape.name, mesh_name)
        if key in done:
            print(f"skip (cached): {key}")
            continue
        if not ok:
            row = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
                   "skipped": True,
                   "reason": "long_500k needs sub-quadratic attention "
                             "(pure full-attention arch; DESIGN.md §4)"}
            with open(args.cache, "a") as f:
                f.write(json.dumps(row) + "\n")
            print(f"SKIP {key}: {row['reason']}")
            continue
        try:
            row = run_cell(arch, shape, multi_pod=mp)
            with open(args.cache, "a") as f:
                f.write(json.dumps(row) + "\n")
        except Exception:
            print(f"FAILED {key}")
            traceback.print_exc()
            failures.append(key)
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        return 1
    print("\nall requested cells OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
