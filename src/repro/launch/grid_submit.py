"""Nimrod/G over the TPU fleet: submit an (arch x hyper-parameter) sweep
as a grid experiment with a deadline and a budget.

This is where the paper meets the roofline machinery: each job's duration
estimate on a TPU slice comes from the dry-run's roofline terms
(step_time lower bound x steps), refined online by the scheduler's
measured consumption rates.  Pods are priced per chip-hour by their
owners; the DBC strategy picks the fleet subset.

    PYTHONPATH=src python -m repro.launch.grid_submit \
        --deadline-hours 12 --budget 50000 --strategy cost
"""
from __future__ import annotations

import argparse
import json
import math
import os
from typing import Dict, Optional

from repro.configs import ARCH_IDS, get_config, get_shape
from repro.core import (Dispatcher, NimrodG, Journal, PriceSchedule,
                        ResourceDirectory, ResourceSpec, SimulatedExecutor,
                        Simulator, TradeServer, UserRequirements, parse_plan)
from repro.roofline.analysis import PEAK_FLOPS

HOUR = 3600.0
DRYRUN_CACHE = "benchmarks/results/dryrun_cells.jsonl"


def tpu_fleet(n_pods: int = 24, seed: int = 0):
    """A fleet of TPU v5e pods across sites with owner-set prices."""
    import random
    rng = random.Random(seed)
    sites = ("us-central", "us-east", "europe-west", "asia-ne")
    specs = []
    for i in range(n_pods):
        chips = rng.choice([64, 128, 256, 256])
        specs.append(ResourceSpec(
            name=f"pod-{sites[i % 4]}-{i:02d}", site=sites[i % 4],
            chips=chips,
            peak_flops_per_chip=PEAK_FLOPS,
            perf_factor=rng.choice([0.85, 1.0, 1.0, 1.1]),
            slots=1,
            base_price=0.4 * chips * rng.choice([0.8, 1.0, 1.3]) / 64,
            peak_multiplier=rng.choice([1.0, 1.5, 2.0]),
            mtbf_hours=rng.choice([150.0, 300.0, 600.0]),
            mttr_hours=0.5,
            closed=(rng.random() < 0.25),
            stage_bw=rng.choice([1e9, 10e9]),
        ))
    return specs


def load_step_time_lb(cache: str = DRYRUN_CACHE) -> Dict[str, float]:
    """arch -> roofline step-time lower bound (s) for train_4k on 16x16."""
    out: Dict[str, float] = {}
    if not os.path.exists(cache):
        return out
    with open(cache) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if r.get("skipped") or r.get("shape") != "train_4k" or \
                    r.get("mesh") != "16x16":
                continue
            out[r["arch"]] = max(r["t_compute_s"], r["t_memory_s"],
                                 r["t_collective_s"])
    return out


def est_seconds_fn(step_lbs: Dict[str, float], steps_per_job: int,
                   efficiency: float = 0.35):
    """Roofline LB -> wall estimate on a reference 256-chip pod."""
    def est(point) -> float:
        arch = point.get("arch", "gemma3-1b")
        lb = step_lbs.get(arch, 0.5)
        return steps_per_job * lb / efficiency
    return est


def build_sweep_plan(archs=None, lrs=(1e-3, 3e-4, 1e-4), seeds=(0, 1)):
    archs = archs or list(ARCH_IDS)
    arch_list = " ".join(f'"{a}"' for a in archs)
    lr_list = " ".join(str(v) for v in lrs)
    seed_hi = len(seeds) - 1
    return parse_plan(f"""
parameter arch text select anyof {arch_list}
parameter lr float select anyof {lr_list}
parameter seed integer range from 0 to {seed_hi} step 1
task main
    copy dataset.idx node:.
    execute python -m repro.launch.train --arch $arch --lr $lr --seed $seed
    copy node:metrics.json results/$jobname.json
endtask
""")


def run_grid(deadline_hours: float = 12.0, budget: float = 50_000.0,
             strategy: str = "cost", steps_per_job: int = 2000,
             n_pods: int = 24, seed: int = 0,
             journal_path: Optional[str] = None, verbose: bool = True):
    directory = ResourceDirectory()
    for spec in tpu_fleet(n_pods, seed=seed):
        directory.register(spec)
    schedules = {n: PriceSchedule(directory.spec(n), spot_amplitude=0.15,
                                  phase=hash(n) % 24)
                 for n in directory.all_names()}
    trade = TradeServer(directory, schedules)
    sim = Simulator()
    executor = SimulatedExecutor(sim, directory, seed=seed)
    disp = Dispatcher(executor, directory)

    plan = build_sweep_plan()
    step_lbs = load_step_time_lb()
    req = UserRequirements(deadline=deadline_hours * HOUR, budget=budget,
                           strategy=strategy)
    journal = Journal(journal_path) if journal_path else None
    eng = NimrodG.from_plan(
        "arch-sweep", plan, req, directory, trade, disp,
        est_seconds=est_seconds_fn(step_lbs, steps_per_job),
        stage_in_bytes=2_000_000_000,   # dataset shard + container
        stage_out_bytes=50_000_000,
        sim=sim, journal=journal, seed=seed)
    report = eng.run_simulated()
    if verbose:
        print(report.summary())
        used = sorted(report.resources_used)
        print(f"pods used ({len(used)}): {', '.join(used[:8])}"
              + (" ..." if len(used) > 8 else ""))
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--deadline-hours", type=float, default=12.0)
    ap.add_argument("--budget", type=float, default=50_000.0)
    ap.add_argument("--strategy", default="cost",
                    choices=("cost", "time", "conservative"))
    ap.add_argument("--steps-per-job", type=int, default=2000)
    ap.add_argument("--n-pods", type=int, default=24)
    ap.add_argument("--journal", default=None)
    args = ap.parse_args(argv)
    run_grid(args.deadline_hours, args.budget, args.strategy,
             args.steps_per_job, args.n_pods, journal_path=args.journal)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
