"""Training driver (single-process; any arch at smoke or full scale).

Real training on the local device(s) with the full substrate: synthetic
data pipeline, AdamW + cosine schedule, sharded checkpoint save/restore
with exact data-position resume — the per-job payload the Nimrod/G grid
schedules and restarts.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke \
        --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/run1 --ckpt-every 20
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import latest_step_dir, load_metadata, restore, save
from repro.configs import get_config, smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, abstract_opt_state, init_opt_state
from repro.train.steps import make_train_step


@dataclasses.dataclass
class TrainResult:
    steps: int
    final_loss: float
    losses: list
    tokens_per_sec: float
    restored_from: Optional[str] = None


def run_training(arch: str, *, smoke: bool = True, steps: int = 50,
                 batch: int = 8, seq: int = 256, lr: float = 1e-3,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 seed: int = 0, log_every: int = 10,
                 quantized_moments: bool = False,
                 verbose: bool = True) -> TrainResult:
    cfg = smoke_config(arch) if smoke else get_config(arch)
    mesh = make_local_mesh()
    opt_cfg = AdamWConfig(lr=lr, quantized_moments=quantized_moments)
    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
        seed=seed, input_kind=cfg.input_kind, d_model=cfg.d_model))

    start_step = 0
    restored_from = None
    params = opt_state = None
    if ckpt_dir:
        last = latest_step_dir(ckpt_dir)
        if last is not None:
            meta = load_metadata(last)
            start_step = int(meta["step"])
            aparams = tfm.abstract_model(cfg)
            params = restore(os.path.join(last, "params"), aparams)
            aopt = abstract_opt_state(aparams, opt_cfg)
            opt_state = restore(os.path.join(last, "opt"), aopt)
            restored_from = last
            if verbose:
                print(f"restored step {start_step} from {last}")
    if params is None:
        params = tfm.init_model(cfg, jax.random.PRNGKey(seed))
        opt_state = init_opt_state(params, opt_cfg)

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, mesh=mesh,
                                      total_steps=max(steps, 100)))
    losses = []
    t0 = time.time()
    tokens = 0
    for step in range(start_step, steps):
        b = data.batch(step)
        batch_dev = {k: jax.numpy.asarray(v) for k, v in b.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
        loss = float(metrics["loss"])
        losses.append(loss)
        tokens += batch * seq
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f}", flush=True)
        if not np.isfinite(loss):
            raise FloatingPointError(f"loss diverged at step {step}")
        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            d = os.path.join(ckpt_dir, f"step_{step + 1:07d}")
            save(os.path.join(d, "params"), params,
                 metadata={"step": step + 1, "arch": arch})
            save(os.path.join(d, "opt"), opt_state,
                 metadata={"step": step + 1})
            with open(os.path.join(d, "manifest.json"), "w") as f:
                json.dump({"metadata": {"step": step + 1, "arch": arch},
                           "entries": [], "crcs": {}}, f)
            if verbose:
                print(f"checkpointed -> {d}")
    dt = max(time.time() - t0, 1e-9)
    return TrainResult(steps=steps - start_step,
                       final_loss=losses[-1] if losses else float("nan"),
                       losses=losses,
                       tokens_per_sec=tokens / dt,
                       restored_from=restored_from)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quantized-moments", action="store_true")
    args = ap.parse_args(argv)
    r = run_training(args.arch, smoke=args.smoke, steps=args.steps,
                     batch=args.batch, seq=args.seq, lr=args.lr,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     seed=args.seed,
                     quantized_moments=args.quantized_moments)
    print(f"done: {r.steps} steps, final_loss={r.final_loss:.4f}, "
          f"{r.tokens_per_sec:,.0f} tok/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
