"""Production meshes.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state).  Single pod = 16x16 = 256 chips over ("data", "model");
multi-pod = 2x16x16 = 512 chips with a leading pure-DP "pod" axis whose
gradient all-reduce is the only traffic crossing the pod boundary.

``AxisType`` only exists on newer JAX (>= 0.5); on older installs we
simply omit ``axis_types`` — every mesh here is fully Auto anyway, which
is also the old default.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:  # older jax: no explicit axis types, Auto is implicit
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh() -> Mesh:
    """1x1 mesh on the local device (CPU smoke tests / examples)."""
    return _make_mesh((1, 1), ("data", "model"))


def make_mesh_for(n_devices: int) -> Mesh:
    """Largest (data, model) mesh that fits n_devices (elastic re-slice)."""
    model = 1
    for m in (16, 8, 4, 2, 1):
        if n_devices % m == 0:
            model = m
            break
    return _make_mesh((n_devices // model, model), ("data", "model"))
