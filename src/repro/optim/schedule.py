"""LR schedules (pure functions of step, jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_cosine(step, *, warmup: int, total: int,
                         min_ratio: float = 0.1):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return warm * (min_ratio + (1 - min_ratio) * cos)


def constant(step):
    return jnp.ones_like(step, jnp.float32)
