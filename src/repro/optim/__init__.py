from repro.optim.adamw import (AdamWConfig, OptState, abstract_opt_state,
                               apply_updates, global_norm, init_opt_state)
from repro.optim.schedule import constant, linear_warmup_cosine

__all__ = ["AdamWConfig", "OptState", "abstract_opt_state", "apply_updates",
           "global_norm", "init_opt_state", "constant",
           "linear_warmup_cosine"]
