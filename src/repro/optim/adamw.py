"""AdamW with decoupled weight decay, global-norm clipping, and optional
int8 block-quantized moments (distributed-optimization memory trick; the
quantized states shard exactly like the params, ZeRO-style via FSDP)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantized_moments: bool = False   # int8 m/v with per-block scales
    qblock: int = 256


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


# -- int8 moment quantization ------------------------------------------------

def _is_q(x) -> bool:
    return isinstance(x, dict) and "q" in x and "s" in x


def _q8(x, block):
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    fp = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def _dq8(qd, shape):
    out = (qd["q"].astype(jnp.float32) * qd["s"]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return out[:n].reshape(shape)


def _moment_zeros(p, quantized, block):
    z = jnp.zeros_like(p, jnp.float32)
    return _q8(z, block) if quantized else z


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    zeros = lambda p: _moment_zeros(p, cfg.quantized_moments, cfg.qblock)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def abstract_opt_state(abstract_params, cfg: AdamWConfig) -> OptState:
    def zeros(p):
        if not cfg.quantized_moments:
            return jax.ShapeDtypeStruct(p.shape, jnp.float32)
        n = 1
        for s in p.shape:
            n *= s
        rows = -(-n // cfg.qblock)
        return {"q": jax.ShapeDtypeStruct((rows, cfg.qblock), jnp.int8),
                "s": jax.ShapeDtypeStruct((rows, 1), jnp.float32)}
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                    m=jax.tree.map(zeros, abstract_params),
                    v=jax.tree.map(zeros, abstract_params))


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig,
                  lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else jnp.asarray(1.0, jnp.float32)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = _dq8(m, p.shape) if _is_q(m) else m
        vf = _dq8(v, p.shape) if _is_q(v) else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(g)
        u = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        new_m = _q8(mf, cfg.qblock) if _is_q(m) else mf
        new_v = _q8(vf, cfg.qblock) if _is_q(v) else vf
        return new_p, new_m, new_v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm}
