"""Real-Gated LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block:  x -> [W_x -> causal conv1d(4) -> RG-LRU]  ⊙ GeLU(W_gate x) -> W_out

RG-LRU cell (all elementwise over the lru width):
    r_t = sigmoid(blockdiag(W_a) x_t + b_a)          recurrence gate
    i_t = sigmoid(blockdiag(W_i) x_t + b_i)          input gate
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill lower to ``lax.associative_scan`` (log-depth, parallel);
decode is a single fused step.  The Pallas TPU kernel lives in
``repro.kernels.rglru_scan``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec


def rglru_specs(cfg: ModelConfig) -> dict:
    g = cfg.rglru
    d = cfg.d_model
    lw = g.lru_width or d
    nb = g.num_blocks or cfg.num_heads
    bw = lw // nb
    return {
        "w_x": ParamSpec((d, lw), ("embed", "lru")),
        "w_gate": ParamSpec((d, lw), ("embed", "lru")),
        "conv_w": ParamSpec((g.conv_width, lw), (None, "lru"), fan_dims=(0,)),
        "conv_b": ParamSpec((lw,), ("lru",), init="zeros"),
        "gate_a_w": ParamSpec((nb, bw, bw), (None, None, None), fan_dims=(1,)),
        "gate_a_b": ParamSpec((nb, bw), (None, None), init="zeros"),
        "gate_i_w": ParamSpec((nb, bw, bw), (None, None, None), fan_dims=(1,)),
        "gate_i_b": ParamSpec((nb, bw), (None, None), init="zeros"),
        "lam": ParamSpec((lw,), ("lru",), init="rglru_a", dtype="float32"),
        "w_out": ParamSpec((lw, d), ("lru", "embed")),
    }


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype):
    g = cfg.rglru
    lw = g.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, lw), jnp.float32),
        "conv": jnp.zeros((batch, g.conv_width - 1, lw), dtype),
    }


def abstract_rglru_cache(cfg: ModelConfig, batch: int, dtype):
    g = cfg.rglru
    lw = g.lru_width or cfg.d_model
    return {
        "h": jax.ShapeDtypeStruct((batch, lw), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, g.conv_width - 1, lw),
                                     jnp.dtype(dtype)),
    }


def _gates(cfg, p, xs):
    """xs: (B,S,lw) -> (log_a, gated_input) in fp32."""
    g = cfg.rglru
    nb = g.num_blocks or cfg.num_heads
    B, S, lw = xs.shape
    xb = xs.reshape(B, S, nb, lw // nb).astype(jnp.float32)
    ra = jnp.einsum("bsnk,nkj->bsnj", xb, p["gate_a_w"].astype(jnp.float32))
    ra = jax.nn.sigmoid(ra + p["gate_a_b"].astype(jnp.float32))
    ri = jnp.einsum("bsnk,nkj->bsnj", xb, p["gate_i_w"].astype(jnp.float32))
    ri = jax.nn.sigmoid(ri + p["gate_i_b"].astype(jnp.float32))
    r = ra.reshape(B, S, lw)
    i = ri.reshape(B, S, lw)
    log_a = -cfg.rglru.c_exponent * jax.nn.softplus(p["lam"]) * r
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * xs.astype(jnp.float32)
    return log_a, b


def rglru_scan_ref(log_a, b, h0=None):
    """Associative scan of h_t = a_t h_{t-1} + b_t along axis 1 (fp32)."""
    a = jnp.exp(log_a)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def comb(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    return h


def _causal_conv(p, xs, state=None):
    """Depthwise causal conv over time. xs: (B,S,lw)."""
    w = p["conv_w"].astype(xs.dtype)                 # (W, lw)
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((xs.shape[0], W - 1, xs.shape[2]), xs.dtype)
    else:
        pad = state.astype(xs.dtype)
    xp = jnp.concatenate([pad, xs], axis=1)
    out = sum(xp[:, i:i + xs.shape[1]] * w[i] for i in range(W))
    out = out + p["conv_b"].astype(xs.dtype)
    new_state = xp[:, -(W - 1):] if W > 1 else pad
    return out, new_state


def rglru_layer(cfg: ModelConfig, p: dict, x, *, mode: str,
                cache: Optional[dict]):
    """x: (B,S,d). Returns (out, new_cache)."""
    dt = x.dtype
    xs = x @ p["w_x"].astype(dt)
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dt))

    if mode in ("train", "prefill"):
        conv_state = None if cache is None else cache["conv"]
        xs, new_conv = _causal_conv(p, xs, conv_state)
        log_a, b = _gates(cfg, p, xs)
        h = rglru_scan_ref(log_a, b, None if cache is None else cache["h"])
        new_cache = cache
        if mode == "prefill" and cache is not None:
            new_cache = {"h": h[:, -1].astype(jnp.float32), "conv": new_conv}
        y = (h.astype(dt) * gate) @ p["w_out"].astype(dt)
        return y, new_cache

    assert mode == "decode" and cache is not None
    # single step: xs (B,1,lw)
    w = p["conv_w"].astype(dt)
    hist = jnp.concatenate([cache["conv"].astype(dt), xs], axis=1)  # (B,W,lw)
    conv = jnp.einsum("bwl,wl->bl", hist, w) + p["conv_b"].astype(dt)
    log_a, b = _gates(cfg, p, conv[:, None, :])
    a = jnp.exp(log_a[:, 0])
    h = a * cache["h"] + b[:, 0]
    new_cache = {"h": h, "conv": hist[:, 1:]}
    y = (h[:, None, :].astype(dt) * gate) @ p["w_out"].astype(dt)
    return y, new_cache
