"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time-mix with
data-dependent decay + squared-ReLU channel-mix.

Time-mix (per head, head_dim N):
    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]
    y_t[j]   = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] * k_t[i] * v_t[j])

with per-channel decay w_t = exp(-exp(ww_t)) computed from the token via a
LoRA, and the ddlerp token-shift data-dependent interpolation.

Training lowers to a chunked scan (chunk=64) — parallel within chunks,
sequential across chunk states; decode is a single state update.  The
Pallas TPU kernel lives in ``repro.kernels.rwkv_wkv``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def rwkv_tm_specs(cfg: ModelConfig) -> dict:
    r = cfg.rwkv
    d = cfg.d_model
    H = d // r.head_dim
    L = r.mix_lora
    return {
        "mu_x": ParamSpec((d,), ("embed",), init="normal", scale=0.1),
        "mu": ParamSpec((5, d), (None, "embed"), init="normal", scale=0.1),
        "maa_w1": ParamSpec((d, 5 * L), ("embed", None)),
        "maa_w2": ParamSpec((5, L, d), (None, None, "embed"), fan_dims=(1,)),
        "decay_base": ParamSpec((d,), ("embed",), init="normal", scale=0.5),
        "td_w1": ParamSpec((d, r.decay_lora), ("embed", None)),
        "td_w2": ParamSpec((r.decay_lora, d), (None, "embed"), fan_dims=(0,)),
        "u": ParamSpec((H, r.head_dim), (None, "head_dim"), init="normal",
                       scale=0.5),
        "wr": ParamSpec((d, d), ("embed", None)),
        "wk": ParamSpec((d, d), ("embed", None)),
        "wv": ParamSpec((d, d), ("embed", None)),
        "wg": ParamSpec((d, d), ("embed", None)),
        "ln_x_w": ParamSpec((d,), ("embed",), init="ones"),
        "ln_x_b": ParamSpec((d,), ("embed",), init="zeros"),
        "wo": ParamSpec((d, d), (None, "embed")),
    }


def rwkv_cm_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": ParamSpec((d,), ("embed",), init="normal", scale=0.1),
        "mu_r": ParamSpec((d,), ("embed",), init="normal", scale=0.1),
        "wk": ParamSpec((d, f), ("embed", "mlp")),
        "wv": ParamSpec((f, d), ("mlp", "embed")),
        "wr": ParamSpec((d, d), ("embed", None)),
    }


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype):
    r = cfg.rwkv
    d = cfg.d_model
    H = d // r.head_dim
    return {
        "state": jnp.zeros((batch, H, r.head_dim, r.head_dim), jnp.float32),
        "x_tm": jnp.zeros((batch, d), dtype),
        "x_cm": jnp.zeros((batch, d), dtype),
    }


def abstract_rwkv_cache(cfg: ModelConfig, batch: int, dtype):
    r = cfg.rwkv
    d = cfg.d_model
    H = d // r.head_dim
    dt = jnp.dtype(dtype)
    return {
        "state": jax.ShapeDtypeStruct((batch, H, r.head_dim, r.head_dim),
                                      jnp.float32),
        "x_tm": jax.ShapeDtypeStruct((batch, d), dt),
        "x_cm": jax.ShapeDtypeStruct((batch, d), dt),
    }


# ---------------------------------------------------------------------------
# time-mix
# ---------------------------------------------------------------------------

def _token_shift(x, last):
    """previous-token x; ``last`` is (B,d) carry or None (zeros)."""
    if last is None:
        last = jnp.zeros_like(x[:, 0])
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _ddlerp(p, x, xprev):
    """Data-dependent interpolation -> (xw, xk, xv, xr, xg)."""
    dt = x.dtype
    sx = xprev - x
    base = x + sx * p["mu_x"].astype(dt)
    B, S, d = x.shape
    L5 = p["maa_w1"].shape[1]
    a = jnp.tanh(base @ p["maa_w1"].astype(dt))          # (B,S,5L)
    a = a.reshape(B, S, 5, L5 // 5)
    m = jnp.einsum("bsfl,fld->bsfd", a, p["maa_w2"].astype(dt))  # (B,S,5,d)
    mix = p["mu"].astype(dt)[None, None] + m             # (B,S,5,d)
    outs = tuple(x + sx * mix[:, :, i] for i in range(5))
    return outs                                          # w,k,v,r,g


def _decay(p, xw):
    """per-token per-channel log decay ww (fp32, ~negative)."""
    dt = xw.dtype
    lora = jnp.tanh(xw @ p["td_w1"].astype(dt)) @ p["td_w2"].astype(dt)
    ww = (p["decay_base"].astype(jnp.float32) - 6.0) + lora.astype(jnp.float32)
    return -jnp.exp(ww)                                  # log w_t  (<0)


def wkv_chunked_ref(r, k, v, logw, u, state0=None, chunk: int = 32):
    """Chunked WKV recurrence (fp32).

    r,k,v: (B,S,H,N); logw: (B,S,H,N) log decay; u: (H,N).
    Returns y (B,S,H,N), final state (B,H,N,N) where state[i,j] keys i vals j.
    """
    B, S, H, N = r.shape
    C = min(chunk, S)
    while S % C:
        C //= 2
    nc = S // C
    f32 = jnp.float32
    rs = r.astype(f32).reshape(B, nc, C, H, N)
    ks = k.astype(f32).reshape(B, nc, C, H, N)
    vs = v.astype(f32).reshape(B, nc, C, H, N)
    lw = logw.astype(f32).reshape(B, nc, C, H, N)

    # cumulative decay within chunk: W[t] = exp(sum_{s<=t} logw_s)
    cum = jnp.cumsum(lw, axis=2)                          # (B,nc,C,H,N)
    total = cum[:, :, -1]                                 # (B,nc,H,N)

    def chunk_step(state, inp):
        rc, kc, vc, lwc, cumc, totc = inp                 # (B,C,H,N)...
        # intra-chunk pair (s < t): decay prod_{s<m<=t-1} w_m
        #   = exp(cum_{t-1} - cum_s) = exp((cum_t - logw_t) - cum_s)
        # plus diagonal bonus u for s == t.
        q = rc * jnp.exp(cumc - lwc)                      # (B,C,H,N)
        kk = kc * jnp.exp(-cumc)
        att = jnp.einsum("bthn,bshn->bhts", q, kk)
        tri = jnp.tril(jnp.ones((C, C), bool), k=-1)
        att = att * tri[None, None]
        diag = jnp.einsum("bthn,hn,bthn->bth", rc, u.astype(f32), kc)
        y = jnp.einsum("bhts,bshn->bthn", att, vc)
        y = y + diag[..., None] * vc
        # inter-chunk: carried state decayed to t-1 within the chunk
        y = y + jnp.einsum("bthn,bhnm->bthm", q, state)
        # state update: S' = diag(exp(tot)) S + sum_t k_t exp(tot - cum_t) v_t^T
        kw = kc * jnp.exp(totc[:, None] - cumc)
        state = jnp.exp(totc)[..., None] * state + \
            jnp.einsum("bthn,bthm->bhnm", kw, vc)
        return state, y

    state = (jnp.zeros((B, H, N, N), f32) if state0 is None
             else state0.astype(f32))
    inps = tuple(a.transpose(1, 0, 2, 3, 4) for a in (rs, ks, vs, lw, cum)) \
        + (total.transpose(1, 0, 2, 3),)
    state, ys = jax.lax.scan(chunk_step, state, inps)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, N)
    return y, state


def _group_norm(x, w, b, H, eps=64e-5):
    """Per-head LayerNorm over head_dim. x: (B,S,d)."""
    B, S, d = x.shape
    xh = x.reshape(B, S, H, d // H).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    out = xh.reshape(B, S, d) * w.astype(jnp.float32) + b.astype(jnp.float32)
    return out


def rwkv_time_mix(cfg: ModelConfig, p: dict, x, *, mode: str,
                  cache: Optional[dict]):
    r = cfg.rwkv
    d = cfg.d_model
    H = d // r.head_dim
    N = r.head_dim
    B, S, _ = x.shape
    dt = x.dtype

    last = None if cache is None else cache["x_tm"]
    xprev = _token_shift(x, last) if mode != "decode" else (
        last[:, None] if last is not None else jnp.zeros_like(x))
    xw, xk, xv, xr, xg = _ddlerp(p, x, xprev)
    rr = (xr @ p["wr"].astype(dt)).reshape(B, S, H, N)
    kk = (xk @ p["wk"].astype(dt)).reshape(B, S, H, N)
    vv = (xv @ p["wv"].astype(dt)).reshape(B, S, H, N)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))
    logw = _decay(p, xw).reshape(B, S, H, N)

    state0 = None if cache is None else cache["state"]
    if mode == "train":
        fn = lambda *a: wkv_chunked_ref(*a, state0)
        y, state = jax.checkpoint(fn)(rr, kk, vv, logw, p["u"])
    elif mode == "prefill":
        y, state = wkv_chunked_ref(rr, kk, vv, logw, p["u"], state0)
    else:
        st = state0 if state0 is not None else jnp.zeros((B, H, N, N),
                                                         jnp.float32)
        r1 = rr[:, 0].astype(jnp.float32)
        k1 = kk[:, 0].astype(jnp.float32)
        v1 = vv[:, 0].astype(jnp.float32)
        w1 = jnp.exp(logw[:, 0])
        y1 = jnp.einsum("bhn,bhnm->bhm", r1, st) + \
            jnp.einsum("bhn,hn,bhn,bhm->bhm", r1, p["u"].astype(jnp.float32),
                       k1, v1)
        state = w1[..., None] * st + jnp.einsum("bhn,bhm->bhnm", k1, v1)
        y = y1[:, None].reshape(B, 1, H, N)

    y = _group_norm(y.reshape(B, S, d), p["ln_x_w"], p["ln_x_b"], H)
    y = (y.astype(dt) * g) @ p["wo"].astype(dt)
    new_cache = cache
    if cache is not None:
        new_cache = {"state": state.astype(jnp.float32),
                     "x_tm": x[:, -1].astype(cache["x_tm"].dtype),
                     "x_cm": cache["x_cm"]}
    return y, new_cache


def rwkv_channel_mix(cfg: ModelConfig, p: dict, x, *, mode: str,
                     cache: Optional[dict]):
    dt = x.dtype
    last = None if cache is None else cache["x_cm"]
    xprev = _token_shift(x, last) if mode != "decode" else (
        last[:, None] if last is not None else jnp.zeros_like(x))
    sx = xprev - x
    xk = x + sx * p["mu_k"].astype(dt)
    xr = x + sx * p["mu_r"].astype(dt)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt)))
    kv = k @ p["wv"].astype(dt)
    y = jax.nn.sigmoid(xr @ p["wr"].astype(dt)) * kv
    new_cache = cache
    if cache is not None:
        new_cache = dict(cache)
        new_cache["x_cm"] = x[:, -1].astype(cache["x_cm"].dtype)
    return y, new_cache
