"""Model assembly: embeddings + [prologue | scanned periods | epilogue] + head.

The layer stack is organized as ``cfg.stack_plan()`` dictates:

    prologue (unrolled)  ->  lax.scan over n_periods x layer_pattern  ->  epilogue

Scanned parameters are stacked on a leading ``layers`` axis per
position-in-period, so heterogeneous periods (e.g. gemma3's 5 local + 1
global) scan cleanly.  KV caches mirror the same structure.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import (ParamSpec, abstract_params, init_params,
                                 param_axes, rms_norm, softcap, stack_specs)
from repro.models.mlp import mlp_apply, mlp_specs


def _constrain(x, mesh, spec_dims, seq_shard: bool = False):
    from repro.models.common import constrain_batch
    return constrain_batch(x, mesh, seq_shard=seq_shard,
                           vocab_last=spec_dims)


# ---------------------------------------------------------------------------
# spec tree
# ---------------------------------------------------------------------------

def _layer_specs(cfg: ModelConfig, kind: str, use_moe: bool) -> dict:
    sp: Dict[str, Any] = {"ln1": ParamSpec((cfg.d_model,), ("embed",),
                                           init="zeros")}
    if kind in ("full", "local"):
        sp["attn"] = (mla_mod.mla_specs(cfg) if cfg.mla is not None
                      else attn.attn_specs(cfg))
    elif kind == "rglru":
        sp["rglru"] = rglru_mod.rglru_specs(cfg)
    elif kind == "rwkv":
        sp["rwkv_tm"] = rwkv_mod.rwkv_tm_specs(cfg)
    else:
        raise ValueError(kind)

    sp["ln2"] = ParamSpec((cfg.d_model,), ("embed",), init="zeros")
    if kind == "rwkv":
        sp["rwkv_cm"] = rwkv_mod.rwkv_cm_specs(cfg)
    elif use_moe:
        sp["moe"] = moe_mod.moe_specs(cfg)
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None:   # dense prologue layer of an MoE arch
            d_ff = cfg.moe.d_ff_dense or cfg.d_ff
        sp["mlp"] = mlp_specs(cfg, d_ff=d_ff)
    if cfg.sandwich_norm:
        sp["ln1_post"] = ParamSpec((cfg.d_model,), ("embed",), init="zeros")
        sp["ln2_post"] = ParamSpec((cfg.d_model,), ("embed",), init="zeros")
    return sp


def model_specs(cfg: ModelConfig) -> dict:
    pro, n_periods, epi = cfg.stack_plan()
    sp: Dict[str, Any] = {}
    if cfg.input_kind == "tokens":
        sp["embed"] = ParamSpec((cfg.vocab_size, cfg.d_model),
                                ("vocab", "embed"), init="normal",
                                scale=cfg.d_model ** -0.5)
    else:
        sp["in_norm"] = ParamSpec((cfg.d_model,), ("embed",), init="zeros")
    if not cfg.tie_embeddings or cfg.input_kind != "tokens":
        sp["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                  ("embed", "vocab"))
    sp["final_norm"] = ParamSpec((cfg.d_model,), ("embed",), init="zeros")

    def moe_at(global_idx: int) -> bool:
        return cfg.layer_uses_moe(global_idx)

    sp["prologue"] = [
        _layer_specs(cfg, k, moe_at(i)) for i, k in enumerate(pro)]
    base = len(pro)
    if n_periods:
        period = [
            _layer_specs(cfg, k, moe_at(base + i))
            for i, k in enumerate(cfg.layer_pattern)]
        sp["scan"] = [stack_specs(s, n_periods, "layers") for s in period]
    else:
        sp["scan"] = []
    epi_base = base + n_periods * cfg.period
    sp["epilogue"] = [
        _layer_specs(cfg, k, moe_at(epi_base + i)) for i, k in enumerate(epi)]
    return sp


def init_model(cfg: ModelConfig, key) -> Any:
    return init_params(model_specs(cfg), key, cfg.param_dtype)


def abstract_model(cfg: ModelConfig) -> Any:
    return abstract_params(model_specs(cfg), cfg.param_dtype)


def model_axes(cfg: ModelConfig) -> Any:
    return param_axes(model_specs(cfg))


# ---------------------------------------------------------------------------
# caches (mirror the stack structure)
# ---------------------------------------------------------------------------

def _layer_cache(cfg, kind, batch, max_len, dtype, abstract: bool):
    if kind in ("full", "local"):
        if cfg.mla is not None:
            f = (mla_mod.abstract_mla_cache if abstract
                 else mla_mod.init_mla_cache)
            return f(cfg, batch, max_len, dtype)
        f = (attn.abstract_attn_cache if abstract else attn.init_attn_cache)
        return f(cfg, kind, batch, max_len, dtype)
    if kind == "rglru":
        f = (rglru_mod.abstract_rglru_cache if abstract
             else rglru_mod.init_rglru_cache)
        return f(cfg, batch, dtype)
    if kind == "rwkv":
        f = (rwkv_mod.abstract_rwkv_cache if abstract
             else rwkv_mod.init_rwkv_cache)
        return f(cfg, batch, dtype)
    raise ValueError(kind)


def _stack_cache(tree, n: int, abstract: bool):
    if abstract:
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), tree)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False):
    pro, n_periods, epi = cfg.stack_plan()
    dtype = cfg.dtype
    cache: Dict[str, Any] = {
        "pos": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                else jnp.zeros((), jnp.int32)),
        "prologue": [_layer_cache(cfg, k, batch, max_len, dtype, abstract)
                     for k in pro],
        "epilogue": [_layer_cache(cfg, k, batch, max_len, dtype, abstract)
                     for k in epi],
    }
    if n_periods:
        cache["scan"] = [
            _stack_cache(_layer_cache(cfg, k, batch, max_len, dtype, abstract),
                         n_periods, abstract)
            for k in cfg.layer_pattern]
    else:
        cache["scan"] = []
    return cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_layer(cfg: ModelConfig, kind: str, use_moe: bool, p: dict, x, *,
                 positions, mode: str, cache, mesh):
    """One residual block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("full", "local"):
        if cfg.mla is not None:
            o, cache = mla_mod.mla_layer(cfg, p["attn"], h, positions=positions,
                                         mode=mode, cache=cache, mesh=mesh)
        else:
            o, cache = attn.attention_layer(cfg, kind, p["attn"], h,
                                            positions=positions, mode=mode,
                                            cache=cache, mesh=mesh)
    elif kind == "rglru":
        o, cache = rglru_mod.rglru_layer(cfg, p["rglru"], h, mode=mode,
                                         cache=cache)
    else:  # rwkv time-mix
        o, cache = rwkv_mod.rwkv_time_mix(cfg, p["rwkv_tm"], h, mode=mode,
                                          cache=cache)
    if cfg.sandwich_norm:
        o = rms_norm(o, p["ln1_post"], cfg.norm_eps)
    x = x + o

    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "rwkv":
        o, cache = rwkv_mod.rwkv_channel_mix(cfg, p["rwkv_cm"], h, mode=mode,
                                             cache=cache)
    elif use_moe:
        o, aux = moe_mod.moe_apply(cfg, p["moe"], h, mesh=mesh,
                                   train=(mode == "train"))
    else:
        o = mlp_apply(cfg, p["mlp"], h)
    if cfg.sandwich_norm:
        o = rms_norm(o, p["ln2_post"], cfg.norm_eps)
    x = x + o
    return x, cache, aux


def _remat_wrap(cfg: ModelConfig, fn, mode: str):
    if mode != "train" or cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)   # "full": save only the period carry


def forward(cfg: ModelConfig, params, batch: Dict[str, Any], *, mode: str,
            cache=None, mesh=None, return_hidden: bool = False):
    """Returns (logits, new_cache, aux_loss) — or (hidden, cache, aux) when
    ``return_hidden`` (the chunked-CE path computes logits itself).

    batch: {"tokens": (B,S) int32} or {"embeds": (B,S,d)};
    decode mode uses cache["pos"] for positions.
    """
    pro, n_periods, epi = cfg.stack_plan()
    kinds = cfg.expanded_kinds()
    dt = jnp.dtype(cfg.dtype)

    if cfg.input_kind == "tokens":
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    else:
        x = batch["embeds"].astype(dt)
        x = rms_norm(x, params["in_norm"], cfg.norm_eps)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    x = _constrain(x, mesh, spec_dims=False, seq_shard=cfg.seq_shard)

    B, S = x.shape[:2]
    if mode == "decode":
        pos0 = cache["pos"]
        positions = jnp.broadcast_to(pos0[None, None], (B, 1)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))

    aux_total = jnp.zeros((), jnp.float32)
    new_cache = None if cache is None else dict(cache)

    def run_layer(i_global, kind, p, x, c):
        return _apply_layer(cfg, kind, cfg.layer_uses_moe(i_global), p, x,
                            positions=positions, mode=mode, cache=c, mesh=mesh)

    # prologue
    pro_caches = []
    for i, k in enumerate(pro):
        c = None if cache is None else cache["prologue"][i]
        x, c, aux = run_layer(i, k, params["prologue"][i], x, c)
        aux_total += aux
        pro_caches.append(c)

    # scanned periods
    scan_caches = cache["scan"] if cache is not None else None
    if n_periods:
        base = len(pro)

        def period_body(carry, xs):
            x, aux_acc = carry
            x = _constrain(x, mesh, spec_dims=False,
                           seq_shard=cfg.seq_shard)
            p_list, c_list = xs
            new_c = []
            for j, kind in enumerate(cfg.layer_pattern):
                cj = None if c_list is None else c_list[j]
                x, cj, aux = run_layer(base + j, kind, p_list[j], x, cj)
                aux_acc = aux_acc + aux
                new_c.append(cj)
            return (x, aux_acc), new_c

        body = _remat_wrap(cfg, period_body, mode)
        xs = (params["scan"], scan_caches)
        (x, aux_total), scan_caches = jax.lax.scan(body, (x, aux_total), xs)

    # epilogue
    epi_caches = []
    epi_base = len(pro) + n_periods * cfg.period
    for i, k in enumerate(epi):
        c = None if cache is None else cache["epilogue"][i]
        x, c, aux = run_layer(epi_base + i, k, params["epilogue"][i], x, c)
        aux_total += aux
        epi_caches.append(c)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    x = _constrain(x, mesh, spec_dims=False, seq_shard=cfg.seq_shard)
    if return_hidden:
        if new_cache is not None:
            new_cache["prologue"] = pro_caches
            new_cache["scan"] = scan_caches
            new_cache["epilogue"] = epi_caches
            step = jnp.asarray(1 if mode == "decode" else S, jnp.int32)
            new_cache["pos"] = cache["pos"] + step
        return x, new_cache, aux_total
    if cfg.tie_embeddings and cfg.input_kind == "tokens":
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(dt))
    else:
        logits = x @ params["unembed"].astype(dt)
    logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    logits = _constrain(logits, mesh, spec_dims=True,
                        seq_shard=cfg.seq_shard)

    if new_cache is not None:
        new_cache["prologue"] = pro_caches
        new_cache["scan"] = scan_caches
        new_cache["epilogue"] = epi_caches
        step = jnp.asarray(1 if mode == "decode" else S, jnp.int32)
        new_cache["pos"] = cache["pos"] + step
    return logits, new_cache, aux_total
