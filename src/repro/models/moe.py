"""Mixture-of-Experts FFN (DeepSeek-style shared + fine-grained routed).

Two implementations:

* ``dense`` — every expert computed for every token, combined with the
  top-k mask.  O(E) FLOPs; the numerical oracle for tests.
* ``ep``    — expert-parallel: experts sharded over the ``model`` mesh
  axis, expert weights FSDP-sharded over ``data`` (gathered on use),
  sort-based capacity dispatch per shard, partial outputs psum-combined
  over ``model``.  Tokens never cross data shards (no all-to-all): each
  model shard holds a replica of the activations (standard TP layout) and
  computes the (token, expert) pairs whose expert lives locally — total
  work across the model axis is exactly top_k GEMM pairs per token.

The ``ep`` path runs inside ``jax.shard_map`` (full-manual over the mesh)
and is differentiable; gradients of the FSDP all-gather transpose to
reduce-scatters automatically.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec
from repro.models.mlp import mlp_specs, mlp_apply

try:  # jax >= 0.6 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map


def moe_specs(cfg: ModelConfig) -> dict:
    mo = cfg.moe
    d, E, f = cfg.d_model, mo.num_experts, mo.d_ff_expert
    if cfg.moe_impl == "ep_a2a":
        # token-routed layout: experts over "data", expert-FFN over "model"
        sp = {
            "router": ParamSpec((d, E), (None, None), dtype="float32"),
            "w_gate": ParamSpec((E, d, f), ("experts_dp", None, "expert_tp"),
                                fan_dims=(1,)),
            "w_up": ParamSpec((E, d, f), ("experts_dp", None, "expert_tp"),
                              fan_dims=(1,)),
            "w_down": ParamSpec((E, f, d), ("experts_dp", "expert_tp", None),
                                fan_dims=(1,)),
        }
    else:
        # weight-gathered layout: experts over "model", FSDP-d over "data"
        sp = {
            "router": ParamSpec((d, E), (None, None), dtype="float32"),
            "w_gate": ParamSpec((E, d, f), ("experts", "embed", None),
                                fan_dims=(1,)),
            "w_up": ParamSpec((E, d, f), ("experts", "embed", None),
                              fan_dims=(1,)),
            "w_down": ParamSpec((E, f, d), ("experts", None, "embed"),
                                fan_dims=(1,)),
        }
    if mo.num_shared:
        sp["shared"] = mlp_specs(cfg, d_ff=mo.d_ff_shared)
    return sp


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def router_topk(cfg: ModelConfig, router_w, x):
    """x: (T, d) -> (probs (T,k) f32, ids (T,k) i32, logits (T,E) f32)."""
    mo = cfg.moe
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    scores = jax.nn.softmax(logits, axis=-1)
    probs, ids = jax.lax.top_k(scores, mo.top_k)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    return probs, ids, logits


def aux_load_balance_loss(cfg: ModelConfig, logits, ids):
    """Switch-style load-balance loss over *local* tokens (caller averages)."""
    mo = cfg.moe
    E = mo.num_experts
    scores = jax.nn.softmax(logits, axis=-1)            # (T,E)
    pe = scores.mean(axis=0)                            # mean router prob
    assign = jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(axis=1)  # (T,E)
    fe = assign.mean(axis=0) / mo.top_k                 # fraction routed
    return E * jnp.sum(fe * pe)


# ---------------------------------------------------------------------------
# dense oracle
# ---------------------------------------------------------------------------

def moe_dense(cfg: ModelConfig, p: dict, x):
    """x: (B,S,d). Returns (y, aux_loss)."""
    mo = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    probs, ids, logits = router_topk(cfg, p["router"], xt)
    w = jax.nn.one_hot(ids, mo.num_experts, dtype=probs.dtype)  # (T,k,E)
    w = (w * probs[..., None]).sum(axis=1)                      # (T,E)
    dt = x.dtype
    h_g = jnp.einsum("td,edf->tef", xt, p["w_gate"].astype(dt))
    h_u = jnp.einsum("td,edf->tef", xt, p["w_up"].astype(dt))
    h = jax.nn.silu(h_g) * h_u
    y_e = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(dt))
    y = jnp.einsum("ted,te->td", y_e, w.astype(dt))
    aux = aux_load_balance_loss(cfg, logits, ids)
    y = y.reshape(B, S, d)
    if mo.num_shared:
        y = y + mlp_apply(cfg.replace(mlp="swiglu"), p["shared"], x)
    return y, aux


# ---------------------------------------------------------------------------
# expert-parallel path
# ---------------------------------------------------------------------------

def _ep_local(cfg: ModelConfig, capacity: int, n_model: int, batch_axes,
              n_batch: int, xt, router_w, w_gate, w_up, w_down):
    """Per-device body. xt: (T_loc, d) replicated over 'model';
    w_*: (E_loc, d/Dd, f) sharded over ('model','data')."""
    mo = cfg.moe
    E, k = mo.num_experts, mo.top_k
    e_loc = E // n_model
    shard = jax.lax.axis_index("model")
    dt = xt.dtype
    T = xt.shape[0]

    probs, ids, logits = router_topk(cfg, router_w, xt)

    flat_ids = ids.reshape(-1)                              # (T*k,)
    flat_w = probs.reshape(-1)
    tok = jnp.arange(T * k, dtype=jnp.int32) // k
    local = (flat_ids // e_loc) == shard
    loc_eid = jnp.where(local, flat_ids - shard * e_loc, e_loc)  # e_loc=overflow

    order = jnp.argsort(loc_eid, stable=True)
    sk = loc_eid[order]                                     # sorted keys
    stok = tok[order]
    sw = flat_w[order]
    # position within the expert group
    first = jnp.searchsorted(sk, sk, side="left")
    gpos = jnp.arange(T * k, dtype=jnp.int32) - first.astype(jnp.int32)
    valid = (sk < e_loc) & (gpos < capacity)
    slot = jnp.where(valid, sk * capacity + gpos, e_loc * capacity)

    xg = jnp.take(xt, stok, axis=0)                         # (T*k, d)
    buf = jnp.zeros((e_loc * capacity, xt.shape[1]), dt)
    buf = buf.at[slot].set(jnp.where(valid[:, None], xg, 0), mode="drop")
    buf = buf.reshape(e_loc, capacity, -1)

    # FSDP gather of expert weights over the data axis
    wg = jax.lax.all_gather(w_gate, "data", axis=1, tiled=True).astype(dt)
    wu = jax.lax.all_gather(w_up, "data", axis=1, tiled=True).astype(dt)
    wd = jax.lax.all_gather(w_down, "data", axis=2, tiled=True).astype(dt)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
        jnp.einsum("ecd,edf->ecf", buf, wu)
    y = jnp.einsum("ecf,efd->ecd", h, wd)                   # (E_loc,C,d)

    flat_y = y.reshape(e_loc * capacity, -1)
    contrib = jnp.take(flat_y, jnp.minimum(slot, e_loc * capacity - 1), axis=0)
    contrib = jnp.where(valid[:, None], contrib * sw[:, None].astype(dt), 0)
    out = jnp.zeros_like(xt).at[stok].add(contrib)
    out = jax.lax.psum(out, "model")

    aux = aux_load_balance_loss(cfg, logits, ids)
    if batch_axes:
        aux = jax.lax.psum(aux, batch_axes) / n_batch
    return out, aux


def moe_ep(cfg: ModelConfig, p: dict, x, *, mesh, train: bool):
    """x: (B,S,d). Returns (y, aux_loss). Runs under shard_map."""
    mo = cfg.moe
    B, S, d = x.shape
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    n_model = mesh.shape["model"]
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    if (B * S) % n_batch:
        batch_axes, n_batch = (), 1       # tiny batches: replicate tokens
    T_loc = (B * S) // n_batch
    cf = mo.capacity_factor if train else mo.eval_capacity_factor
    if T_loc * mo.top_k <= 256:
        # tiny per-shard batches (decode): dropless — capacity covers the
        # worst case of every assignment landing on one local expert
        capacity = T_loc * mo.top_k
    else:
        capacity = max(1, int(-(-T_loc * mo.top_k * cf // mo.num_experts)))

    xt = x.reshape(B * S, d)
    body = functools.partial(_ep_local, cfg, capacity, n_model, batch_axes,
                             n_batch)
    tspec = P(batch_axes if batch_axes else None, None)
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(tspec, P(None, None), P("model", "data", None),
                  P("model", "data", None), P("model", None, "data")),
        out_specs=(tspec, P()),
    )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    y = y.reshape(B, S, d)
    if mo.num_shared:
        y = y + mlp_apply(cfg.replace(mlp="swiglu"), p["shared"], x)
    return y, aux


# ---------------------------------------------------------------------------
# token-routed expert parallelism (all-to-all over "data"): experts sharded
# over "data", expert FFN dim over "model".  Tokens move (k*d bytes each)
# instead of weights (E_loc*d*f per layer) — wins when tokens-per-chip is
# small (decode); the weight-gathered "ep" path wins for training.
# ---------------------------------------------------------------------------

def _a2a_local(cfg: ModelConfig, cap_out: int, cap_exp: int, n_data: int,
               n_model: int, batch_axes, n_batch: int, xt, router_w,
               w_gate, w_up, w_down):
    """xt: (T_loc, d) batch-sharded over (pod,data), replicated over model;
    w_*: (E/n_data, d, f/n_model) resident (no gather)."""
    mo = cfg.moe
    E, k = mo.num_experts, mo.top_k
    e_loc = E // n_data
    dt = xt.dtype
    T = xt.shape[0]
    d = xt.shape[1]

    probs, ids, logits = router_topk(cfg, router_w, xt)
    flat_ids = ids.reshape(-1)
    flat_w = probs.reshape(-1)
    tok = jnp.arange(T * k, dtype=jnp.int32) // k
    dest = flat_ids // e_loc                                # owning data shard

    # bucket assignments by destination shard (capacity cap_out per peer)
    order = jnp.argsort(dest, stable=True)
    sd = dest[order]
    stok = tok[order]
    sw = flat_w[order]
    seid = (flat_ids % e_loc)[order]
    first = jnp.searchsorted(sd, sd, side="left")
    gpos = jnp.arange(T * k, dtype=jnp.int32) - first.astype(jnp.int32)
    valid = gpos < cap_out
    slot = jnp.where(valid, sd * cap_out + gpos, n_data * cap_out)

    send_x = jnp.zeros((n_data * cap_out, d), dt)
    send_x = send_x.at[slot].set(
        jnp.where(valid[:, None], jnp.take(xt, stok, axis=0), 0),
        mode="drop")
    send_e = jnp.full((n_data * cap_out,), -1, jnp.int32)
    send_e = send_e.at[slot].set(jnp.where(valid, seid, -1), mode="drop")

    rx = jax.lax.all_to_all(send_x.reshape(n_data, cap_out, d), "data",
                            split_axis=0, concat_axis=0, tiled=False)
    re = jax.lax.all_to_all(send_e.reshape(n_data, cap_out), "data",
                            split_axis=0, concat_axis=0, tiled=False)
    rx = rx.reshape(n_data * cap_out, d)
    re = re.reshape(n_data * cap_out)

    # bucket received tokens by local expert
    key2 = jnp.where(re >= 0, re, e_loc)
    order2 = jnp.argsort(key2, stable=True)
    sk2 = key2[order2]
    first2 = jnp.searchsorted(sk2, sk2, side="left")
    gpos2 = jnp.arange(sk2.shape[0], dtype=jnp.int32) - first2.astype(jnp.int32)
    valid2 = (sk2 < e_loc) & (gpos2 < cap_exp)
    slot2 = jnp.where(valid2, sk2 * cap_exp + gpos2, e_loc * cap_exp)
    buf = jnp.zeros((e_loc * cap_exp, d), dt)
    buf = buf.at[slot2].set(
        jnp.where(valid2[:, None], jnp.take(rx, order2, axis=0), 0),
        mode="drop")
    buf = buf.reshape(e_loc, cap_exp, d)

    wg, wu, wd = (w.astype(dt) for w in (w_gate, w_up, w_down))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
        jnp.einsum("ecd,edf->ecf", buf, wu)
    y = jnp.einsum("ecf,efd->ecd", h, wd)                   # partial over f
    y = jax.lax.psum(y, "model")

    flat_y = y.reshape(e_loc * cap_exp, d)
    y_sorted = jnp.take(flat_y, jnp.minimum(slot2, e_loc * cap_exp - 1),
                        axis=0)
    y_sorted = jnp.where(valid2[:, None], y_sorted, 0)
    y_rx = jnp.zeros((n_data * cap_out, d), dt).at[order2].set(y_sorted)

    y_back = jax.lax.all_to_all(y_rx.reshape(n_data, cap_out, d), "data",
                                split_axis=0, concat_axis=0, tiled=False)
    y_back = y_back.reshape(n_data * cap_out, d)

    contrib = jnp.take(y_back, jnp.minimum(slot, n_data * cap_out - 1),
                       axis=0)
    contrib = jnp.where(valid[:, None], contrib * sw[:, None].astype(dt), 0)
    out = jnp.zeros_like(xt).at[stok].add(contrib)

    aux = aux_load_balance_loss(cfg, logits, ids)
    if batch_axes:
        aux = jax.lax.psum(aux, batch_axes) / n_batch
    return out, aux


def moe_a2a(cfg: ModelConfig, p: dict, x, *, mesh, train: bool):
    mo = cfg.moe
    B, S, d = x.shape
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    n_model = mesh.shape["model"]
    n_data = mesh.shape.get("data", 1)
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    if (B * S) % n_batch:
        batch_axes, n_batch = (), 1
    T_loc = (B * S) // n_batch
    cf = mo.capacity_factor if train else mo.eval_capacity_factor
    if T_loc * mo.top_k <= 256:
        cap_out = T_loc * mo.top_k                      # dropless decode
    else:
        cap_out = max(1, int(-(-T_loc * mo.top_k * cf // n_data)))
    cap_exp = max(1, int(-(-n_data * cap_out * 2 // max(mo.num_experts
                                                        // n_data, 1))))

    xt = x.reshape(B * S, d)
    body = functools.partial(_a2a_local, cfg, cap_out, cap_exp, n_data,
                             n_model, batch_axes, n_batch)
    tspec = P(batch_axes if batch_axes else None, None)
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(tspec, P(None, None), P("data", None, "model"),
                  P("data", None, "model"), P("data", "model", None)),
        out_specs=(tspec, P()),
    )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    y = y.reshape(B, S, d)
    if mo.num_shared:
        y = y + mlp_apply(cfg.replace(mlp="swiglu"), p["shared"], x)
    return y, aux


def moe_apply(cfg: ModelConfig, p: dict, x, *, mesh=None, train: bool = True):
    if cfg.moe_impl == "dense" or mesh is None:
        return moe_dense(cfg, p, x)
    if cfg.moe_impl == "ep_a2a":
        return moe_a2a(cfg, p, x, mesh=mesh, train=train)
    return moe_ep(cfg, p, x, mesh=mesh, train=train)
