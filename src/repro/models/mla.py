"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Train/prefill use the naive (decompressed) form; decode uses the
*absorbed* form where W_UK / W_UV are folded into the query / output so
the KV cache is just the (kv_lora + rope) latent per token — the paper's
serving-memory contribution.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, apply_rope, rms_norm
from repro.models.attention import (NEG_INF, blockwise_attention,
                                    reference_attention)


def mla_specs(cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_a": ParamSpec((d, m.q_lora_rank), ("embed", "q_lora")),
        "q_norm": ParamSpec((m.q_lora_rank,), ("q_lora",), init="zeros"),
        "wq_b": ParamSpec((m.q_lora_rank, H, qk), ("q_lora", "heads", None)),
        "wkv_a": ParamSpec((d, m.kv_lora_rank + m.qk_rope_dim),
                           ("embed", "kv_lora")),
        "kv_norm": ParamSpec((m.kv_lora_rank,), ("kv_lora",), init="zeros"),
        "wkv_b": ParamSpec((m.kv_lora_rank, H, m.qk_nope_dim + m.v_dim),
                           ("kv_lora", "heads", None)),
        "wo": ParamSpec((H, m.v_dim, d), ("heads", None, "embed"),
                        fan_dims=(0, 1)),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
        "t": jnp.full((max_len,), -(2 ** 30), jnp.int32),
    }


def abstract_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    dt = jnp.dtype(dtype)
    return {
        "ckv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), dt),
        "kr": jax.ShapeDtypeStruct((batch, max_len, m.qk_rope_dim), dt),
        "t": jax.ShapeDtypeStruct((max_len,), jnp.int32),
    }


def _project_q(cfg, p, x):
    m = cfg.mla
    dt = x.dtype
    cq = x @ p["wq_a"].astype(dt)
    cq = rms_norm(cq, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"].astype(dt))
    return q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]


def _project_kv_latent(cfg, p, x, positions):
    """Returns (ckv_normed (B,S,R), k_rope (B,S,rope))."""
    m = cfg.mla
    dt = x.dtype
    ckv = x @ p["wkv_a"].astype(dt)
    c, kr = ckv[..., :m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
    c = rms_norm(c, p["kv_norm"], cfg.norm_eps)
    kr = apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c, kr


def mla_layer(cfg: ModelConfig, p: dict, x, *, positions, mode: str,
              cache: Optional[dict], mesh=None):
    m = cfg.mla
    H = cfg.num_heads
    dt = x.dtype
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5

    if mode in ("train", "prefill"):
        qn, qr = _project_q(cfg, p, x)
        qr = apply_rope(qr, positions, cfg.rope_theta)
        c, kr = _project_kv_latent(cfg, p, x, positions)
        kv = jnp.einsum("bsr,rhk->bshk", c, p["wkv_b"].astype(dt))
        kn, v = kv[..., :m.qk_nope_dim], kv[..., m.qk_nope_dim:]
        q = jnp.concatenate([qn, qr], axis=-1)
        k = jnp.concatenate(
            [kn, jnp.broadcast_to(kr[:, :, None, :], qr.shape[:2] + (H, m.qk_rope_dim))],
            axis=-1)
        qp = positions[0] if positions.ndim > 1 else positions
        S = x.shape[1]
        if S > 2048 and cfg.attn_impl != "reference":
            fn = lambda q_, k_, v_: blockwise_attention(
                q_, k_, v_, q_pos=qp, k_pos=qp, scale=scale)
            o = jax.checkpoint(fn)(q, k, v) if mode == "train" \
                else fn(q, k, v)
        else:
            o = reference_attention(q, k, v, q_pos=qp, k_pos=qp, scale=scale)
        new_cache = cache
        if mode == "prefill" and cache is not None:
            L = cache["ckv"].shape[1]
            padn = L - S
            t = jnp.pad(jnp.arange(S, dtype=jnp.int32), (0, padn),
                        constant_values=-(2 ** 30))
            new_cache = {
                "ckv": jnp.pad(c, ((0, 0), (0, padn), (0, 0))).astype(cache["ckv"].dtype),
                "kr": jnp.pad(kr, ((0, 0), (0, padn), (0, 0))).astype(cache["kr"].dtype),
                "t": t,
            }
        out = jnp.einsum("bshv,hvd->bsd", o, p["wo"].astype(dt))
        return out, new_cache

    # ---- decode: absorbed form over the latent cache ----
    assert mode == "decode" and cache is not None
    pos = positions.reshape(-1)[0]
    qn, qr = _project_q(cfg, p, x)                       # (B,1,H,*)
    qr = apply_rope(qr, positions, cfg.rope_theta)
    c_new, kr_new = _project_kv_latent(cfg, p, x, positions)
    from repro.models.common import constrain_batch
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], c_new.astype(cache["ckv"].dtype), pos, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(
        cache["kr"], kr_new.astype(cache["kr"].dtype), pos, axis=1)
    # split-KV: latent cache sequence sharded over "model" (see attention)
    ckv = constrain_batch(ckv, mesh, seq_shard=True)
    kr = constrain_batch(kr, mesh, seq_shard=True)
    t = jax.lax.dynamic_update_slice_in_dim(
        cache["t"], pos[None].astype(jnp.int32), pos, axis=0)

    w_uk = p["wkv_b"][..., :m.qk_nope_dim].astype(dt)    # (R,H,nope)
    w_uv = p["wkv_b"][..., m.qk_nope_dim:].astype(dt)    # (R,H,v)
    q_lat = jnp.einsum("bshk,rhk->bshr", qn, w_uk)       # absorb W_UK
    s = jnp.einsum("bshr,btr->bhst", q_lat, ckv.astype(dt))
    s = s + jnp.einsum("bshk,btk->bhst", qr, kr.astype(dt))
    s = (s * scale).astype(jnp.float32)
    valid = (t >= 0) & (t <= pos)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", pr.astype(dt), ckv.astype(dt))
    o = jnp.einsum("bshr,rhv->bshv", ctx, w_uv)          # absorb W_UV
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"].astype(dt))
    return out, {"ckv": ckv, "kr": kr, "t": t}
