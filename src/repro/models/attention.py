"""Attention layers: full/global, sliding-window local, GQA, decode paths.

Three implementations share one math definition (``ref`` in
``repro.kernels.ref`` mirrors these):

* ``reference`` — plain einsum + mask; O(S^2) materialized (small S only).
* ``blockwise`` — lax.scan over KV blocks with online softmax; flash-style
  peak memory, used for long sequences and as the dry-run lowering path.
* ``pallas``    — TPU kernel (``repro.kernels``); selected on TPU backends.

Local (sliding-window) attention uses an exact two-chunk banded layout so
FLOPs scale with S*W, not S^2.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, apply_rope, rms_norm, softcap

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig) -> dict:
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    sp = {
        "wq": ParamSpec((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, hd, d), ("heads", "head_dim", "embed"),
                        fan_dims=(0, 1)),
    }
    if cfg.qk_norm:
        sp["q_norm"] = ParamSpec((hd,), ("head_dim",), init="zeros")
        sp["k_norm"] = ParamSpec((hd,), ("head_dim",), init="zeros")
    return sp


# ---------------------------------------------------------------------------
# core math
# ---------------------------------------------------------------------------

def _grouped_scores(q, k):
    """q: (B,Sq,K,G,D)  k: (B,Sk,K,D) -> scores (B,K,G,Sq,Sk)."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                      preferred_element_type=jnp.float32)


def _grouped_out(p, v):
    """p: (B,K,G,Sq,Sk)  v: (B,Sk,K,D) -> (B,Sq,K,G,D)."""
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(p.dtype))


def _causal_mask(q_pos, k_pos, window: int = 0):
    """(Sq,1) x (Sk,) position tensors -> bool mask (Sq,Sk). True=keep."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def reference_attention(q, k, v, *, q_pos, k_pos, window=0, cap=0.0,
                        scale=None):
    """q: (B,Sq,H,D), k/v: (B,Sk,K,D). Returns (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, K, G, D) * scale
    s = _grouped_scores(qg, k)                              # (B,K,G,Sq,Sk)
    s = softcap(s, cap)
    mask = _causal_mask(q_pos, k_pos, window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = _grouped_out(p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, v.shape[-1])


def blockwise_attention(q, k, v, *, q_pos, k_pos, window=0, cap=0.0,
                        scale=None, block_kv=1024):
    """Online-softmax over KV blocks (flash-style peak memory).

    Wrapped in jax.checkpoint by callers for training so backward
    recomputes block scores instead of saving per-block probabilities
    (the FlashAttention backward trade: +1 fwd pass, O(S*D) residuals).
    """
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    nb = -(-Sk // block_kv)
    pad = nb * block_kv - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=2 ** 30)
    Dv = v.shape[-1]
    qg = (q.reshape(B, Sq, K, G, D) * scale)
    kb = k.reshape(B, nb, block_kv, K, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block_kv, K, Dv).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(nb, block_kv)

    def body(carry, blk):
        m, l, acc = carry
        kc, vc, pc = blk
        s = _grouped_scores(qg, kc)                         # (B,K,G,Sq,c)
        s = softcap(s, cap)
        msk = _causal_mask(q_pos, pc, window)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        # zero fully-masked entries explicitly (NEG_INF - NEG_INF == 0 trap)
        p = jnp.exp(s - m_new[..., None]) * msk[None, None, None]
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv).astype(q.dtype)


def local_attention_chunked(q, k, v, *, window: int, cap=0.0, scale=None,
                            q_offset=0):
    """Exact causal sliding-window attention in banded two-chunk form.

    FLOPs ~ S * 2W.  Requires S % W == 0 (callers pad).
    q: (B,S,H,D), k/v: (B,S,K,D), window W = chunk size.
    """
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    W = window
    assert S % W == 0, (S, W)
    n = S // W
    scale = scale if scale is not None else D ** -0.5
    qc = (q.reshape(B, n, W, K, G, D) * scale)
    kc = k.reshape(B, n, W, K, D)
    vc = v.reshape(B, n, W, K, D)
    # previous chunk (zeros before chunk 0)
    kp = jnp.pad(kc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    vp = jnp.pad(vc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    k2 = jnp.concatenate([kp, kc], axis=2)                  # (B,n,2W,K,D)
    v2 = jnp.concatenate([vp, vc], axis=2)
    s = jnp.einsum("bnqkgd,bnskd->bnkgqs", qc, k2,
                   preferred_element_type=jnp.float32)      # (B,n,K,G,W,2W)
    s = softcap(s, cap)
    qpos = jnp.arange(W)[:, None] + W                       # within 2W frame
    kpos = jnp.arange(2 * W)[None, :]
    m = (kpos <= qpos) & (kpos > qpos - W)
    # chunk 0 has no previous chunk: mask the zero-padding keys
    first = jnp.arange(n)[:, None, None] == 0
    valid = jnp.where(first, kpos[None] >= W, True)         # (n,W,2W) broadcast
    msk = m[None] & valid                                   # (n,W,2W)
    s = jnp.where(msk[None, :, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnkgqs,bnskd->bnqkgd", p.astype(v2.dtype), v2)
    return o.reshape(B, S, H, D)


def decode_attention(q, k_cache, v_cache, *, key_mask, cap=0.0, scale=None):
    """Single-token decode. q: (B,1,H,D), caches: (B,S,K,D), key_mask: (B,S)."""
    B, _, H, D = q.shape
    K = k_cache.shape[2]
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, K, G, D) * scale
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32)
    s = softcap(s, cap)
    s = jnp.where(key_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, D)


# ---------------------------------------------------------------------------
# layer: projections + rope + cache handling
# ---------------------------------------------------------------------------

def _maybe_qk_norm(cfg, p, q, k):
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k


def _rope_theta(cfg: ModelConfig, kind: str) -> float:
    if kind == "full" and cfg.rope_theta_global is not None:
        return cfg.rope_theta_global
    return cfg.rope_theta


def attention_layer(cfg: ModelConfig, kind: str, p: dict, x, *, positions,
                    mode: str, cache: Optional[dict], mesh=None):
    """Returns (out (B,S,d), new_cache)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    q, k = _maybe_qk_norm(cfg, p, q, k)
    theta = _rope_theta(cfg, kind)
    q = apply_rope(q, positions, theta, cfg.rope_fraction)
    k = apply_rope(k, positions, theta, cfg.rope_fraction)
    window = cfg.window_size if kind == "local" else 0
    cap = cfg.attn_logit_softcap
    scale = cfg.head_dim ** -0.5

    new_cache = cache
    if mode == "train":
        S = x.shape[1]
        qp = positions[0] if positions.ndim > 1 else positions
        if kind == "local" and window and S % window == 0 and S > window:
            fn = lambda q_, k_, v_: local_attention_chunked(
                q_, k_, v_, window=window, cap=cap, scale=scale)
            o = jax.checkpoint(fn)(q, k, v)
        elif S > 2048 and cfg.attn_impl != "reference":
            fn = lambda q_, k_, v_: blockwise_attention(
                q_, k_, v_, q_pos=qp, k_pos=qp, window=window, cap=cap,
                scale=scale)
            o = jax.checkpoint(fn)(q, k, v)
        else:
            o = reference_attention(q, k, v, q_pos=qp, k_pos=qp,
                                    window=window, cap=cap, scale=scale)
    elif mode == "prefill":
        S = x.shape[1]
        qp = positions[0] if positions.ndim > 1 else positions
        if kind == "local" and window and S % window == 0 and S > window:
            o = local_attention_chunked(q, k, v, window=window, cap=cap,
                                        scale=scale)
        else:
            o = blockwise_attention(q, k, v, q_pos=qp, k_pos=qp,
                                    window=window, cap=cap, scale=scale)
        new_cache = _write_prefill_cache(cfg, kind, cache, k, v, positions)
    elif mode == "decode":
        o, new_cache = _decode_with_cache(cfg, kind, cache, q, k, v,
                                          positions, cap, scale, mesh=mesh)
    else:
        raise ValueError(mode)

    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    return out, new_cache


# -- caches -----------------------------------------------------------------

def init_attn_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                    dtype) -> dict:
    K, hd = cfg.num_kv_heads, cfg.head_dim
    W = cfg.window_size if kind == "local" else max_len
    W = min(W, max_len) or max_len
    return {
        "k": jnp.zeros((batch, W, K, hd), dtype),
        "v": jnp.zeros((batch, W, K, hd), dtype),
        "t": jnp.full((W,), -(2 ** 30), jnp.int32),   # global time per slot
    }


def abstract_attn_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                        dtype) -> dict:
    K, hd = cfg.num_kv_heads, cfg.head_dim
    W = cfg.window_size if kind == "local" else max_len
    W = min(W, max_len) or max_len
    return {
        "k": jax.ShapeDtypeStruct((batch, W, K, hd), jnp.dtype(dtype)),
        "v": jax.ShapeDtypeStruct((batch, W, K, hd), jnp.dtype(dtype)),
        "t": jax.ShapeDtypeStruct((W,), jnp.int32),
    }


def _write_prefill_cache(cfg, kind, cache, k, v, positions):
    if cache is None:
        return None
    W = cache["k"].shape[1]
    S = k.shape[1]
    if W >= S:
        kw = jnp.pad(k, ((0, 0), (0, W - S), (0, 0), (0, 0)))
        vw = jnp.pad(v, ((0, 0), (0, W - S), (0, 0), (0, 0)))
        t = jnp.pad(jnp.arange(S, dtype=jnp.int32), (0, W - S),
                    constant_values=-(2 ** 30))
        return {"k": kw.astype(cache["k"].dtype),
                "v": vw.astype(cache["v"].dtype), "t": t}
    # keep last W keys (ring layout: slot = t % W)
    tail_t = jnp.arange(S - W, S, dtype=jnp.int32)
    roll = (S - W) % W
    kt = jnp.roll(k[:, -W:], roll, axis=1)
    vt = jnp.roll(v[:, -W:], roll, axis=1)
    t = jnp.roll(tail_t, roll)
    return {"k": kt.astype(cache["k"].dtype), "v": vt.astype(cache["v"].dtype),
            "t": t}


def _decode_with_cache(cfg, kind, cache, q, k, v, positions, cap, scale,
                       mesh=None):
    """positions: (B,1) current global position (uniform across batch)."""
    pos = positions.reshape(-1)[0]
    W = cache["k"].shape[1]
    slot = pos % W
    from repro.models.common import constrain_batch
    kc = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    # pin caches: batch over (pod,data), cache *sequence* over "model"
    # (flash-decode split-KV: each model shard scans 1/16 of the cache;
    # softmax over the sharded axis reduces with tiny per-head scalars).
    # Stops SPMD from partially sharding kv heads and re-gathering the
    # whole cache as one giant all-gather.
    kc = constrain_batch(kc, mesh, seq_shard=True)
    vc = constrain_batch(vc, mesh, seq_shard=True)
    t = jax.lax.dynamic_update_slice_in_dim(
        cache["t"], pos[None].astype(jnp.int32), slot, axis=0)
    window = cfg.window_size if kind == "local" else 0
    valid = (t >= 0) & (t <= pos)
    if window:
        valid &= t > pos - window
    key_mask = jnp.broadcast_to(valid[None, :], (q.shape[0], W))
    o = decode_attention(q, kc, vc, key_mask=key_mask, cap=cap, scale=scale)
    return o, {"k": kc, "v": vc, "t": t}
