"""Dense feed-forward variants: SwiGLU / GeGLU / GELU / squared-ReLU,
plus the RWKV channel-mix (which lives in rwkv6.py)."""
from __future__ import annotations

import jax.numpy as jnp
import jax

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, activation


def mlp_specs(cfg: ModelConfig, d_ff: int | None = None,
              mlp_axis: str = "mlp") -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    gated = cfg.mlp in ("swiglu", "geglu")
    sp = {
        "w_up": ParamSpec((d, f), ("embed", mlp_axis)),
        "w_down": ParamSpec((f, d), (mlp_axis, "embed")),
    }
    if gated:
        sp["w_gate"] = ParamSpec((d, f), ("embed", mlp_axis))
    return sp


def mlp_apply(cfg: ModelConfig, p: dict, x):
    dt = x.dtype
    up = x @ p["w_up"].astype(dt)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * up
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"].astype(dt)) * up
    else:
        h = activation(cfg.mlp)(up)
    return h @ p["w_down"].astype(dt)
