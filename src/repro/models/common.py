"""Shared model machinery.

Single source of truth for parameters is a *spec tree*: a pytree whose
leaves are :class:`ParamSpec`.  From the same spec tree we derive

* ``init_params``      — materialized random arrays (trainable state),
* ``abstract_params``  — ``jax.ShapeDtypeStruct`` stand-ins (dry-run),
* ``param_axes``       — logical-axis name tuples (sharding),

so shapes, shardings and initialization can never drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis per dim
    init: str = "fan_in"                     # fan_in | zeros | ones | normal | rglru_a
    fan_dims: Tuple[int, ...] = (0,)         # dims that count as fan-in
    scale: float = 1.0
    dtype: Optional[str] = None              # override param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _leaf_init(key, spec: ParamSpec, dtype) -> jax.Array:
    dt = jnp.dtype(spec.dtype or dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(key, spec.shape)).astype(dt)
    if spec.init == "rglru_a":
        # Griffin: a = exp(-c * softplus(Λ)), init so a^c uniform in [0.9, 0.999]
        u = jax.random.uniform(key, spec.shape, minval=0.9, maxval=0.999)
        # store Λ such that sigmoid-ish param recovers; we keep raw in (0,1) logit
        lam = jnp.log(jnp.expm1(-jnp.log(u) / 8.0))   # inverse softplus
        return lam.astype(dt)
    if spec.init == "fan_in":
        fan = float(np.prod([spec.shape[d] for d in spec.fan_dims])) or 1.0
        std = spec.scale / np.sqrt(fan)
        return (std * jax.random.normal(key, spec.shape)).astype(dt)
    raise ValueError(f"unknown init {spec.init}")


def init_params(spec_tree, key, dtype: str):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    arrs = [_leaf_init(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrs)


def abstract_params(spec_tree, dtype: str):
    def f(s: ParamSpec):
        return jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or dtype))
    return jax.tree.map(f, spec_tree, is_leaf=is_spec)


def param_axes(spec_tree):
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def stack_specs(spec_tree, n: int, axis_name: Optional[str] = None):
    """Add a leading (scan) dimension of size n to every leaf spec."""
    def f(s: ParamSpec):
        return dataclasses.replace(
            s, shape=(n,) + s.shape, axes=(axis_name,) + s.axes,
            fan_dims=tuple(d + 1 for d in s.fan_dims))
    return jax.tree.map(f, spec_tree, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def constrain_batch(x, mesh, seq_shard: bool = False,
                    vocab_last: bool = False):
    """Pin activation sharding: batch over (pod,data) on dim 0; optionally
    seq over "model" on dim 1 (context parallelism) or vocab over "model"
    on the last dim; everything else replicated (stops SPMD from inventing
    partial shardings that force involuntary collectives)."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not baxes:
        return x
    n = 1
    for a in baxes:
        n *= mesh.shape[a]
    if x.ndim == 0 or x.shape[0] % n or n == 1:
        return x
    parts = [baxes if len(baxes) > 1 else baxes[0]] + [None] * (x.ndim - 1)
    nm = mesh.shape.get("model", 1)
    if seq_shard and x.ndim >= 2 and nm > 1 and x.shape[1] % nm == 0 \
            and x.shape[1] > 1:
        parts[1] = "model"
    elif vocab_last and x.ndim >= 3 and "model" in mesh.axis_names and \
            x.shape[-1] % nm == 0:
        parts[-1] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


def rms_norm(x, weight, eps: float, zero_centered: bool = True):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    w = 1.0 + w if zero_centered else w
    return (x * w).astype(dt)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def activation(name: str):
    if name == "gelu":
        return jax.nn.gelu
    if name == "silu":
        return jax.nn.silu
    if name == "sq_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "relu":
        return jax.nn.relu
    raise ValueError(name)


# -- rotary ----------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, fraction: float = 1.0):
    rot = int(head_dim * fraction)
    rot -= rot % 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float64) / rot))
    return rot, jnp.asarray(inv, jnp.float32)


def apply_rope(x, positions, theta: float, fraction: float = 1.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    rot, inv = rope_freqs(d, theta, fraction)
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    ang = positions.astype(jnp.float32)[..., None] * inv          # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)
