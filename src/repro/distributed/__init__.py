from repro.distributed import sharding
from repro.distributed.sharding import (base_rules, batch_axes,
                                        batch_sharding, cache_sharding,
                                        spec_from_axes, tree_shardings)

__all__ = ["base_rules", "batch_axes", "batch_sharding", "cache_sharding",
           "sharding", "spec_from_axes", "tree_shardings"]
