"""Logical-axis sharding rules (MaxText-style) -> concrete NamedShardings.

Rules are (arch x shape x mesh)-aware:

* training: FSDP over ``data`` (+ pure DP over ``pod``), TP over ``model``;
* serving:  TP over ``model``; FSDP only if the model cannot fit
  model-sharded weights in HBM (bf16, 16 GiB/chip v5e budget);
* any logical dim that does not divide its mesh axes falls back to
  replicated (e.g. 10 heads on a 16-way model axis).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCfg

HBM_BYTES = 16 * 1024 ** 3        # TPU v5e
FSDP_THRESHOLD = 0.5              # use FSDP when weights > 50% HBM


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def base_rules(cfg: ModelConfig, shape: Optional[ShapeCfg],
               mesh: Mesh) -> Dict[str, Any]:
    """logical axis name -> mesh axis (str | tuple | None)."""
    n_model = mesh.shape["model"] if "model" in mesh.axis_names else 1
    n_data = mesh.shape["data"] if "data" in mesh.axis_names else 1

    train = shape is None or shape.kind == "train"
    # serving: only FSDP when TP-sharded weights don't fit
    param_bytes = cfg.param_count() * 2  # bf16
    need_fsdp = train or (param_bytes / max(n_model, 1)
                          > FSDP_THRESHOLD * HBM_BYTES)

    def div(n, axis_size):
        return n % axis_size == 0

    rules: Dict[str, Any] = {
        "batch": batch_axes(mesh),
        "embed": "data" if (need_fsdp and div(cfg.d_model, n_data)) else None,
        "vocab": "model" if div(cfg.vocab_size, n_model) else None,
        "heads": "model" if div(cfg.num_heads, n_model) else None,
        "kv_heads": "model" if div(cfg.num_kv_heads, n_model) else None,
        "head_dim": None,
        "mlp": "model" if div(cfg.d_ff, n_model) else None,
        "experts": "model",
        "experts_dp": "data",     # ep_a2a layout: experts over data...
        "expert_tp": "model",     # ...expert FFN dim over model
        "q_lora": None,
        "kv_lora": None,
        "lru": None,
        "layers": None,
    }
    if cfg.moe is not None:
        f = cfg.moe.d_ff_expert
        if cfg.moe.num_experts % max(n_data, 1):
            rules["experts_dp"] = None
        if f % max(n_model, 1):
            rules["expert_tp"] = None
    if cfg.rglru is not None:
        lw = cfg.rglru.lru_width or cfg.d_model
        rules["lru"] = "model" if div(lw, n_model) else None
    if cfg.moe is not None and not div(cfg.moe.num_experts, n_model):
        rules["experts"] = None
    # GQA: sharding q-heads while kv replicated is fine; but if q-heads
    # can't shard, keep kv replicated too (avoids asymmetric layouts).
    if rules["heads"] is None:
        rules["kv_heads"] = None
    return rules


def spec_from_axes(axes: Tuple[Optional[str], ...],
                   rules: Dict[str, Any]) -> P:
    parts = []
    used = set()
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        # one mesh axis may appear only once in a PartitionSpec
        if m is None:
            parts.append(None)
            continue
        key = tuple(m) if isinstance(m, (tuple, list)) else (m,)
        if any(k in used for k in key):
            parts.append(None)
            continue
        used.update(key)
        parts.append(tuple(m) if isinstance(m, (tuple, list)) else m)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_shardings(axes_tree, mesh: Mesh, rules: Dict[str, Any]):
    def f(axes):
        return NamedSharding(mesh, spec_from_axes(axes, rules))
    return jax.tree.map(f, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(a, (str, type(None))) for a in x))


def batch_sharding(mesh: Mesh, global_batch: int, ndim: int,
                   rules: Dict[str, Any]) -> NamedSharding:
    axes = rules.get("batch", ())
    n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and global_batch % n == 0 and global_batch >= n:
        spec = P(tuple(axes) if len(axes) > 1 else axes[0])
    else:
        spec = P()
    return NamedSharding(mesh, spec)


def cache_sharding(cfg: ModelConfig, mesh: Mesh, rules: Dict[str, Any],
                   cache_abstract) -> Any:
    """Shard caches: batch over data axes, kv-heads over model if possible."""
    baxes = rules.get("batch", ())

    def f(leaf):
        shp = leaf.shape
        spec: list = [None] * len(shp)
        if len(shp) < 2:
            return NamedSharding(mesh, P(*spec))   # replicate 1-D leaves
        n = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
        # leading dims: scan-stack (layers) then batch; find batch dim as the
        # first dim whose size is divisible by the batch-axis product and >1.
        bdim = None
        for i, s in enumerate(shp[:2]):
            if baxes and s % n == 0 and s >= n and n > 1:
                spec[i] = tuple(baxes) if len(baxes) > 1 else baxes[0]
                bdim = i
                break
        # cache sequence dim (split-KV): the dim right after batch, sharded
        # over "model" when long and divisible (matches the decode-path
        # with_sharding_constraint).
        nm = mesh.shape.get("model", 1)
        if bdim is not None and len(shp) >= bdim + 2 and nm > 1:
            sdim = bdim + 1
            if shp[sdim] % nm == 0 and shp[sdim] >= 4 * nm:
                spec[sdim] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(f, cache_abstract)
