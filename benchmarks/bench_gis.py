"""The cost of stale information: GIS TTL × churn-rate sweep.

Brokers discover through the Grid Information Service, so what they
know lags the world by (heartbeat detection latency + view TTL).  This
bench quantifies what that staleness costs: for each (view TTL, site
churn rate) cell it runs the same seeded six-broker market and records
dispatches burned on dead resources, in-flight evictions, deadlines
met and G$ spent.  Longer TTLs on a churning grid mean more scheduling
against corpses — the ``burned`` column is the price of not asking.

Re-runs the churniest cell with the same seed and asserts byte-identical
results, then writes the whole table to ``BENCH_gis.json``.

    PYTHONPATH=src python -m benchmarks.bench_gis            # full
    PYTHONPATH=src python -m benchmarks.bench_gis --smoke    # CI
"""
from __future__ import annotations

import json
import os
import sys
import time

from repro.core import standard_market

HOUR = 3600.0

TTLS = (120.0, 900.0, 3600.0)
CHURN = (("none", None), ("slow", 6.0), ("fast", 2.5))   # mean uptime h
SMOKE_TTLS = (120.0, 3600.0)
SMOKE_CHURN = (("fast", 2.5),)
SEED = 31
N_USERS = 6
N_MACHINES = 12
N_JOBS = 12

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_gis.json")


def _run(ttl: float, uptime_h):
    market = standard_market(
        N_USERS, n_machines=N_MACHINES, seed=SEED, n_jobs=N_JOBS,
        demand_elasticity=1.0, gis_ttl=ttl,
        churn_mean_uptime_h=uptime_h if uptime_h else 6.0,
        churn_mean_downtime_h=2.0)
    t0 = time.time()
    rep = market.run(churn=uptime_h is not None)
    wall = time.time() - t0
    market.bank.reconcile({u.name: e.ledger for u, e in
                           zip(market.users, market.engines)})
    return market, rep, wall


def _row(ttl: float, churn_name: str, rep, wall: float) -> dict:
    return {
        "ttl_s": ttl,
        "churn": churn_name,
        "done": rep.total_done,
        "jobs": rep.total_jobs,
        "deadline_met_frac": rep.deadline_met_frac,
        "total_spent_gd": rep.total_spent,
        "burned_dispatches": rep.resource_losses,
        "evictions": rep.evictions,
        "refunds_gd": rep.refunds,
        "churn_events": len(rep.churn_trace),
        "gis_refreshes": rep.gis_refreshes,
        "wall_s": wall,
    }


def sweep_table(csv: bool = False, ttls=TTLS, churn=CHURN):
    rows = []
    for churn_name, uptime in churn:
        for ttl in ttls:
            _, rep, wall = _run(ttl, uptime)
            rows.append(_row(ttl, churn_name, rep, wall))
    if not csv:
        print("churn  ttl_s   done/jobs  met%   burned  evict  "
              "refresh  spend_G$  wall_s")
        for r in rows:
            print(f"{r['churn']:5s} {r['ttl_s']:6.0f} "
                  f"{r['done']:5d}/{r['jobs']:<5d} "
                  f"{r['deadline_met_frac']:5.0%} {r['burned_dispatches']:6d} "
                  f"{r['evictions']:6d} {r['gis_refreshes']:8d} "
                  f"{r['total_spent_gd']:9.1f} {r['wall_s']:7.2f}")
        churny = [r for r in rows if r["churn"] == churn[-1][0]
                  and r["churn"] != "none"]
        if churny:
            freshest = min(churny, key=lambda r: r["ttl_s"])
            stalest = max(churny, key=lambda r: r["ttl_s"])
            print(f"\nstale-view penalty at churn={stalest['churn']}: "
                  f"TTL {freshest['ttl_s']:.0f}s -> "
                  f"{stalest['ttl_s']:.0f}s burns "
                  f"{freshest['burned_dispatches']} -> "
                  f"{stalest['burned_dispatches']} dispatches on corpses")
    return rows


def determinism_check(csv: bool, ttl: float, uptime_h):
    t0 = time.time()
    _, r1, _ = _run(ttl, uptime_h)
    _, r2, _ = _run(ttl, uptime_h)
    wall = time.time() - t0
    identical = r1.stable_repr() == r2.stable_repr()
    if not csv:
        print(f"same-seed churn-market re-run byte-identical: {identical}")
    if not identical:
        raise AssertionError("GIS/churn market run is not seed-deterministic")
    return [("gis_determinism", wall * 1e6, int(identical))]


def main(csv: bool = False, smoke: bool = False):
    ttls = SMOKE_TTLS if smoke else TTLS
    churn = SMOKE_CHURN if smoke else CHURN
    rows = sweep_table(csv, ttls=ttls, churn=churn)
    out = {
        "bench": "gis",
        "seed": SEED,
        "n_users": N_USERS,
        "n_machines": N_MACHINES,
        "n_jobs_per_user": N_JOBS,
        "sweep": rows,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    if not csv:
        print(f"wrote {OUT_PATH}")
    results = []
    for r in rows:
        results.append((f"gis_{r['churn']}_ttl{r['ttl_s']:.0f}",
                        r["wall_s"] * 1e6, r["burned_dispatches"]))
    churniest = churn[-1][1]
    return results + determinism_check(csv, ttls[-1], churniest)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
