"""Broker hot-path scale sweep: jobs × users × market variant.

The PR-4 refactor makes the scheduling tick O(active work) instead of
O(experiment size): status-bucketed job indices, per-resource in-flight
counters, per-tick quote memoization and cancellable simulator timers.
This bench measures what that buys — the same seeded marketplace run at
jobs/user ∈ {100, 1k, 10k} × brokers ∈ {1, 4, 16}, for the posted-price
market, the auction (negotiated) market, and a failing+churning grid —
and records simulator events/sec as the throughput metric.  The
array-core tier (PR 9) extends the posted sweep to 100k jobs/user
(brokers ∈ {1, 4, 16}) and 1M jobs/user (single broker — the 16-broker
point would need ~16 GB of job tables).

``PRE_REFACTOR`` holds the same points measured on the pre-index code
(commit fe4417f..d675d64 lineage) on the same machine; ``PRE_VECTOR``
holds the large-tier points measured on the PR-4 indexed path before
the batched quote board / calendar queue / array clearing landed.  The
headline ratios are the 10k × 16 posted point (vs PRE_REFACTOR) and
the 100k × 16 posted point (vs PRE_VECTOR).  Results land in
``BENCH_scale.json``.

    PYTHONPATH=src python -m benchmarks.bench_scale            # full
    PYTHONPATH=src python -m benchmarks.bench_scale --smoke    # CI
    # piecemeal re-runs merge into the committed JSON by point key:
    PYTHONPATH=src python -m benchmarks.bench_scale \
        --jobs 1000000 --users 1 --variant posted --best-of 3

Smoke mode runs the 100-job points plus the 100k × 16 posted tier,
re-checks same-seed determinism, rewrites the committed JSON's
``smoke`` section, and FAILS if measured events/sec regressed more
than ``GATE`` (30%) against the committed baseline (override the gate
with SCALE_BENCH_NO_GATE=1 when the hardware legitimately changed).
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import (SchedulerConfig, mixed_auction_market,
                        standard_market)

HOUR = 3600.0

SEED = 11
N_MACHINES = 32
JOBS = (100, 1_000, 10_000)
USERS = (1, 4, 16)
VARIANTS = ("posted", "auction", "churn")
#: array-core tier: (jobs, users, variant) — posted only (the auction
#: and churn variants exercise the same event loop with extra market
#: machinery; the posted path is the apples-to-apples throughput axis)
LARGE_TIER = ((100_000, 1, "posted"), (100_000, 4, "posted"),
              (100_000, 16, "posted"), (1_000_000, 1, "posted"))
SMOKE_JOBS = (100,)
SMOKE_LARGE = ((100_000, 16, "posted"),)
GATE = 0.30                       # max tolerated events/sec regression

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_scale.json")

# Same-machine measurements of the identical scenarios on the
# pre-refactor broker (full job-table rescans per tick, attempts-log
# walks per dispatch, uncached quotes).  events/sec per point.
PRE_REFACTOR = {
    "posted_j100_u1": 3654.3,
    "posted_j100_u4": 2742.9,
    "posted_j100_u16": 2922.3,
    "posted_j1000_u4": 768.5,
    "posted_j1000_u16": 917.6,
    "posted_j10000_u1": 231.7,
    "posted_j10000_u4": 140.3,
    "posted_j10000_u16": 87.4,     # the acceptance point (wall 795.8s)
    "auction_j10000_u16": 75.6,
    "churn_j10000_u16": 130.1,
}

# Large-tier points on the PR-4 indexed path (same machine), before the
# batched quote board, calendar-queue event loop and array clearing.
PRE_VECTOR = {
    "posted_j100000_u1": 9330.5,
    "posted_j100000_u4": 7018.9,
    "posted_j100000_u16": 2429.0,  # the PR-9 acceptance point (28.4s)
    "posted_j1000000_u1": 4519.3,
}


def point_key(variant: str, jobs: int, users: int) -> str:
    return f"{variant}_j{jobs}_u{users}"


def run_point(jobs: int, users: int, variant: str, seed: int = SEED) -> dict:
    builder = mixed_auction_market if variant == "auction" \
        else standard_market
    market = builder(
        users, n_machines=N_MACHINES, seed=seed, n_jobs=jobs,
        est_seconds=600.0, deadline_h=24.0, budget=100.0 * jobs,
        demand_elasticity=0.5,
        sched_cfg=SchedulerConfig(
            timeline_stride=16 if jobs >= 1_000 else 1))
    run_kw = dict(churn=True, failures=True) if variant == "churn" else {}
    t0 = time.time()
    rep = market.run(**run_kw)
    wall = time.time() - t0
    ev = market.sim.events
    return {
        "variant": variant, "jobs_per_user": jobs, "users": users,
        "wall_s": round(wall, 3), "events": ev,
        "events_per_sec": round(ev / max(wall, 1e-9), 1),
        "jobs_done": rep.total_done, "jobs_total": rep.total_jobs,
        "stable_repr_len": len(rep.stable_repr()),
    }


def run_best(jobs: int, users: int, variant: str, best_of: int = 1) -> dict:
    """Best-of-N wrapper: keeps the fastest row, records every wall."""
    tries = [run_point(jobs, users, variant) for _ in range(max(best_of, 1))]
    best = max(tries, key=lambda r: r["events_per_sec"])
    best["best_of"] = len(tries)
    best["walls_s"] = [t["wall_s"] for t in tries]
    return best


def sweep(csv: bool, points, best_of: int = 1) -> list:
    """Run the (variant, jobs, users) points in order; returns rows."""
    rows = []
    if not csv:
        print("variant  jobs/u  users    done/total      events   "
              "ev/s      wall_s")
    for variant, jobs, users in points:
        r = run_best(jobs, users, variant, best_of)
        rows.append(r)
        if not csv:
            print(f"{r['variant']:8s} {r['jobs_per_user']:7d} "
                  f"{r['users']:5d} {r['jobs_done']:8d}/"
                  f"{r['jobs_total']:<8d} {r['events']:9d} "
                  f"{r['events_per_sec']:9.1f} {r['wall_s']:8.2f}")
    return rows


def _points(smoke: bool, jobs=None, users=None, variants=None) -> list:
    """The point list for this invocation, post CLI filters.

    Filters intersect: ``--jobs 1000000 --variant posted`` keeps only
    the large-tier 1M point.  Filtered runs merge into the committed
    JSON instead of replacing it, so the 1M tier can be re-measured
    piecemeal without re-running the whole sweep."""
    pts = []
    grid_jobs = SMOKE_JOBS if smoke else JOBS
    for variant in VARIANTS:
        for j in grid_jobs:
            for u in USERS:
                pts.append((variant, j, u))
    pts.extend((v, j, u) for j, u, v in (SMOKE_LARGE if smoke
                                         else LARGE_TIER))
    if jobs:
        pts = [p for p in pts if p[1] in jobs]
    if users:
        pts = [p for p in pts if p[2] in users]
    if variants:
        pts = [p for p in pts if p[0] in variants]
    return pts


def _fresh_market():
    return standard_market(4, n_machines=N_MACHINES, seed=SEED, n_jobs=100,
                           est_seconds=600.0, deadline_h=24.0,
                           budget=100.0 * 100, demand_elasticity=0.5,
                           sched_cfg=SchedulerConfig())


def determinism_check(csv: bool):
    t0 = time.time()
    rep1, rep2 = _fresh_market().run(), _fresh_market().run()
    wall = time.time() - t0
    identical = rep1.stable_repr() == rep2.stable_repr()
    if not csv:
        print(f"same-seed scale-market re-run byte-identical: {identical}")
    if not identical:
        raise AssertionError("scale market run is not seed-deterministic")
    return [("scale_determinism", wall * 1e6, int(identical))]


def _gate_against_committed(rows: list, csv: bool) -> None:
    """CI regression gate: measured events/sec vs the committed JSON.

    Gates on the AGGREGATE events/sec over the matched smoke points
    (sub-2s single points jitter well past 30% on a shared runner; the
    suite total is the stable signal).  Per-point ratios are printed
    for diagnosis."""
    if os.environ.get("SCALE_BENCH_NO_GATE"):
        return
    if not os.path.exists(OUT_PATH):
        return
    with open(OUT_PATH) as f:
        committed = json.load(f)
    # like-for-like: gate against the committed smoke section (same
    # best-of-N protocol); fall back to the full-sweep rows before the
    # first smoke baseline ever lands
    base_rows = committed.get("smoke") or committed.get("results", [])
    baseline = {r["variant"] + f"_j{r['jobs_per_user']}_u{r['users']}": r
                for r in base_rows}
    got_ev = got_wall = base_ev = base_wall = 0.0
    for r in rows:
        key = point_key(r["variant"], r["jobs_per_user"], r["users"])
        base = baseline.get(key)
        if base is None or not base.get("events_per_sec"):
            continue
        got_ev += r["events"]
        got_wall += r["wall_s"]
        base_ev += base["events"]
        base_wall += base["wall_s"]
        if not csv:
            print(f"gate {key}: {r['events_per_sec']:.0f} ev/s vs "
                  f"committed {base['events_per_sec']:.0f} "
                  f"({r['events_per_sec'] / base['events_per_sec']:.2f}x)")
    if base_wall <= 0 or got_wall <= 0:
        return
    ratio = (got_ev / got_wall) / (base_ev / base_wall)
    if not csv:
        print(f"gate aggregate: {got_ev / got_wall:.0f} ev/s vs committed "
              f"{base_ev / base_wall:.0f} ({ratio:.2f}x)")
    if ratio < 1.0 - GATE:
        raise AssertionError(
            f"aggregate events/sec regressed >{GATE:.0%} vs committed "
            f"baseline ({ratio:.2f}x) — if the hardware changed, re-run "
            f"the full bench and commit a fresh BENCH_scale.json "
            f"(or set SCALE_BENCH_NO_GATE=1)")


def _speedup(rows: list, key: str, base: dict):
    post = next((r["events_per_sec"] for r in rows
                 if point_key(r["variant"], r["jobs_per_user"],
                              r["users"]) == key), None)
    pre = base.get(key)
    return (round(post / pre, 2) if post and pre else None), pre, post


def main(csv: bool = False, smoke: bool = False, jobs=None, users=None,
         variants=None, best_of=None):
    filtered = bool(jobs or users or variants)
    pts = _points(smoke, jobs, users, variants)
    rows = sweep(csv, pts, best_of or (2 if smoke else 1))

    if smoke:
        _gate_against_committed(rows, csv)
        # refresh the smoke section only — the committed full sweep and
        # baseline stay as measured on the reference machine
        doc = {}
        if os.path.exists(OUT_PATH):
            with open(OUT_PATH) as f:
                doc = json.load(f)
        doc["smoke"] = rows
    else:
        prior = []
        if filtered and os.path.exists(OUT_PATH):
            with open(OUT_PATH) as f:
                prior = json.load(f).get("results", [])
        # merge by point key: re-measured points replace their committed
        # row, untouched points survive, brand-new points append
        fresh = {point_key(r["variant"], r["jobs_per_user"], r["users"])
                 for r in rows}
        merged = [r for r in prior
                  if point_key(r["variant"], r["jobs_per_user"],
                               r["users"]) not in fresh] + rows
        speedup, pre, post = _speedup(
            merged, point_key("posted", 10_000, 16), PRE_REFACTOR)
        speedup_v, pre_v, post_v = _speedup(
            merged, point_key("posted", 100_000, 16), PRE_VECTOR)
        doc = {
            "bench": "scale",
            "seed": SEED,
            "n_machines": N_MACHINES,
            "est_seconds": 600.0,
            "deadline_h": 24.0,
            "jobs_axis": list(JOBS),
            "users_axis": list(USERS),
            "variants": list(VARIANTS),
            "large_tier": [list(p) for p in LARGE_TIER],
            "pre_refactor_events_per_sec": PRE_REFACTOR,
            "pre_vector_events_per_sec": PRE_VECTOR,
            "results": merged,
            "speedup_posted_j10000_u16": speedup,
            "speedup_posted_j100000_u16": speedup_v,
        }
        if filtered and os.path.exists(OUT_PATH):
            with open(OUT_PATH) as f:
                old = json.load(f)
            if "smoke" in old:
                doc["smoke"] = old["smoke"]
        if not csv and speedup is not None:
            print(f"\n10k-job x 16-user posted market: {speedup}x "
                  f"events/sec over the pre-refactor broker "
                  f"({pre:.0f} -> {post:.0f})")
        if not csv and speedup_v is not None:
            print(f"100k-job x 16-user posted market: {speedup_v}x "
                  f"events/sec over the pre-vectorization broker "
                  f"({pre_v:.0f} -> {post_v:.0f})")
    with open(OUT_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    if not csv:
        print(f"wrote {OUT_PATH}")

    results = [(point_key(r["variant"], r["jobs_per_user"], r["users"]),
                r["wall_s"] * 1e6, r["events_per_sec"]) for r in rows]
    return results + determinism_check(csv)


def _cli():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: 100-job grid + 100k smoke point, "
                         "regression-gated against the committed JSON")
    ap.add_argument("--csv", action="store_true",
                    help="suppress the human-readable table")
    ap.add_argument("--jobs", type=int, action="append",
                    help="keep only points with this jobs/user "
                         "(repeatable)")
    ap.add_argument("--users", type=int, action="append",
                    help="keep only points with this many users "
                         "(repeatable)")
    ap.add_argument("--variant", action="append", choices=VARIANTS,
                    dest="variants",
                    help="keep only this market variant (repeatable)")
    ap.add_argument("--best-of", type=int, default=None,
                    help="walls per point; the fastest is kept and every "
                         "wall is recorded (default: 1 full, 2 smoke)")
    a = ap.parse_args()
    main(csv=a.csv, smoke=a.smoke, jobs=a.jobs, users=a.users,
         variants=a.variants, best_of=a.best_of)


if __name__ == "__main__":
    _cli()
