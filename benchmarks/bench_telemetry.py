"""Tracer overhead: the zero-overhead-when-disabled claim, measured.

Every instrumentation site in the market guards on ``tracer is None``,
so a telemetry-off run should cost one attribute read + None check per
site and a telemetry-on run should cost a bounded, ring-buffered append
per event.  This bench runs the SAME seeded marketplace with telemetry
off and on for the posted and auction markets and records the
events/sec ratio (``overhead = 1 - off/on`` of the walls).  The timed
traced arms carry a live streaming subscriber (raw delivery, counting
every event), so the gate bounds record + bus delivery — the full
``ExperimentMonitor`` (watchdogs on) rides the untimed correctness
pair instead, where its zero-violations and observes-only guarantees
are asserted without gating its workload-dependent arithmetic.
Results land in ``BENCH_telemetry.json``; the traced smoke run's
Chrome export is written to ``benchmarks/trace_smoke.json`` for the
CI artifact.

    PYTHONPATH=src python -m benchmarks.bench_telemetry            # full
    PYTHONPATH=src python -m benchmarks.bench_telemetry --smoke    # CI

Methodology (smoke): a single long-lived process cannot time this
fairly — the arm that runs later inherits an aged heap and reads 2-4%
slow regardless of order, which is the same magnitude as the effect
being gated.  So each timed run executes in a FRESH subprocess (this
module is its own worker via ``--worker``), each iteration runs the
off and on arms back-to-back, and the gate statistic is the MEDIAN of
the paired off/on wall ratios across both variants: drift on a shared
runner cancels within a pair, and the median discards the outlier
pairs such a box produces.  The reported per-arm walls are the MIN
over repeats (noise is strictly additive).  The gate FAILS if the
median paired ratio falls more than ``GATE`` (5%) below 1
(``TELEMETRY_BENCH_NO_GATE=1`` to override on hardware too noisy to
resolve it).  Correctness rides along untimed: two same-seed traced
runs must export byte-identical JSONL and a traced+monitored run's
``stable_repr`` must equal the untraced run's.

The full tier times the 10k-job x 16-broker markets in-process as one
off/on pair per variant — minutes-long walls amortise heap aging.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from repro.core import (ExperimentMonitor, SchedulerConfig, Tracer,
                        export_chrome_trace, mixed_auction_market,
                        standard_market)

HOUR = 3600.0

SEED = 11
N_MACHINES = 32
JOBS = 10_000
USERS = 16
VARIANTS = ("posted", "auction")
SMOKE_JOBS = 300
SMOKE_USERS = 4
SMOKE_REPEATS = 5                 # fresh-subprocess walls per arm
GATE = 0.05                       # max tolerated traced-on ev/s overhead

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_telemetry.json")
TRACE_PATH = os.path.join(ROOT, "benchmarks", "trace_smoke.json")


def _market(jobs: int, users: int, variant: str, tracer):
    builder = mixed_auction_market if variant == "auction" \
        else standard_market
    return builder(
        users, n_machines=N_MACHINES, seed=SEED, n_jobs=jobs,
        est_seconds=600.0, deadline_h=24.0, budget=100.0 * jobs,
        demand_elasticity=0.5,
        sched_cfg=SchedulerConfig(
            timeline_stride=16 if jobs >= 1_000 else 1),
        tracer=tracer)


class _CountingSubscriber:
    """Minimal live consumer for the timed arms: subscribes to the whole
    stream with raw delivery and collects every event — the cheapest
    honest subscriber (the callback is C-level ``list.append``), so the
    gate prices the bus itself."""

    __slots__ = ("seen",)

    def __init__(self, tracer):
        self.seen: list = []
        tracer.subscribe("*", self.seen.append, raw=True)

    @property
    def n(self) -> int:
        return len(self.seen)


def _run_once(jobs: int, users: int, variant: str, traced: bool,
              monitored: bool = False):
    tracer = Tracer() if traced else None
    # the counting subscriber attaches before market construction so it
    # sees the build-time stream (machine registrations) too
    sub = _CountingSubscriber(tracer) if traced and not monitored else None
    market = _market(jobs, users, variant, tracer)
    # untimed correctness arm: full online-observability stack —
    # watchdogs must stay silent and the run must stay bit-identical
    monitor = ExperimentMonitor(market) if monitored else None
    t0 = time.perf_counter()
    rep = market.run()
    wall = time.perf_counter() - t0
    if monitor is not None and monitor.violations:
        raise AssertionError(
            f"{variant}: watchdogs fired on a clean benchmark run: "
            f"{monitor.violations[0]}")
    if sub is not None and sub.n != tracer.n_events():
        raise AssertionError(
            f"{variant}: streaming subscriber saw {sub.n} events but the "
            f"tracer recorded {tracer.n_events()}")
    return {"wall": wall, "events": market.sim.events,
            "report": rep, "tracer": tracer,
            "monitor_events": monitor.events_seen if monitor else 0}


def _wall_in_subprocess(jobs: int, users: int, variant: str,
                        traced: bool) -> float:
    """One timed run in a fresh interpreter: no heap aging, no carryover
    between arms.  The worker is this module itself."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(p for p in sys.path if p)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_telemetry", "--worker",
         variant, str(jobs), str(users), "on" if traced else "off"],
        capture_output=True, text=True, env=env, cwd=ROOT)
    if out.returncode != 0:
        raise RuntimeError(f"bench worker failed:\n{out.stderr}")
    return float(out.stdout.strip().splitlines()[-1])


def _worker(argv) -> None:
    variant, jobs, users, arm = argv
    r = _run_once(int(jobs), int(users), variant, arm == "on")
    print(f"{r['wall']:.6f}")


def run_point_subprocess(jobs: int, users: int, variant: str,
                         repeats: int) -> dict:
    """One off/on comparison point, each wall from a fresh subprocess,
    arms interleaved so slow patches of a shared runner hit both."""
    offs, ons = [], []
    for i in range(repeats):
        arms = ("off", "on") if i % 2 == 0 else ("on", "off")
        for arm in arms:
            w = _wall_in_subprocess(jobs, users, variant, arm == "on")
            (ons if arm == "on" else offs).append(w)
    # untimed in-process pair: the observational guarantee with the full
    # watchdog monitor attached + the trace itself (event counts, the
    # Chrome artifact)
    off = _run_once(jobs, users, variant, False)
    on = _run_once(jobs, users, variant, True, monitored=True)
    if off["report"].stable_repr() != on["report"].stable_repr():
        raise AssertionError(
            f"{variant}: monitoring changed the market outcome — the "
            f"monitor must be purely observational")
    wall_off, wall_on = min(offs), min(ons)
    ev = off["events"]
    tr = on["tracer"]
    row = _row(variant, jobs, users, ev, wall_off, wall_on, tr)
    row["monitor_events"] = on["monitor_events"]
    # the gate statistic: each iteration's off/on walls ran back-to-back
    # so a slow patch on a shared runner hits both sides of the pair —
    # the per-pair ratio is far more stable than any single wall
    row["pair_ratios"] = [round(o / n, 4) for o, n in zip(offs, ons)]
    return row


def run_point_inprocess(jobs: int, users: int, variant: str) -> dict:
    """Full-tier point: one in-process off/on pair (walls are minutes,
    heap-aging noise amortises away)."""
    off = _run_once(jobs, users, variant, False)
    on = _run_once(jobs, users, variant, True)
    if off["report"].stable_repr() != on["report"].stable_repr():
        raise AssertionError(
            f"{variant}: tracing changed the market outcome — telemetry "
            f"must be purely observational")
    return _row(variant, jobs, users, off["events"], off["wall"],
                on["wall"], on["tracer"])


def _row(variant, jobs, users, ev, wall_off, wall_on, tracer) -> dict:
    return {
        "variant": variant, "jobs_per_user": jobs, "users": users,
        "events": ev,
        "wall_off_s": round(wall_off, 3),
        "wall_on_s": round(wall_on, 3),
        "events_per_sec_off": round(ev / max(wall_off, 1e-9), 1),
        "events_per_sec_on": round(ev / max(wall_on, 1e-9), 1),
        "overhead": round(1.0 - wall_off / max(wall_on, 1e-9), 4),
        "trace_events": tracer.n_events(),
        "trace_dropped": tracer.n_dropped(),
        "_tracer": tracer,
    }


def determinism_check(jobs: int, users: int, csv: bool):
    """Two same-seed traced runs must export byte-identical JSONL."""
    t0 = time.perf_counter()
    lines = []
    for _ in range(2):
        tr = Tracer()
        _market(jobs, users, "posted", tr).run()
        lines.append("\n".join(tr.jsonl_lines()))
    wall = time.perf_counter() - t0
    identical = lines[0] == lines[1]
    if not csv:
        print(f"same-seed traced re-run JSONL byte-identical: {identical}")
    if not identical:
        raise AssertionError("trace JSONL is not seed-deterministic")
    return [("telemetry_determinism", wall * 1e6, int(identical))]


def _aggregate_ratio(rows: list, csv: bool) -> float:
    """Gate statistic: the MEDIAN of all paired off/on wall ratios
    across the variants.  Each pair ran adjacently in fresh
    subprocesses, so runner drift cancels within the pair, and the
    median discards the outlier pairs a shared box produces — a far
    tighter estimator of the true overhead than comparing two min
    walls drawn from heavy-tailed noise."""
    pairs = sorted(p for r in rows for p in r.get("pair_ratios", ()))
    if not pairs:
        return 1.0
    mid = len(pairs) // 2
    ratio = (pairs[mid] if len(pairs) % 2
             else 0.5 * (pairs[mid - 1] + pairs[mid]))
    if not csv:
        print(f"gate: median paired off/on wall ratio {ratio:.3f}x "
              f"over {len(pairs)} pairs")
    return ratio


def _measure(smoke: bool, repeats: int, csv: bool) -> list:
    rows = []
    if not csv:
        print("variant  jobs/u  users   ev/s off    ev/s on  overhead"
              "   trace_ev  dropped")
    for variant in VARIANTS:
        if smoke:
            r = run_point_subprocess(SMOKE_JOBS, SMOKE_USERS, variant,
                                     repeats=repeats)
        else:
            r = run_point_inprocess(JOBS, USERS, variant)
        rows.append(r)
        if not csv:
            print(f"{r['variant']:8s} {r['jobs_per_user']:6d} "
                  f"{r['users']:5d} {r['events_per_sec_off']:10.1f} "
                  f"{r['events_per_sec_on']:10.1f} "
                  f"{r['overhead']:9.2%} {r['trace_events']:10d} "
                  f"{r['trace_dropped']:8d}")
    return rows


def main(csv: bool = False, smoke: bool = False):
    rows = _measure(smoke, SMOKE_REPEATS, csv)
    if smoke and not os.environ.get("TELEMETRY_BENCH_NO_GATE"):
        ratio = _aggregate_ratio(rows, csv)
        if ratio < 1.0 - GATE:
            # one retry at double the repeats before failing hard: the
            # gate hunts a real regression (overhead jumping well past
            # 5% fails both passes), not a slow patch on a shared
            # runner — the first reading sits within noise of the line
            if not csv:
                print(f"gate read {ratio:.3f}x < {1 - GATE:.2f}x; "
                      f"re-measuring once at {2 * SMOKE_REPEATS} repeats")
            rows = _measure(smoke, 2 * SMOKE_REPEATS, csv)
            ratio = _aggregate_ratio(rows, csv)
            if ratio < 1.0 - GATE:
                raise AssertionError(
                    f"tracer overhead exceeds {GATE:.0%}: traced "
                    f"aggregate events/sec is {ratio:.2f}x the untraced "
                    f"arm on both passes — profile the instrumentation "
                    f"sites (or set TELEMETRY_BENCH_NO_GATE=1 on noisy "
                    f"hardware)")

    # the traced posted run's Chrome export is the CI artifact: a
    # Perfetto-loadable picture of the whole smoke market
    export_chrome_trace(
        rows[0].pop("_tracer"), TRACE_PATH,
        run_name=f"bench_telemetry_{'smoke' if smoke else 'full'}")
    for r in rows:
        r.pop("_tracer", None)
    if not csv:
        print(f"wrote {TRACE_PATH}")

    if smoke:
        doc = {}
        if os.path.exists(OUT_PATH):
            with open(OUT_PATH) as f:
                doc = json.load(f)
        doc["smoke"] = {
            "jobs_per_user": SMOKE_JOBS, "users": SMOKE_USERS,
            "repeats": SMOKE_REPEATS, "gate_max_overhead": GATE,
            "protocol": "min wall of fresh-subprocess runs per arm",
            "results": rows,
        }
    else:
        doc = {
            "bench": "telemetry",
            "seed": SEED,
            "n_machines": N_MACHINES,
            "est_seconds": 600.0,
            "deadline_h": 24.0,
            "jobs_per_user": JOBS,
            "users": USERS,
            "variants": list(VARIANTS),
            "gate_max_overhead": GATE,
            "results": rows,
        }
        if os.path.exists(OUT_PATH):
            with open(OUT_PATH) as f:
                doc["smoke"] = json.load(f).get("smoke", {})
    with open(OUT_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    if not csv:
        print(f"wrote {OUT_PATH}")

    results = [(f"telemetry_{r['variant']}_j{r['jobs_per_user']}"
                f"_u{r['users']}", r["wall_on_s"] * 1e6, r["overhead"])
               for r in rows]
    return results + determinism_check(SMOKE_JOBS, SMOKE_USERS, csv)


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker(sys.argv[sys.argv.index("--worker") + 1:])
    else:
        main(smoke="--smoke" in sys.argv)
