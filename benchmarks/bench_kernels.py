"""Kernel microbenchmarks (interpret mode on CPU — wall time measures the
interpreter, so the *derived* column reports the kernel's useful FLOPs and
the parity error vs the jnp oracle, which is the meaningful signal here)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _timeit(f, *args, reps=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        f(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = f(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / reps


def bench_flash(csv=False):
    B, H, K, S, D = 1, 4, 2, 512, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, K, S, D))
    v = jax.random.normal(ks[2], (B, K, S, D))
    us = _timeit(lambda a, b, c: ops.flash_attention(a, b, c), q, k, v) * 1e6
    err = float(jnp.max(jnp.abs(ops.flash_attention(q, k, v) -
                                ref.attention_ref(q, k, v))))
    flops = 4 * B * H * S * S * D / 2  # causal
    if not csv:
        print(f"flash_attention S={S}: {us:.0f}us  max_err={err:.2e}")
    return [("kernel_flash_attn_512", us, err)]


def bench_rglru(csv=False):
    B, S, L = 2, 512, 256
    ks = jax.random.split(KEY, 3)
    log_a = -jnp.exp(jax.random.normal(ks[0], (B, S, L)) * 0.5 - 2)
    b = jax.random.normal(ks[1], (B, S, L))
    h0 = jax.random.normal(ks[2], (B, L))
    us = _timeit(lambda *a: ops.rglru_scan(*a), log_a, b, h0) * 1e6
    err = float(jnp.max(jnp.abs(ops.rglru_scan(log_a, b, h0) -
                                ref.rglru_ref(log_a, b, h0))))
    if not csv:
        print(f"rglru_scan S={S} L={L}: {us:.0f}us  max_err={err:.2e}")
    return [("kernel_rglru_512", us, err)]


def bench_wkv(csv=False):
    B, S, H, N = 1, 256, 4, 64
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, S, H, N)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, N)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, N)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) * 0.5 - 1.5)
    u = jax.random.normal(ks[4], (H, N)) * 0.5
    us = _timeit(lambda *a: ops.wkv(*a), r, k, v, logw, u) * 1e6
    y, _ = ops.wkv(r, k, v, logw, u)
    yr, _ = ref.wkv_ref(r, k, v, logw, u)
    err = float(jnp.max(jnp.abs(y - yr)))
    if not csv:
        print(f"wkv S={S} H={H} N={N}: {us:.0f}us  max_err={err:.2e}")
    return [("kernel_wkv_256", us, err)]


def bench_group_gemm(csv=False):
    E, C, D, F = 8, 256, 128, 256
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (E, C, D))
    w = jax.random.normal(ks[1], (E, D, F))
    n = jax.random.randint(ks[2], (E,), 0, C + 1)
    us = _timeit(lambda *a: ops.group_gemm(*a), x, w, n) * 1e6
    err = float(jnp.max(jnp.abs(ops.group_gemm(x, w, n) -
                                ref.group_gemm_ref(x, w, n))))
    if not csv:
        print(f"group_gemm E={E} C={C}: {us:.0f}us  max_err={err:.2e}")
    return [("kernel_group_gemm", us, err)]


def main(csv: bool = False):
    return (bench_flash(csv) + bench_rglru(csv) + bench_wkv(csv)
            + bench_group_gemm(csv))


if __name__ == "__main__":
    main()
