"""Roofline table from the dry-run cache (§Roofline deliverable).

Reads benchmarks/results/dryrun_cells.jsonl (produced by
``python -m repro.launch.dryrun --all [--multi-pod]``) and prints the
three-term table; derived column = roofline MFU upper bound.
"""
from __future__ import annotations

import json
import os

CACHE = os.path.join(os.path.dirname(__file__), "results",
                     "dryrun_cells.jsonl")


def load_rows(mesh: str = "16x16"):
    rows = []
    if not os.path.exists(CACHE):
        return rows
    seen = {}
    with open(CACHE) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if r.get("skipped") or r.get("mesh") != mesh:
                continue
            seen[(r["arch"], r["shape"])] = r   # last write wins
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    return sorted(seen.values(),
                  key=lambda r: (r["arch"], order.get(r["shape"], 9)))


def main(csv: bool = False):
    from repro.roofline.analysis import table
    rows = load_rows()
    if not rows:
        print("no dry-run cache; run: python -m repro.launch.dryrun --all")
        return [("roofline_cells", 0.0, 0)]
    if not csv:
        print(table(rows))
        mp = load_rows("2x16x16")
        print(f"\nsingle-pod cells: {len(rows)}; "
              f"multi-pod (2x16x16) cells compiled: {len(mp)}")
    return [(f"roofline_{r['arch']}_{r['shape']}",
             max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) * 1e6,
             round(r["mfu_upper_bound"], 4)) for r in rows]


if __name__ == "__main__":
    main()
