"""Regenerate EXPERIMENTS.md from the dry-run cache + perf iteration log.

    PYTHONPATH=src python -m benchmarks.make_report

Or render the observability dashboard from an exported market trace —
price curve, deadline-hit waterfall, and GridBank flow summary, all
reconstructed from the Chrome trace-event JSON alone (no market objects
needed; any file written by ``export_chrome_trace`` works):

    PYTHONPATH=src python -m benchmarks.make_report --market-trace out.json

Or render the causal post-mortem for one job — every attempt it made,
what each cost, and what else was happening on the machines it touched
(churn, failures, suspicions, exceptional money movements):

    PYTHONPATH=src python -m benchmarks.make_report \\
        --explain-job exp/rajkumar:j00007 out.json

``--explain-job auto`` picks the most-retried job in the trace (ideal
for CI smoke renders).  Both readers exit nonzero with a one-line error
on a truncated, corrupt, or empty trace file.
"""
import argparse
import json
import math
import os
import sys
from collections import Counter, defaultdict

CELLS = "benchmarks/results/dryrun_cells.jsonl"
PERF = "benchmarks/results/perf_iterations.jsonl"
OUT = "EXPERIMENTS.md"

ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(path):
    rows = []
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    rows.append(json.loads(line))
                except json.JSONDecodeError:
                    pass
    return rows


def fmt_s(s):
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.0f}us"


def fmt_b(b):
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def dedup(rows, keyf):
    seen = {}
    for r in rows:
        seen[keyf(r)] = r
    return list(seen.values())


# ---------------------------------------------------------------------------
# --market-trace: dashboard from an exported Chrome trace alone
# ---------------------------------------------------------------------------

HOUR_US = 3600.0 * 1e6
SPARK = "▁▂▃▄▅▆▇█"


def _sparkline(samples, width=64, max_samples=None):
    """Bucket (ts, value) samples into ``width`` columns and render a
    unicode sparkline; empty buckets hold the last seen value.

    A 100k-job trace emits one price sample per clearing round — far
    more points than the ``width`` columns can show — so past
    ``max_samples`` (default 64 per column) the sorted series is
    stride-downsampled first, always keeping the first and last sample
    so the rendered time span is exact."""
    if not samples:
        return "", 0.0, 0.0
    samples = sorted(samples)
    cap = max_samples or width * 64
    if len(samples) > cap:
        stride = len(samples) // cap + 1
        samples = samples[::stride] + [samples[-1]]
    t0, t1 = samples[0][0], samples[-1][0]
    span = (t1 - t0) or 1.0
    sums = [0.0] * width
    counts = [0] * width
    for ts, v in samples:
        i = min(int((ts - t0) / span * width), width - 1)
        sums[i] += v
        counts[i] += 1
    vals, last = [], samples[0][1]
    for s, n in zip(sums, counts):
        if n:
            last = s / n
        vals.append(last)
    lo, hi = min(vals), max(vals)
    rng = (hi - lo) or 1.0
    line = "".join(SPARK[min(int((v - lo) / rng * len(SPARK)),
                             len(SPARK) - 1)] for v in vals)
    return line, lo, hi


def _load_trace(path):
    """Read a Chrome trace for the dashboard/post-mortem readers.  A
    missing, truncated, corrupt, or empty file is a *diagnosable* error:
    print one line to stderr and exit 2 instead of tracebacking — CI
    gates read the exit code."""
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except OSError as e:
        print(f"error: cannot read trace {path!r}: {e.strerror or e}",
              file=sys.stderr)
        sys.exit(2)
    except json.JSONDecodeError as e:
        print(f"error: corrupt trace {path!r}: not valid JSON "
              f"(line {e.lineno}: {e.msg})", file=sys.stderr)
        sys.exit(2)
    if not isinstance(doc, dict) or not doc.get("traceEvents"):
        print(f"error: empty trace {path!r}: no traceEvents "
              f"(truncated export?)", file=sys.stderr)
        sys.exit(2)
    return doc


def _percentile_from_summary(summary, p):
    """Percentile estimate from an exported ``Histogram.summary()`` dict
    (``le_<bound>`` / ``overflow`` bucket keys) — the dashboard has only
    the JSON, not the live instrument.  Mirrors
    ``telemetry.Histogram.percentile``: linear interpolation inside the
    containing bucket, min/max tightening the edge buckets."""
    count = summary.get("count", 0)
    if not count:
        return 0.0
    buckets = summary.get("buckets", {})
    bounds = sorted(float(k[3:]) for k in buckets if k.startswith("le_"))
    # bucket keys were written as le_<repr(bound)>; match them by value
    counts = []
    for b in bounds:
        for k, v in buckets.items():
            if k.startswith("le_") and float(k[3:]) == b:
                counts.append(v)
                break
        else:
            counts.append(0)
    counts.append(buckets.get("overflow", 0))
    lo_obs, hi_obs = summary.get("min", 0.0), summary.get("max", 0.0)
    target = p / 100.0 * count
    cum = 0.0
    for i, n in enumerate(counts):
        if n == 0:
            continue
        lo = lo_obs if i == 0 else max(bounds[i - 1], lo_obs)
        hi = hi_obs if i == len(bounds) else min(bounds[i], hi_obs)
        if hi < lo:
            hi = lo
        if cum + n >= target:
            return lo + (target - cum) / n * (hi - lo)
        cum += n
    return hi_obs


def market_dashboard(path):
    """Render the market dashboard from a Chrome trace file: the inputs
    are ``price.mean_quote`` counter samples, ``broker_finish``
    instants, attempt-span outcomes, and the ``otherData`` metrics
    snapshot — everything the exporter wrote, nothing else."""
    doc = _load_trace(path)
    evs = [e for e in doc.get("traceEvents", []) if e.get("ph") != "M"]
    other = doc.get("otherData", {})
    metrics = other.get("metrics", {})

    L = []
    A = L.append
    span_us = max((e["ts"] for e in evs), default=0.0)
    A(f"# Market dashboard — {other.get('run', '?')}")
    A(f"trace: {len(evs)} events over {span_us / HOUR_US:.1f} sim-hours"
      + (f", dropped {other['dropped']}" if other.get("dropped") else ""))

    # -------- price curve (posted-price quote over sim time) --------
    quotes = [(e["ts"], e["args"]["value"]) for e in evs
              if e["ph"] == "C" and e["name"] == "price.mean_quote"]
    A("\n## Price curve — mean grid quote (G$/chip-h)")
    if quotes:
        line, lo, hi = _sparkline(quotes)
        t_lo, t_hi = quotes[0][0] / HOUR_US, quotes[-1][0] / HOUR_US
        A(f"```\n{hi:7.3f} ┐\n        {line}\n{lo:7.3f} ┘  "
          f"t = {t_lo:.1f}h .. {t_hi:.1f}h"
          f"   (demand multiplier {hi / lo if lo else 0:.2f}x)\n```")
    else:
        A("*(no price samples in this trace)*")

    # -------- deadline-hit waterfall (one bar per broker) --------
    fins = sorted((e for e in evs if e["name"] == "broker_finish"),
                  key=lambda e: (e["ts"], e["args"]["user"]))
    A("\n## Deadline waterfall — broker finishes")
    if fins:
        horizon = max(e["ts"] / HOUR_US + max(e["args"]["slack_h"], 0.0)
                      for e in fins) or 1.0
        width = 36
        A("```")
        for e in fins:
            a = e["args"]
            fin_h = e["ts"] / HOUR_US
            dl_h = fin_h + a["slack_h"]
            n_fin = max(min(int(round(fin_h / horizon * width)), width), 1)
            n_dl = max(min(int(round(dl_h / horizon * width)), width),
                       n_fin)
            bar = "█" * n_fin + "·" * (n_dl - n_fin) + \
                  " " * (width - n_dl)
            met = "✓" if a["met_deadline"] else "✗"
            stall = f"  [{a['stall']}]" if a.get("stall") else ""
            A(f"{a['user']:>8s} {a['strategy']:<12s} |{bar}| "
              f"{fin_h:6.1f}h {met} slack {a['slack_h']:+6.1f}h  "
              f"{a['done']}/{a['jobs']} jobs  "
              f"{a['spent']:.0f}/{a['budget']:.0f} G${stall}")
        A("█ = run time to finish, · = unused slack before the deadline")
        A("```")
        met_n = sum(1 for e in fins if e["args"]["met_deadline"])
        A(f"{met_n}/{len(fins)} brokers met their deadline")
    else:
        A("*(no broker_finish instants in this trace)*")

    # -------- attempt funnel (span outcomes) --------
    outcomes = Counter(e["args"]["outcome"] for e in evs
                       if e["ph"] == "e" and e["name"] == "attempt"
                       and "outcome" in e.get("args", {}))
    if outcomes:
        A("\n## Dispatch-attempt funnel")
        total = sum(outcomes.values())
        for name, n in outcomes.most_common():
            A(f"* {name}: {n} ({n / total:.0%})")

    # -------- GridBank flow summary --------
    A("\n## GridBank flow")
    spend = metrics.get("bank.total_spend_gd")
    rev = metrics.get("bank.total_revenue_gd")
    if spend is None:
        A("*(no bank metrics in this trace)*")
    else:
        A(f"* total spend: **{spend:.2f} G$** — total owner revenue: "
          f"**{rev:.2f} G$** (delta {spend - rev:+.2e})")
        if "bank.settlements" in metrics:
            A(f"* settlements recorded: {metrics['bank.settlements']:.0f}")
        by_kind = metrics.get("bank.revenue_by_kind_gd")
        if by_kind:
            A("\n| revenue stream | G$ |")
            A("|---|---|")
            for label in sorted(by_kind):
                A(f"| {label} | {by_kind[label]:.2f} |")
            A(f"| **total** | **{math.fsum(by_kind.values()):.2f}** |")
    att = metrics.get("broker.attempts_per_job")
    if isinstance(att, dict) and att.get("count"):
        A(f"\nattempts/job: mean {att['sum'] / att['count']:.2f} "
          f"(n={att['count']}, max {att['max']:.0f})")
    lat = metrics.get("broker.attempt_latency_s")
    if isinstance(lat, dict) and lat.get("count"):
        p50, p95, p99 = (_percentile_from_summary(lat, p)
                         for p in (50, 95, 99))
        A(f"attempt latency (submit->settle): p50 {p50 / 60:.1f}min, "
          f"p95 {p95 / 60:.1f}min, p99 {p99 / 60:.1f}min "
          f"(n={lat['count']})")
    eps = metrics.get("market.events_per_sec")
    if eps:
        A(f"sim throughput when captured: {eps:,.0f} events/s")
    return "\n".join(L)


# ---------------------------------------------------------------------------
# --explain-job: causal post-mortem for one job from the trace alone
# ---------------------------------------------------------------------------

def _job_key(span_id):
    """``EXP/JOB/aN`` -> ``EXP/JOB``; ``EXP/JOB`` -> itself."""
    parts = span_id.rsplit("/", 1)
    if len(parts) == 2 and parts[1].startswith("a") \
            and parts[1][1:].isdigit():
        return parts[0]
    return span_id


def _primary_key(job_key):
    """Duplicates are ``EXP/JOB~k`` — fold them onto their primary."""
    return job_key.split("~", 1)[0]


def explain_job(path, target):
    """Walk the trace backward from one job and narrate what happened
    to it: every dispatch attempt (where it went, at what committed
    price, how it ended), the churn/failure/suspicion/money events on
    the machines it touched while it was there, and a cost-and-delay
    attribution across the attempts.  ``target`` is the job span id
    (``EXP/JOB``); ``auto`` picks the most-retried job in the trace."""
    doc = _load_trace(path)
    evs = [e for e in doc["traceEvents"] if e.get("ph") != "M"]
    tid_track = {e["tid"]: e["args"]["name"]
                 for e in doc["traceEvents"]
                 if e.get("ph") == "M" and e.get("name") == "thread_name"}

    # group attempt-span events by primary job.  A fault requeue hands
    # the attempt back (the counter rolls back), so one span id can
    # legitimately carry several begin/end pairs — pair them in stream
    # order rather than keying on the id alone
    raw = defaultdict(list)             # primary -> [event, ...]
    job_spans = defaultdict(dict)       # job_key -> {b, e}
    for e in evs:
        if e.get("cat") != "job" or e["ph"] not in ("b", "e"):
            continue
        sid = e.get("id", "")
        if e["name"] == "attempt":
            raw[_primary_key(_job_key(sid))].append(e)
        elif e["name"] == "job":
            job_spans[sid][e["ph"]] = e

    if not raw:
        print(f"error: trace {path!r} has no attempt spans "
              f"(exported before any dispatch?)", file=sys.stderr)
        sys.exit(2)

    def _pair(events):
        """Stream-order pairing: (begin_ts, span_id, b_ev, e_ev) rows."""
        open_b = {}
        rows = []
        for e in events:
            sid = e.get("id", "")
            if e["ph"] == "b":
                open_b[sid] = e
            else:
                b = open_b.pop(sid, None)
                ts = b["ts"] if b else e["ts"]
                rows.append((ts, sid, b, e))
        rows.extend((b["ts"], sid, b, None) for sid, b in open_b.items())
        rows.sort(key=lambda r: (r[0], r[1]))
        return rows

    if target == "auto":
        # the most-retried job tells the best story; break ties on the
        # latest attempt timestamp, then id (deterministic)
        def _score(item):
            key, events = item
            return (sum(1 for e in events if e["ph"] == "e"),
                    max(e["ts"] for e in events), key)
        target = max(raw.items(), key=_score)[0]
    elif target not in raw:
        near = sorted(k for k in raw if target in k)[:5]
        hint = f" (close: {', '.join(near)})" if near else ""
        print(f"error: no job {target!r} in trace {path!r}{hint}",
              file=sys.stderr)
        sys.exit(3)

    rows = _pair(raw[target])
    resources = {ev["args"]["resource"]
                 for _, _, b, e in rows for ev in (b, e)
                 if ev and ev.get("args", {}).get("resource")}
    user = ""
    track = rows[0][2] or rows[0][3]
    if track is not None:
        tr = tid_track.get(track["tid"], "")
        user = tr[7:] if tr.startswith("broker:") else tr

    L = []
    A = L.append
    A(f"# Post-mortem: job {target}  (broker {user or '?'})")
    jspan = job_spans.get(target, {})
    jb, je = jspan.get("b"), jspan.get("e")
    if jb and je:
        A(f"lifecycle: {jb['ts'] / HOUR_US:.2f}h -> "
          f"{je['ts'] / HOUR_US:.2f}h "
          f"({(je['ts'] - jb['ts']) / HOUR_US:.2f}h wall), outcome "
          f"**{je['args'].get('outcome', '?')}** after "
          f"{je['args'].get('attempts', len(rows))} attempt(s), "
          f"final cost {je['args'].get('cost', 0.0):.2f} G$")
    elif jb:
        A(f"lifecycle: began {jb['ts'] / HOUR_US:.2f}h, never closed "
          f"(run ended with the job in flight)")

    # context: what happened on/around the machines this job touched
    lo = min(ts for ts, _, _, _ in rows)
    hi = max((ev["ts"] for _, _, b, e in rows for ev in (b, e) if ev),
             default=lo)
    pad = 0.5 * HOUR_US
    context = []
    for e in evs:
        if e["ph"] != "i" or not (lo - pad <= e["ts"] <= hi + pad):
            continue
        a = e.get("args", {})
        cat, name = e.get("cat"), e.get("name")
        if cat in ("churn", "gis", "bank") and (
                a.get("resource") in resources
                or (cat == "churn"
                    and name in ("site_leave", "site_join", "eviction"))):
            context.append(e)
        elif cat == "auction" and name == "contract" \
                and a.get("user") == user:
            context.append(e)
        elif cat == "job" and name in ("requeue", "duplicate") \
                and _primary_key(f"x/{a.get('job_id', '')}") \
                == f"x/{target.split('/', 1)[-1]}":
            context.append(e)
    context.sort(key=lambda e: e["ts"])

    A(f"\n## Attempts ({len(rows)})")
    settled_cost = killed_cost = 0.0
    failed_time = gap_time = 0.0
    prev_end = None
    for i, (ts, sid, b, e) in enumerate(rows, 1):
        ba = (b or {}).get("args", {})
        ea = (e or {}).get("args", {})
        res = ea.get("resource") or ba.get("resource") or "?"
        out = ea.get("outcome", "open")
        cost = ea.get("cost", 0.0)
        t0 = ts / HOUR_US
        dup = "~" in sid.rsplit("/", 1)[0]
        label = "duplicate " if dup else ""
        line = (f"{i}. t={t0:6.2f}h  {label}attempt `{sid}` -> {res} "
                f"(committed {ba.get('committed', 0.0):.2f} G$")
        if ba.get("price"):
            line += f" @ {ba['price']:.3f} G$/chip-h"
        line += f"): **{out}**"
        if e is not None:
            dur = (e["ts"] - ts) / HOUR_US if b else 0.0
            line += f" after {dur:.2f}h"
            if out == "settled":
                settled_cost += cost
                line += f", cost {cost:.2f} G$"
            elif out == "killed":
                killed_cost += cost
                line += f", sunk {cost:.2f} G$ (lost the duplicate race)"
            elif out in ("failed", "slot_lost"):
                failed_time += (e["ts"] - ts) if b else 0.0
                if ea.get("reason"):
                    line += f" ({ea['reason']})"
            if prev_end is not None and ts > prev_end:
                gap_time += ts - prev_end
            prev_end = e["ts"]
        A(line)

    if context:
        A(f"\n## Concurrent events on involved machines "
          f"({len(context)})")
        for e in context:
            a = e.get("args", {})
            bits = " ".join(f"{k}={a[k]}" for k in sorted(a))
            A(f"* t={e['ts'] / HOUR_US:6.2f}h  [{e['cat']}] "
              f"{e['name']}  {bits}")

    A("\n## Attribution")
    A(f"* money: {settled_cost:.2f} G$ bought the result"
      + (f"; {killed_cost:.2f} G$ sunk into killed duplicates "
         f"(speculation premium)" if killed_cost else
         "; no duplicate spend"))
    A(f"* delay: {failed_time / HOUR_US:.2f}h burned in "
      f"failed/preempted attempts, {gap_time / HOUR_US:.2f}h waiting "
      f"between attempts (queue/replan)")
    if jb and je and rows:
        useful = (je["ts"] - jb["ts"]) - failed_time - gap_time
        A(f"* of {(je['ts'] - jb['ts']) / HOUR_US:.2f}h wall, "
          f"{max(useful, 0.0) / HOUR_US:.2f}h was the winning attempt")
    return "\n".join(L)


def main():
    cells = load(CELLS)
    perf = load(PERF)
    ok = dedup([r for r in cells if not r.get("skipped")],
               lambda r: (r["arch"], r["shape"], r["mesh"]))
    sk = dedup([r for r in cells if r.get("skipped")],
               lambda r: (r["arch"], r["shape"], r["mesh"]))
    single = sorted([r for r in ok if r["mesh"] == "16x16"],
                    key=lambda r: (r["arch"], ORDER[r["shape"]]))
    multi = [r for r in ok if r["mesh"] == "2x16x16"]

    L = []
    A = L.append
    A("# EXPERIMENTS — Nimrod/G on a TPU computational grid\n")
    A("Hardware model: TPU v5e — 197 bf16 TFLOP/s, 819 GB/s HBM, "
      "~50 GB/s/link ICI per chip. Single pod = 16x16 = 256 chips "
      "(`data` x `model`); multi-pod = 2x16x16 = 512 chips "
      "(+ leading pure-DP `pod` axis).\n")
    A("All numbers derive from compiled dry-run artifacts "
      "(`.lower().compile()` with `ShapeDtypeStruct` inputs on 512 "
      "placeholder host devices): `memory_analysis()`, a loop-aware HLO "
      "walk for FLOPs/bytes (XLA's own `cost_analysis()` counts `while` "
      "bodies once — verified to under-count scans by exactly the trip "
      "count, see `repro/roofline/hlo_cost.py`), and per-op collective "
      "byte accounting.  Methodology caveat: the byte term is an *upper "
      "bound* — the CPU backend's small kLoop fusions count more HBM "
      "round-trips than a TPU compilation would make (every cross-fusion "
      "operand/result is charged). Relative deltas between variants are "
      "the reliable signal; we report them as such in §Perf.\n")

    # ---------------- Dry-run ----------------
    A("\n## §Dry-run\n")
    A(f"* single-pod (16x16): **{len(single)}/{len(single)} applicable "
      "cells lower + compile cleanly**")
    A(f"* multi-pod (2x16x16): **{len(multi)} cells compile** — the `pod` "
      "axis shards (gradient all-reduce crosses the pod boundary; "
      "verified in the partitioned HLO)")
    A(f"* skipped cells: {len(sk) // 2 if sk else 0} x `long_500k` on pure "
      "full-attention archs (stablelm, nemotron, musicgen, deepseek-v2, "
      "kimi-k2, llava-next) per the assignment's sub-quadratic rule; "
      "recorded in the cache with reasons (DESIGN.md §4).\n")
    A("Per-cell compiled footprint (single-pod; per-device bytes from "
      "`memory_analysis()`):\n")
    A("| arch | shape | args/device | temp/device | compile |")
    A("|---|---|---|---|---|")
    for r in single:
        A(f"| {r['arch']} | {r['shape']} | "
          f"{fmt_b(r.get('argument_size_per_chip', 0))} | "
          f"{fmt_b(r.get('peak_memory_per_chip', 0))} | "
          f"{r.get('t_compile_s', 0):.0f}s |")
    A("\nNotes: kimi-k2-1t train args = 42.7 GB/chip (bf16 params + fp32 "
      "Adam moments for 1.04T params over 256 chips) — exceeds a v5e's "
      "16 GB HBM; the config is *compilable and analyzable* but a real "
      "run needs more pods or the int8-moment optimizer "
      "(`AdamWConfig.quantized_moments`, implemented + tested) which "
      "drops it to ~18 GB/chip. Temp sizes are CPU-backend buffer "
      "assignments (upper bounds; no TPU rematerializer).\n")

    # ---------------- Roofline ----------------
    A("\n## §Roofline (single-pod, per step)\n")
    A("| arch | shape | compute | memory | collective | bottleneck | "
      "useful | MFU_ub |")
    A("|---|---|---|---|---|---|---|---|")
    for r in single:
        A(f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute_s'])} | "
          f"{fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} | "
          f"{r['bottleneck']} | {r['useful_flops_fraction']:.2f} | "
          f"{r['mfu_upper_bound']:.2%} |")
    A("\n*useful* = MODEL_FLOPS / HLO_FLOPs with MODEL_FLOPS = 6·N·D "
      "(trains) or 2·N_active·D (serving); values < 1 expose remat "
      "recompute (~1.33x for full remat), replicated compute "
      "(unshardable head counts), and capacity-padded MoE GEMMs. "
      "*MFU_ub* = MODEL_FLOPS / (chips · peak · max-term).\n")
    A("Per-cell bottleneck notes (what would move the dominant term):\n")
    bn = defaultdict(list)
    for r in single:
        bn[r["arch"]].append(r)
    notes = {
        "llava-next-34b": "56 heads % 16 != 0 -> attention replicated over "
        "the model axis; fixed by context parallelism (§Perf cell A, 13x)",
        "musicgen-medium": "24 heads % 16 != 0 -> same replication "
        "pathology as llava; same fix applies (verified on cell A)",
        "rwkv6-3b": "40 wkv heads % 16 != 0 -> replicated time-mix; "
        "chunked-GLA kernel with head padding to 48 is the TPU answer",
        "kimi-k2-1t-a32b": "decode was collective-bound on per-step expert "
        "weight gathers; fixed by token-routed EP (§Perf cell B, 2.8x)",
        "deepseek-v2-236b": "memory-bound on MoE token gather/scatter "
        "(top-6 x d per token per layer) + MLA decompression",
        "gemma3-27b": "memory-bound: fp32 master gathers + f32 norm/CE "
        "chains (§Perf cell C)",
        "gemma3-1b": "small model on 256 chips: DP+FSDP dominates; "
        "long_500k is collective-bound on B=1 unshardable batch",
        "recurrentgemma-2b": "healthiest small arch (local attn bands + "
        "cheap RG-LRU scan)",
        "nemotron-4-15b": "decode collective-bound on kv-weight "
        "resharding (kv=8 < 16); replicate kv projections to fix",
        "stablelm-1.6b": "memory-bound on f32 norm chains at small d",
    }
    for arch in sorted(bn):
        A(f"* **{arch}** — {notes.get(arch, '')}")

    # ---------------- Perf ----------------
    A("\n## §Perf — hillclimbing log (3 cells)\n")
    A("Cells: **A** = worst useful-FLOPs big-model train cell "
      "(llava-next-34b x train_4k); **B** = most collective-bound "
      "(kimi-k2-1t x decode_32k); **C** = most representative of the "
      "paper's workload — the sweep's dense train jobs "
      "(gemma3-27b x train_4k).  Loop: hypothesis -> napkin math -> "
      "change -> re-lower -> record (confirmed/refuted).\n")
    by_exp = defaultdict(list)
    for r in perf:
        by_exp[r.get("experiment", "?")].append(r)
    verdicts = {
        ("A", "A1_seq_shard"): "CONFIRMED (13.3x step-LB: 581.6s -> 43.7s; "
        "compute 30.5 -> 6.0s, memory 581.6 -> 43.7s). Collective rose "
        "8.5 -> 21.0s (blockwise-attention KV gathers + grad all-reduce "
        "over model for now-replicated weights) — a good trade.",
        ("A", "A2_+bf16_params"): "REFUTED (no change): XLA gathered the "
        "fp32 masters *then* converted; the cast must be fused into the "
        "collective (convert-before-gather) to pay off — see cell C where "
        "it does.",
        ("A", "A3_+chunked_ce"): "REFUTED (±0.1%): with seq sharded over "
        "model, each device already holds only S/16 of the logits; "
        "chunking adds nothing on top.",
        ("B", "B1_ep_a2a"): "CONFIRMED for the collective term (5.14s -> "
        "0.21s, 25x): tokens (k·d bytes each) instead of 2.1 GB/layer of "
        "expert weights. Step-LB 5.14 -> 2.75s (1.9x vs same-day "
        "baseline; 2.8x vs the original 7.76s pre-split-KV baseline). "
        "Bottleneck moved to memory (dense-weight FSDP gathers + cache).",
        ("B", "B2_+chunked_ce"): "REFUTED (no change): 128 rows of logits "
        "are negligible at decode.",
        ("C", "C1_bf16_params"): "REFUTED (no change): same gather-then-"
        "convert ordering as A2.",
        ("C", "C2_+chunked_ce"): "REFUTED (+0.2%): the CE region is a "
        "small share of the (inflated) activation-byte total.",
        ("C", "C3_+remat_dots"): "MIXED: compute -20% as predicted "
        "(4.77 -> 3.80s) but saved dots push memory 42.0 -> 54.6s; net "
        "regression on the dominant term — kept remat=full.",
        ("C", "C4_bf16_masters"): "REFUTED, informatively: memory "
        "unchanged => the byte term is ACTIVATION-dominated, not "
        "weight-gather-dominated, at 1M tokens/step. Redirected the "
        "search to activation sharding (C5).",
        ("C", "C5_+seq_shard"): "CONFIRMED (2.81x): sharding seq over "
        "'model' on top of batch-over-'data' makes activations 256-way "
        "sharded; memory 41.97 -> 14.44s, MFU_ub 8.0% -> 22.6%; now "
        "collective-bound (local-attention band exchanges + KV gathers).",
        ("C", "C6_+remat_dots"): "REFUTED as net win: compute -22% "
        "(3.63s) but memory 14.4 -> 17.6s > collective 14.9s; dominant "
        "term worsens. Stopped: last three C-iterations < 5% on the "
        "dominant term.",
    }
    for key in sorted(by_exp):
        rows = dedup(by_exp[key], lambda r: r["variant"])
        if not rows:
            continue
        arch, shape = rows[0]["arch"], rows[0]["shape"]
        A(f"\n### Cell {key}: {arch} x {shape}\n")
        A("| variant | compute | memory | collective | bottleneck | MFU_ub "
          "| step-LB |")
        A("|---|---|---|---|---|---|---|")
        for r in rows:
            lb = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
            A(f"| {r['variant']} | {fmt_s(r['t_compute_s'])} | "
              f"{fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} | "
              f"{r['bottleneck']} | {r['mfu_upper_bound']:.2%} | "
              f"{fmt_s(lb)} |")
        A("")
        for r in rows:
            if r["variant"] == "baseline":
                A(f"* **baseline (paper-faithful)** — {r['hypothesis']}")
            else:
                v = verdicts.get((key, r["variant"]), "")
                A(f"* **{r['variant']}** — hypothesis: {r['hypothesis']} "
                  f"-> {v}")
        base = next(r for r in rows if r["variant"] == "baseline")
        blb = max(base["t_compute_s"], base["t_memory_s"],
                  base["t_collective_s"])
        best = min(max(r["t_compute_s"], r["t_memory_s"],
                       r["t_collective_s"]) for r in rows)
        A(f"\n**Cell {key} outcome: step-time lower bound "
          f"{fmt_s(blb)} -> {fmt_s(best)} ({blb / best:.2f}x).**")

    A("\n### Perf summary — paper-faithful vs beyond-paper\n")
    A("| cell | paper-faithful baseline | optimized | gain | mechanism |")
    A("|---|---|---|---|---|")
    A("| A llava train_4k | 581.6s (MFU_ub 0.74%) | 43.6s (MFU_ub 9.8%) | "
      "**13.3x** | context parallelism for unshardable head counts |")
    A("| B kimi decode_32k | 7.76s (original) / 5.14s (with split-KV "
      "cache) | 2.75s | **2.8x** | token-routed EP (a2a) + split-KV "
      "decode cache |")
    A("| C gemma3-27b train_4k | 41.97s (MFU_ub 8.0%) | 14.92s (MFU_ub "
      "22.6%) | **2.8x** | 2-D activation sharding (batch x seq) |")
    A("\nMoE parallelism crossover (generalizing cell B; "
      "`benchmarks/bench_moe_crossover.py`): for kimi-k2, token-routed "
      "a2a EP beats weight-gathered EP 25x on the decode collective term "
      "(0.20s vs 5.14s) but loses 6x at train (334s vs 54s) — the "
      "crossover sits at T_loc ~ E_loc*f/(2k) ~ 3k tokens/chip, "
      "confirmed in compiled collectives in both directions.\n")
    A("\nBeyond-paper techniques adopted framework-wide after validation: "
      "split-KV decode-cache sharding (all decode cells), dropless MoE "
      "capacity for serving batches, int8 Adam moments (optional), "
      "`seq_shard`/`cast_params_bf16`/`chunked_ce`/`moe_impl=ep_a2a` as "
      "per-config knobs. The Nimrod/G scheduler itself consumes these "
      "numbers: `grid_submit` seeds job-duration estimates from the "
      "roofline step-time lower bounds and refines them online from "
      "measured consumption rates — the paper's 'historical information' "
      "loop closed with real compiler artifacts.\n")

    # ---------------- paper validation ----------------
    A("\n## §Paper validation (Figure 3 + §3 economy)\n")
    A("`python -m benchmarks.run` reproduces, on a 70-machine GUSTO-like "
      "testbed with 165 jobs (the paper's April/May 1999 trial shape):\n")
    A("* deadline 10h -> peak 8 machines; 15h -> 5; 20h -> 4 — *'as the "
      "deadline is tightened, the scheduler needs to find more resources "
      "until the deadline can be met'* — all three deadlines met "
      "(`test_figure3_deadline_vs_resources`, asserted as a property "
      "over random grids too);")
    A("* time-optimization finishes 7.7x faster at 9.4x the cost of "
      "cost-optimization on the same workload (paper §3's trade-off);")
    A("* budget is a hard ceiling under all three strategies "
      "(property-tested); conservative mode stalls rather than "
      "over-commits;")
    A("* failures requeue (at-least-once execution, exactly-once "
      "completion via the journal), stragglers race duplicates, "
      "first-finisher wins;")
    A("* contract mode returns feasible/infeasible quotes with cost + "
      "completion estimates and locks prices via reservations on "
      "acceptance.\n")
    A("Control-plane scale (DES wall time on 1 CPU core): 70 machines x "
      "165 jobs ~ 0.2s; 300 x 2k ~ 4s; 1000 x 10k ~ 62s — the scheduler "
      "tick is O(resources log resources) and journaling is O(1)/event, "
      "comfortably 1000+ node scale.\n")

    with open(OUT, "w") as f:
        f.write("\n".join(L) + "\n")
    print(f"wrote {OUT} ({len(L)} lines)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--market-trace", metavar="TRACE_JSON", default=None,
                    help="render the observability dashboard from an "
                         "exported Chrome trace instead of EXPERIMENTS.md")
    ap.add_argument("--explain-job", metavar="EXP/JOB", default=None,
                    help="render a causal post-mortem for one job from "
                         "the trace given as the positional argument "
                         "(or --market-trace); 'auto' picks the "
                         "most-retried job")
    ap.add_argument("trace", nargs="?", default=None,
                    help="trace file for --explain-job")
    args = ap.parse_args()
    if args.explain_job:
        path = args.trace or args.market_trace
        if not path:
            ap.error("--explain-job needs a trace file")
        print(explain_job(path, args.explain_job))
    elif args.market_trace:
        print(market_dashboard(args.market_trace))
    else:
        main()
