import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""MoE parallelism crossover: weight-gathered EP vs token-routed (a2a) EP.

The §Perf cell-B insight generalized: expert weights cost
E_loc*d*f*2B per layer to gather; tokens cost T_loc*k*d*2B*2 to route.
The collective-optimal layout flips at
    T_loc ~ E_loc*f / (2*k)
(kimi: 24*2048/(2*8) = 3072 tokens/chip).  This bench lowers kimi-k2
decode (T_loc=8) and train (T_loc=65536) under both layouts and reports
the measured collective terms against that prediction.

    PYTHONPATH=src python -m benchmarks.bench_moe_crossover
"""
import time

from repro.configs import get_config, get_shape
from repro.launch.dryrun import run_cell


def main(csv: bool = False):
    out = []
    arch = "kimi-k2-1t-a32b"
    for shape_name, impls in (("decode_32k", ("ep", "ep_a2a")),
                              ("train_4k", ("ep", "ep_a2a"))):
        shape = get_shape(shape_name)
        for impl in impls:
            cfg = get_config(arch).replace(moe_impl=impl)
            t0 = time.time()
            row = run_cell(arch, shape, multi_pod=False, verbose=False,
                           cfg_override=cfg)
            out.append((shape_name, impl, row, time.time() - t0))
    if not csv:
        print("shape        impl     collective   memory   bottleneck")
        for shape_name, impl, row, _ in out:
            print(f"{shape_name:12s} {impl:8s} {row['t_collective_s']:9.2f}s "
                  f"{row['t_memory_s']:8.2f}s   {row['bottleneck']}")
        print("\nprediction: a2a wins at decode (T_loc=8 << 3072), "
              "weight-gathered wins at train (T_loc=65536 >> 3072)")
        dec = {impl: r for s, impl, r, _ in out if s == "decode_32k"}
        trn = {impl: r for s, impl, r, _ in out if s == "train_4k"}
        assert dec["ep_a2a"]["t_collective_s"] < dec["ep"]["t_collective_s"]
        assert trn["ep"]["t_collective_s"] < trn["ep_a2a"]["t_collective_s"]
        print("both predictions CONFIRMED by the compiled collectives")
    return [(f"moe_{s}_{i}", w * 1e6, round(r["t_collective_s"], 3))
            for s, i, r, w in out]


if __name__ == "__main__":
    main()
