"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus human-readable tables
before the CSV block).

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    results = []
    failures = []
    from benchmarks import (bench_auctions, bench_distributed,
                            bench_figure3, bench_gis, bench_kernels,
                            bench_marketplace, bench_roofline,
                            bench_scale, bench_scheduler,
                            bench_secondary, bench_telemetry,
                            bench_tournament)
    mods = [("figure3 (paper Fig.3, GUSTO deadline trial)", bench_figure3),
            ("scheduler tables (strategies / scale / faults)",
             bench_scheduler),
            ("marketplace (N concurrent brokers, contended economy)",
             bench_marketplace),
            ("auctions (negotiated contracts vs posted prices)",
             bench_auctions),
            ("GIS staleness (view TTL x site churn)", bench_gis),
            ("scale (array core: jobs x users x variant + 100k/1M tier)",
             bench_scale),
            ("secondary market (resale on/off x brokers, price discovery)",
             bench_secondary),
            ("strategy tournament (registry zoo x 4 market regimes)",
             bench_tournament),
            ("telemetry (tracer overhead, traced vs untraced)",
             bench_telemetry),
            ("distributed (wire loopback vs per-domain processes)",
             bench_distributed),
            ("kernels (pallas vs oracle)", bench_kernels),
            ("roofline (dry-run 3-term table)", bench_roofline)]
    # moe crossover needs 512 placeholder devices; include only when the
    # process was launched with the dry-run XLA flag
    import jax
    if jax.device_count() >= 512:
        from benchmarks import bench_moe_crossover
        mods.append(("MoE EP crossover (weight-gathered vs token-routed)",
                     bench_moe_crossover))
    for title, mod in mods:
        print(f"\n===== {title} =====")
        try:
            results.extend(mod.main())
        except Exception:
            traceback.print_exc()
            failures.append(title)

    print("\nname,us_per_call,derived")
    for name, us, derived in results:
        print(f"{name},{us:.1f},{derived}")
    if failures:
        print(f"\nFAILED sections: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
