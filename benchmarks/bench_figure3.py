"""Paper Figure 3: GUSTO trial reproduction.

165-job ionization-chamber-style experiment over a ~70-machine,
multi-domain testbed; deadlines 10/15/20 h.  The paper's claim: as the
deadline tightens the scheduler buys more (and more expensive) resources,
meeting every deadline.  We reproduce the qualitative law and print the
resource/cost table; an ASCII timeline mirrors the figure's
machines-in-use-over-time panels.
"""
from __future__ import annotations

import time
from typing import Dict

from repro.core import (Dispatcher, NimrodG, PriceSchedule,
                        ResourceDirectory, SimulatedExecutor, Simulator,
                        TradeServer, UserRequirements, gusto_like_testbed,
                        parse_plan)

HOUR = 3600.0

PLAN = """
parameter angle float range from 1 to 165 step 1
task main
    copy ion.model node:.
    execute ionize --angle $angle
    copy node:out.dat results/$jobname.dat
endtask
"""


def run_trial(deadline_h: float, strategy: str = "cost",
              budget: float = 30_000.0, seed: int = 0):
    directory = ResourceDirectory()
    for spec in gusto_like_testbed(70, seed=1):
        directory.register(spec)
    schedules = {n: PriceSchedule(directory.spec(n))
                 for n in directory.all_names()}
    trade = TradeServer(directory, schedules)
    sim = Simulator()
    ex = SimulatedExecutor(sim, directory, seed=seed)
    disp = Dispatcher(ex, directory)
    req = UserRequirements(deadline=deadline_h * HOUR, budget=budget,
                           strategy=strategy)
    eng = NimrodG.from_plan("ion-chamber", parse_plan(PLAN), req, directory,
                            trade, disp, est_seconds=lambda p: 2400.0,
                            sim=sim, seed=seed)
    return eng.run_simulated()


def ascii_timeline(report, width: int = 48) -> str:
    if not report.timeline:
        return ""
    tmax = report.timeline[-1][0] or 1.0
    peak = max(a for _, a, _, _ in report.timeline) or 1
    cells = [0] * width
    for t, alloc, _, _ in report.timeline:
        i = min(int(t / tmax * (width - 1)), width - 1)
        cells[i] = max(cells[i], alloc)
    return "".join(" .:-=+*#%@"[min(int(c / peak * 9), 9)] for c in cells)


def main(csv: bool = False):
    t0 = time.time()
    rows = []
    for dl in (10, 15, 20):
        rep = run_trial(dl)
        rows.append((dl, rep))
    if not csv:
        print("deadline_h  met   peak_resources  resources_used  cost_G$  "
              "completion_h")
        for dl, rep in rows:
            print(f"{dl:9.0f}  {str(rep.met_deadline):5s} "
                  f"{rep.peak_allocation:14d}  {len(rep.resources_used):14d} "
                  f"{rep.total_cost:8.1f}  {rep.completion_time / HOUR:8.2f}")
        for dl, rep in rows:
            print(f"  {dl:3.0f}h |{ascii_timeline(rep)}| "
                  f"(machines in use over time)")
    # the paper's law, asserted
    peaks = {dl: rep.peak_allocation for dl, rep in rows}
    assert peaks[10] > peaks[15] >= peaks[20], peaks
    assert all(rep.met_deadline for _, rep in rows)
    dt = time.time() - t0
    return [("figure3_gusto_deadline_10h", dt / 3 * 1e6,
             rows[0][1].peak_allocation),
            ("figure3_gusto_deadline_15h", dt / 3 * 1e6,
             rows[1][1].peak_allocation),
            ("figure3_gusto_deadline_20h", dt / 3 * 1e6,
             rows[2][1].peak_allocation)]


if __name__ == "__main__":
    main()
