"""Scheduler tables beyond Figure 3:

* strategy comparison (cost / time / conservative) on one workload —
  the paper §3 trade-off as a table;
* control-plane scalability: events/second and wall time as the grid
  grows to 1000+ resources and 10k jobs (large-scale runnability of the
  scheduling layer itself);
* fault-tolerance accounting under an unreliable grid.
"""
from __future__ import annotations

import time

from repro.core import (Dispatcher, NimrodG, PriceSchedule,
                        ResourceDirectory, SchedulerConfig,
                        SimulatedExecutor, Simulator, TradeServer,
                        UserRequirements, gusto_like_testbed, parse_plan)

HOUR = 3600.0


def _plan(n_jobs: int):
    return parse_plan(f"""
parameter i integer range from 1 to {n_jobs} step 1
task main
    execute run --i $i
endtask
""")


def _engine(n_jobs, n_machines, deadline_h, strategy, budget=1e9, seed=0,
            est=1800.0, mtbf_scale=1.0):
    directory = ResourceDirectory()
    for spec in gusto_like_testbed(n_machines, seed=1):
        if mtbf_scale != 1.0:
            import dataclasses
            spec = dataclasses.replace(
                spec, mtbf_hours=spec.mtbf_hours * mtbf_scale)
        directory.register(spec)
    schedules = {n: PriceSchedule(directory.spec(n))
                 for n in directory.all_names()}
    trade = TradeServer(directory, schedules)
    sim = Simulator()
    ex = SimulatedExecutor(sim, directory, seed=seed)
    disp = Dispatcher(ex, directory)
    req = UserRequirements(deadline=deadline_h * HOUR, budget=budget,
                           strategy=strategy)
    return NimrodG.from_plan("bench", _plan(n_jobs), req, directory, trade,
                             disp, est_seconds=lambda p: est, sim=sim,
                             seed=seed)


def strategy_table(csv: bool = False):
    out = []
    for strat in ("cost", "time", "conservative"):
        t0 = time.time()
        rep = _engine(165, 70, 15, strat, budget=30_000).run_simulated()
        out.append((strat, rep, time.time() - t0))
    if not csv:
        print("strategy       done  completion_h  cost_G$  peak_res  met")
        for strat, rep, _ in out:
            print(f"{strat:13s} {rep.n_done:5d} "
                  f"{rep.completion_time / HOUR:12.2f} "
                  f"{rep.total_cost:8.1f} {rep.peak_allocation:9d}  "
                  f"{rep.met_deadline}")
    return [(f"strategy_{s}", dt * 1e6, rep.total_cost)
            for s, rep, dt in out]


def scale_table(csv: bool = False):
    rows = []
    for n_machines, n_jobs in ((70, 165), (300, 2000), (1000, 10000)):
        t0 = time.time()
        eng = _engine(n_jobs, n_machines, 24, "cost", est=600.0,
                      mtbf_scale=10.0)
        rep = eng.run_simulated()
        wall = time.time() - t0
        n_events = rep.n_done + rep.requeues + rep.duplicates_launched
        rows.append((n_machines, n_jobs, rep, wall,
                     n_events / max(wall, 1e-9)))
    if not csv:
        print("machines  jobs    done    wall_s  jobs/sec_sim  met")
        for m, j, rep, wall, eps in rows:
            print(f"{m:8d} {j:6d} {rep.n_done:6d} {wall:9.2f} "
                  f"{rep.n_done / max(wall, 1e-9):12.0f}  {rep.met_deadline}")
    return [(f"scale_{m}m_{j}j", wall * 1e6, rep.n_done)
            for m, j, rep, wall, _ in rows]


def fault_table(csv: bool = False):
    rows = []
    for mtbf_scale, label in ((1.0, "normal"), (0.05, "hostile")):
        t0 = time.time()
        eng = _engine(200, 40, 30, "time", est=1800.0,
                      mtbf_scale=mtbf_scale)
        eng.cfg = SchedulerConfig(max_attempts=50)
        rep = eng.run_simulated()
        rows.append((label, rep, time.time() - t0))
    if not csv:
        print("grid      done  requeues  duplicates  completion_h")
        for label, rep, _ in rows:
            print(f"{label:8s} {rep.n_done:5d} {rep.requeues:9d} "
                  f"{rep.duplicates_launched:11d} "
                  f"{rep.completion_time / HOUR:12.2f}")
    return [(f"fault_{label}", dt * 1e6, rep.requeues)
            for label, rep, dt in rows]


def main(csv: bool = False):
    return strategy_table(csv) + scale_table(csv) + fault_table(csv)


if __name__ == "__main__":
    main()
