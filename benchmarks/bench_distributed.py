"""What the wire costs: loopback codec vs per-domain OS processes.

The sharded grid runs the same protocol over two transports — an
in-process loopback (codec on, no process boundary) and real domain
processes joined by pipes.  This bench measures both against the
direct-call baseline: market events/sec for a full loopback marketplace
run, and request throughput + settlement round-trip latency against
2/4/8 domain processes.

Writes ``BENCH_distributed.json``.

    PYTHONPATH=src python -m benchmarks.bench_distributed            # full
    PYTHONPATH=src python -m benchmarks.bench_distributed --smoke    # CI
"""
from __future__ import annotations

import json
import os
import sys
import time

from repro.core.marketplace import standard_market
from repro.core.resources import ResourceSpec
from repro.core.transport import DomainConfig, spawn_domains

HOUR = 3600.0
SEED = 17
N_USERS = 4
N_MACHINES = 10
N_JOBS = 10

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_distributed.json")


# -- loopback: full market, direct vs codec ------------------------------

def _market_events_per_sec(wire: str, n_jobs: int) -> dict:
    market = standard_market(N_USERS, n_machines=N_MACHINES, seed=SEED,
                             n_jobs=n_jobs, wire=wire)
    t0 = time.time()
    rep = market.run()
    wall = time.time() - t0
    fired = market.sim.events
    row = {
        "wire": wire,
        "events_fired": fired,
        "events_per_sec": fired / max(wall, 1e-9),
        "wall_s": wall,
        "done": rep.total_done,
    }
    if wire == "loopback":
        transports = [s._transport
                      for s in market.trade.servers.values()]
        row["wire_messages"] = sum(t.messages for t in transports)
        row["wire_bytes"] = sum(t.bytes_out + t.bytes_in
                                for t in transports)
    return row


# -- process mode: request throughput + settlement latency ---------------

def _mk_specs(n_domains: int, per_domain: int):
    specs = []
    for d in range(n_domains):
        site = f"site{d:02d}"
        for i in range(per_domain):
            specs.append(ResourceSpec(
                name=f"{site.lower()}-{i:03d}", site=site,
                department=f"{site}/d0", chips=8, slots=2,
                base_price=1.0 + 0.1 * d))
    return specs


def _process_grid(n_domains: int, n_requests: int) -> dict:
    by_site = {}
    for s in _mk_specs(n_domains, per_domain=2):
        by_site.setdefault(s.site, []).append(s)
    cfgs = [DomainConfig(site=site, specs=tuple(ss))
            for site, ss in sorted(by_site.items())]
    t0 = time.time()
    procs, fed, gis = spawn_domains(cfgs)
    spawn_s = time.time() - t0
    try:
        names = fed.directory.all_names()
        # quote throughput: round-robin price reads across the domains
        t0 = time.time()
        for i in range(n_requests):
            fed.quote(names[i % len(names)], float(i))
        quote_wall = time.time() - t0
        # settlement round-trip latency (reserve once per domain first
        # so the ledgers have something real behind them)
        sites = fed.sites()
        lat = []
        for i in range(min(n_requests, 200)):
            site = sites[i % len(sites)]
            t0 = time.time()
            fed.servers[site].settle(f"bench:{i}", t=float(i), user="u0",
                                     resource=names[i % len(names)],
                                     amount=0.25)
            lat.append(time.time() - t0)
        lat.sort()
        return {
            "domains": n_domains,
            "spawn_s": spawn_s,
            "requests": n_requests,
            "quotes_per_sec": n_requests / max(quote_wall, 1e-9),
            "settle_p50_us": lat[len(lat) // 2] * 1e6,
            "settle_p95_us": lat[int(len(lat) * 0.95)] * 1e6,
            "settlements": len(lat),
        }
    finally:
        for p in procs.values():
            p.stop()


def main(csv: bool = False, smoke: bool = False):
    n_jobs = 4 if smoke else N_JOBS
    fanouts = (2,) if smoke else (2, 4, 8)
    n_requests = 200 if smoke else 2000

    loopback_rows = [_market_events_per_sec(w, n_jobs)
                     for w in ("direct", "loopback")]
    process_rows = [_process_grid(n, n_requests) for n in fanouts]

    if not csv:
        print(f"{'wire':10s} {'events/s':>12s} {'wall_s':>8s}")
        for r in loopback_rows:
            print(f"{r['wire']:10s} {r['events_per_sec']:12.0f} "
                  f"{r['wall_s']:8.3f}")
        print(f"\n{'domains':>8s} {'quotes/s':>10s} {'settle p50us':>13s} "
              f"{'p95us':>8s}")
        for r in process_rows:
            print(f"{r['domains']:8d} {r['quotes_per_sec']:10.0f} "
                  f"{r['settle_p50_us']:13.0f} {r['settle_p95_us']:8.0f}")

    out = {
        "bench": "distributed",
        "seed": SEED,
        "n_users": N_USERS,
        "n_machines": N_MACHINES,
        "n_jobs_per_user": n_jobs,
        "loopback": loopback_rows,
        "process": process_rows,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    if not csv:
        print(f"wrote {OUT_PATH}")

    results = []
    for r in loopback_rows:
        results.append((f"distributed_{r['wire']}_market",
                        r["wall_s"] * 1e6, r["events_per_sec"]))
    for r in process_rows:
        results.append((f"distributed_{r['domains']}proc_settle_p50",
                        r["settle_p50_us"], r["quotes_per_sec"]))
    return results


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
