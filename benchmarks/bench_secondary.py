"""Secondary capacity market sweep: resale on/off x brokers.

The PR-5 economy closes two loops: brokers resell contracted windows a
re-plan left idle (instead of paying the commitment fee to tear them
up), and owners' posted prices learn from clearing history
(``discovery_gain`` EMA).  This bench measures what that buys on one
seed:

* **wasted-contract spend** — G$ of commitment fees paid for
  reserved-but-unused windows (``GridBank`` kind ``"idle"``).  Enabling
  resale must strictly reduce it at every broker count (the N=16 point
  is the acceptance criterion);
* **price discovery** — the mean relative |posted - clearing| gap at
  each resource's k-th clearing round.  With ``discovery_gain > 0`` the
  sequence must shrink monotonically over the run;
* **books** — ``GridBank`` reconciles exactly against every broker
  ledger in every swept configuration (transfers, lump refunds, fees,
  discovery-adjusted settlements included).

    PYTHONPATH=src python -m benchmarks.bench_secondary            # full
    PYTHONPATH=src python -m benchmarks.bench_secondary --smoke    # CI

Results land in ``BENCH_secondary.json``.  Smoke mode runs the 4-broker
points only, re-checks same-seed determinism, rewrites the committed
JSON's ``smoke`` section, and FAILS if aggregate events/sec regressed
more than ``GATE`` (30%) against the committed baseline (override with
SECONDARY_BENCH_NO_GATE=1 when the hardware legitimately changed).
"""
from __future__ import annotations

import json
import os
import sys
import time

from repro.core import mixed_auction_market

HOUR = 3600.0

SEED = 11
N_MACHINES = 24
BROKERS = (4, 8, 16)
SMOKE_BROKERS = (4,)
GATE = 0.30                       # max tolerated events/sec regression

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_secondary.json")

MARKET_KW = dict(
    n_machines=N_MACHINES, seed=SEED, n_jobs=80, est_seconds=2700.0,
    deadline_h=20.0, budget=16000.0, auction_round=1800.0,
    auction_window=4 * HOUR, release_fee=0.25, ask_fraction=0.15,
    discovery_gain=0.2)


def point_key(resale: bool, users: int) -> str:
    return f"{'resale' if resale else 'fee'}_u{users}"


def _build(users: int, resale: bool):
    return mixed_auction_market(users, resale=resale, **MARKET_KW)


def run_point(users: int, resale: bool) -> dict:
    market = _build(users, resale)
    t0 = time.time()
    rep = market.run()
    wall = time.time() - t0
    # the books must balance in EVERY swept configuration — exactly
    ledgers = {u.name: e.ledger for u, e in zip(market.users,
                                                market.engines)}
    market.bank.reconcile(ledgers)
    gaps = market.history.gap_by_observation()
    ev = market.sim.events
    return {
        "resale": resale, "users": users,
        "wall_s": round(wall, 3), "events": ev,
        "events_per_sec": round(ev / max(wall, 1e-9), 1),
        "jobs_done": rep.total_done, "jobs_total": rep.total_jobs,
        "wasted_spend": round(rep.wasted_spend, 6),
        "resales": rep.resales,
        "resale_volume": round(rep.resale_volume, 6),
        "contracts": rep.contracts_struck,
        "total_spent": round(rep.total_spent, 6),
        "gap_by_observation": [round(g, 6) for g in gaps],
    }


def sweep(csv: bool, brokers, best_of: int = 1) -> list:
    rows = []
    if not csv:
        print("mode    users    done/total   wasted$   fills  contracts"
              "   ev/s    wall_s")
    for users in brokers:
        for resale in (False, True):
            r = max((run_point(users, resale) for _ in range(best_of)),
                    key=lambda r: r["events_per_sec"])
            rows.append(r)
            if not csv:
                mode = "resale" if r["resale"] else "fee"
                print(f"{mode:7s} {r['users']:5d} {r['jobs_done']:8d}/"
                      f"{r['jobs_total']:<7d} {r['wasted_spend']:8.2f} "
                      f"{r['resales']:6d} {r['contracts']:8d} "
                      f"{r['events_per_sec']:8.1f} {r['wall_s']:8.2f}")
    return rows


def check_acceptance(rows: list, csv: bool) -> None:
    """The claims this sweep exists to demonstrate, asserted."""
    by_key = {point_key(r["resale"], r["users"]): r for r in rows}
    for users in sorted({r["users"] for r in rows}):
        off = by_key.get(point_key(False, users))
        on = by_key.get(point_key(True, users))
        if off is None or on is None:
            continue
        assert on["wasted_spend"] < off["wasted_spend"], (
            f"u{users}: resale did not reduce wasted-contract spend "
            f"({on['wasted_spend']} vs {off['wasted_spend']})")
        gaps = on["gap_by_observation"]
        assert len(gaps) >= 2, f"u{users}: too few clearing rounds"
        assert all(b <= a + 1e-9 for a, b in zip(gaps, gaps[1:])), (
            f"u{users}: posted-vs-clearing gap not monotone: {gaps}")
        assert gaps[-1] < gaps[0], f"u{users}: gap did not shrink: {gaps}"
        if not csv:
            drop = off["wasted_spend"] - on["wasted_spend"]
            print(f"u{users}: wasted spend {off['wasted_spend']:.2f} -> "
                  f"{on['wasted_spend']:.2f} G$ (-{drop:.2f}), "
                  f"{on['resales']} fills, gap {gaps[0]:.4f} -> "
                  f"{gaps[-1]:.4f}")


def determinism_check(csv: bool):
    t0 = time.time()
    rep1 = _build(4, True).run()
    rep2 = _build(4, True).run()
    wall = time.time() - t0
    identical = rep1.stable_repr() == rep2.stable_repr()
    if not csv:
        print(f"same-seed resale-market re-run byte-identical: {identical}")
    if not identical:
        raise AssertionError("resale market run is not seed-deterministic")
    return [("secondary_determinism", wall * 1e6, int(identical))]


def _gate_against_committed(rows: list, csv: bool) -> None:
    """CI regression gate: aggregate events/sec vs the committed JSON
    (single points jitter on shared runners; the suite total is the
    stable signal — same pattern as bench_scale)."""
    if os.environ.get("SECONDARY_BENCH_NO_GATE"):
        return
    if not os.path.exists(OUT_PATH):
        return
    with open(OUT_PATH) as f:
        committed = json.load(f)
    base_rows = committed.get("smoke") or committed.get("results", [])
    baseline = {point_key(r["resale"], r["users"]): r for r in base_rows}
    got_ev = got_wall = base_ev = base_wall = 0.0
    for r in rows:
        base = baseline.get(point_key(r["resale"], r["users"]))
        if base is None or not base.get("events_per_sec"):
            continue
        got_ev += r["events"]
        got_wall += r["wall_s"]
        base_ev += base["events"]
        base_wall += base["wall_s"]
    if base_wall <= 0 or got_wall <= 0:
        return
    ratio = (got_ev / got_wall) / (base_ev / base_wall)
    if not csv:
        print(f"gate aggregate: {got_ev / got_wall:.0f} ev/s vs committed "
              f"{base_ev / base_wall:.0f} ({ratio:.2f}x)")
    if ratio < 1.0 - GATE:
        raise AssertionError(
            f"aggregate events/sec regressed >{GATE:.0%} vs committed "
            f"baseline ({ratio:.2f}x) — if the hardware changed, re-run "
            f"the full bench and commit a fresh BENCH_secondary.json "
            f"(or set SECONDARY_BENCH_NO_GATE=1)")


def main(csv: bool = False, smoke: bool = False):
    brokers = SMOKE_BROKERS if smoke else BROKERS
    # smoke points finish in under a second each: best-of-2 keeps the
    # regression gate reading throughput, not shared-runner jitter
    rows = sweep(csv, brokers, best_of=2 if smoke else 1)
    check_acceptance(rows, csv)

    if smoke:
        _gate_against_committed(rows, csv)
        doc = {}
        if os.path.exists(OUT_PATH):
            with open(OUT_PATH) as f:
                doc = json.load(f)
        doc["smoke"] = rows
    else:
        head = next((r for r in rows
                     if r["users"] == 16 and not r["resale"]), None)
        head_on = next((r for r in rows
                        if r["users"] == 16 and r["resale"]), None)
        doc = {
            "bench": "secondary",
            "seed": SEED,
            "n_machines": N_MACHINES,
            "market_kw": dict(MARKET_KW),
            "brokers_axis": list(BROKERS),
            "results": rows,
            "wasted_spend_drop_u16": (
                round(head["wasted_spend"] - head_on["wasted_spend"], 6)
                if head and head_on else None),
        }
        if os.path.exists(OUT_PATH):
            with open(OUT_PATH) as f:
                doc["smoke"] = json.load(f).get("smoke", [])
    with open(OUT_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    if not csv:
        print(f"wrote {OUT_PATH}")

    results = [(point_key(r["resale"], r["users"]), r["wall_s"] * 1e6,
                r["wasted_spend"]) for r in rows]
    return results + determinism_check(csv)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
