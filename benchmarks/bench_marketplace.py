"""Grid-marketplace sweep: the economy under competition.

N ∈ {1, 2, 4, 8, 16} brokers (cost/time/conservative mix) share one
GUSTO-like testbed on one virtual clock.  Reports per-user deadline-met
and spend stats, market-wide slot-race pressure, and the demand-priced
mean quote — then re-runs the largest market with the same seed and
verifies the result is byte-identical (deterministic economy).

    PYTHONPATH=src python -m benchmarks.bench_marketplace
"""
from __future__ import annotations

import time

from repro.core import standard_market

HOUR = 3600.0

SWEEP = (1, 2, 4, 8, 16)
SEED = 11
N_MACHINES = 16
N_JOBS = 24


def _run(n_users: int, seed: int = SEED):
    market = standard_market(n_users, n_machines=N_MACHINES, seed=seed,
                             n_jobs=N_JOBS, demand_elasticity=1.0)
    return market, market.run()


def sweep_table(csv: bool = False, rows: list = None):
    rows = [] if rows is None else rows
    for n in SWEEP:
        t0 = time.time()
        market, rep = _run(n)
        wall = time.time() - t0
        peak_quote = max(p for _, p in rep.price_trace)
        rows.append((n, rep, wall, peak_quote))
    if not csv:
        print("users  done/jobs  met%   spend_G$  races_lost  "
              "peak_quote  wall_s")
        for n, rep, wall, pq in rows:
            print(f"{n:5d} {rep.total_done:5d}/{rep.total_jobs:<5d} "
                  f"{rep.deadline_met_frac:5.0%} {rep.total_spent:9.1f} "
                  f"{rep.slot_races_lost:11d} {pq:11.3f} {wall:7.2f}")
        print("\nper-user stats, most contended market "
              f"(N={SWEEP[-1]}):")
        print(rows[-1][1].summary())
    return [(f"market_{n}u", wall * 1e6, rep.slot_races_lost)
            for n, rep, wall, _ in rows]


def determinism_check(csv: bool = False, rep1=None):
    t0 = time.time()
    if rep1 is None:
        _, rep1 = _run(SWEEP[-1])
    _, rep2 = _run(SWEEP[-1])
    wall = time.time() - t0
    identical = rep1.stable_repr() == rep2.stable_repr()
    if not csv:
        print(f"\nsame-seed re-run byte-identical: {identical}")
    if not identical:
        raise AssertionError("marketplace run is not seed-deterministic")
    return [("market_determinism", wall * 1e6, int(identical))]


def main(csv: bool = False):
    rows: list = []
    out = sweep_table(csv, rows=rows)
    # reuse the N=16 sweep report: the re-run must match it byte-for-byte
    return out + determinism_check(csv, rep1=rows[-1][1])


if __name__ == "__main__":
    main()
