import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver: hypothesis -> change -> re-lower -> record.

Each experiment = (cell, sequence of config overrides).  Every variant is
lowered+compiled on the production mesh and its roofline terms recorded to
benchmarks/results/perf_iterations.jsonl.

    PYTHONPATH=src python -m benchmarks.perf_hillclimb [--cell A|B|C]
"""
import argparse
import json
import time

from repro.configs import get_config, get_shape
from repro.launch.dryrun import run_cell

LOG = "benchmarks/results/perf_iterations.jsonl"

# (name, arch, shape, [(variant, hypothesis, {overrides})...])
EXPERIMENTS = {
    "A": ("llava-next-34b", "train_4k", [
        ("baseline", "paper-faithful generic TP/FSDP; 56 heads % 16 != 0 "
         "forces attention replication over the model axis", {}),
        ("A1_seq_shard", "context parallelism (seq over model) removes the "
         "16x replicated attention/MLP compute; predict compute ~/10, "
         "memory ~/8", {"seq_shard": True}),
        ("A2_+bf16_params", "cast fp32 masters to bf16 pre-forward: FSDP "
         "all-gather + weight-read bytes halve; predict collective ~-40%, "
         "memory -20%", {"seq_shard": True, "cast_params_bf16": True}),
        ("A3_+chunked_ce", "never materialize (B,S,V) logits; predict "
         "memory term -10-20% more", {"seq_shard": True,
                                      "cast_params_bf16": True,
                                      "chunked_ce": True}),
    ]),
    "B": ("kimi-k2-1t-a32b", "decode_32k", [
        ("baseline", "weight-gathered EP: every decode step all-gathers "
         "E_loc*d*f expert weights over 'data' per layer -> collective-"
         "bound", {}),
        ("B1_ep_a2a", "token-routed EP (all-to-all over 'data', expert-FFN "
         "over 'model'): tokens move (k*d B each) instead of 2.1GB/layer "
         "weights; predict collective 7.8s -> <0.5s", {"moe_impl": "ep_a2a"}),
        ("B2_+chunked_ce", "decode computes full-vocab logits for 128 rows; "
         "chunking is free insurance (minor)", {"moe_impl": "ep_a2a",
                                                "chunked_ce": False,
                                                "cast_params_bf16": False,
                                                "seq_shard": False}),
    ]),
    "C": ("gemma3-27b", "train_4k", [
        ("baseline", "paper-faithful: fp32 masters gathered per layer; full "
         "remat; monolithic CE", {}),
        ("C1_bf16_params", "bf16 compute params: gather/read bytes halve; "
         "predict collective 16.1s -> ~8.5s, memory -20%",
         {"cast_params_bf16": True}),
        ("C2_+chunked_ce", "chunked CE removes the 4.3GB fp32 logits "
         "region (several passes); predict memory -10%",
         {"cast_params_bf16": True, "chunked_ce": True}),
        ("C3_+remat_dots", "save batch-free dots instead of full remat: "
         "fewer recomputed matmuls; predict compute -20%, memory may rise",
         {"cast_params_bf16": True, "chunked_ce": True, "remat": "dots"}),
        ("C4_bf16_masters", "C1 failed because XLA gathers f32 then casts; "
         "store masters in bf16 (fp32 Adam moments retain update "
         "precision): gathers+reads halve BY CONSTRUCTION; predict "
         "memory -25%, collective -40%", {"param_dtype": "bfloat16"}),
        ("C5_+seq_shard", "gemma3-27b heads=32 shard fine, but seq-sharding "
         "may still cut activation traffic on top of C4",
         {"param_dtype": "bfloat16", "seq_shard": True}),
        ("C6_+remat_dots", "with memory no longer dominant (C5), trade the "
         "full-remat recompute for saved dots: predict compute -20%, "
         "collective unchanged, net win if memory stays under collective",
         {"param_dtype": "bfloat16", "seq_shard": True, "remat": "dots"}),
    ]),
}


def run_experiment(key: str):
    arch, shape_name, variants = EXPERIMENTS[key]
    shape = get_shape(shape_name)
    print(f"\n======== cell {key}: {arch} x {shape_name} ========")
    rows = []
    for name, hypothesis, overrides in variants:
        cfg = get_config(arch)
        if overrides:
            cfg = cfg.replace(**overrides)
        t0 = time.time()
        row = run_cell(arch, shape, multi_pod=False, verbose=False,
                       cfg_override=cfg)
        row.update({"experiment": key, "variant": name,
                    "hypothesis": hypothesis, "overrides": overrides,
                    "wall_s": round(time.time() - t0, 1)})
        rows.append(row)
        dom = max(row["t_compute_s"], row["t_memory_s"],
                  row["t_collective_s"])
        print(f"{name:18s} compute={row['t_compute_s']:8.3f}s "
              f"memory={row['t_memory_s']:8.3f}s "
              f"collective={row['t_collective_s']:8.3f}s "
              f"bottleneck={row['bottleneck']:10s} "
              f"MFU_ub={row['mfu_upper_bound']:6.2%} step_lb={dom:8.3f}s",
              flush=True)
        with open(LOG, "a") as f:
            f.write(json.dumps(row) + "\n")
    base = max(rows[0]["t_compute_s"], rows[0]["t_memory_s"],
               rows[0]["t_collective_s"])
    best = min(max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
               for r in rows)
    print(f"cell {key}: step-time lower bound {base:.3f}s -> {best:.3f}s "
          f"({base / best:.2f}x)")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=list(EXPERIMENTS))
    args = ap.parse_args()
    os.makedirs("benchmarks/results", exist_ok=True)
    keys = [args.cell] if args.cell else list(EXPERIMENTS)
    for k in keys:
        run_experiment(k)


if __name__ == "__main__":
    main()
