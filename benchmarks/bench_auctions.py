"""Auction-vs-posted-price market sweep: what negotiation buys.

For N ∈ {2, 4, 8, 16} brokers on the same seeded GUSTO-like testbed,
runs the market twice — once all posted-price (cost/time/conservative
mix) and once with auction brokers in the mix (double-auction contracts
via the per-site trade servers) — and compares spend, deadlines met and
contract volume.  Re-runs the largest mixed market with the same seed
and asserts byte-identical results, then writes the whole table to
``BENCH_auctions.json`` at the repo root (the perf trajectory file).

    PYTHONPATH=src python -m benchmarks.bench_auctions            # full
    PYTHONPATH=src python -m benchmarks.bench_auctions --smoke    # CI
"""
from __future__ import annotations

import json
import os
import sys
import time

from repro.core import mixed_auction_market, standard_market

HOUR = 3600.0

SWEEP = (2, 4, 8, 16)
SMOKE_SWEEP = (2,)
SEED = 23
N_MACHINES = 16
N_JOBS = 20

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_auctions.json")


def _run(kind: str, n_users: int, seed: int = SEED):
    maker = standard_market if kind == "posted" else mixed_auction_market
    market = maker(n_users, n_machines=N_MACHINES, seed=seed,
                   n_jobs=N_JOBS, demand_elasticity=1.0)
    t0 = time.time()
    rep = market.run()
    wall = time.time() - t0
    market.bank.reconcile({u.name: e.ledger for u, e in
                           zip(market.users, market.engines)})
    return market, rep, wall


def _row(kind: str, rep, wall: float) -> dict:
    return {
        "kind": kind,
        "n_users": rep.n_users,
        "done": rep.total_done,
        "jobs": rep.total_jobs,
        "deadline_met_frac": rep.deadline_met_frac,
        "total_spent_gd": rep.total_spent,
        "slot_races_lost": rep.slot_races_lost,
        "contracts": rep.contracts_struck,
        "owner_revenue": rep.owner_revenue,
        "wall_s": wall,
    }


def sweep_table(csv: bool = False, sweep=SWEEP):
    rows = []
    for n in sweep:
        _, posted, wall_p = _run("posted", n)
        market, mixed, wall_m = _run("auction", n)
        rows.append((n, _row("posted", posted, wall_p),
                     _row("auction", mixed, wall_m), market))
    if not csv:
        print("users  kind     done/jobs  met%   spend_G$  contracts  wall_s")
        for n, p, a, _ in rows:
            for r in (p, a):
                print(f"{n:5d}  {r['kind']:7s} {r['done']:5d}/{r['jobs']:<5d}"
                      f" {r['deadline_met_frac']:5.0%} "
                      f"{r['total_spent_gd']:9.1f} {r['contracts']:9d} "
                      f"{r['wall_s']:7.2f}")
        last = rows[-1]
        if last[1]["total_spent_gd"] > 0:
            save = 1 - last[2]["total_spent_gd"] / last[1]["total_spent_gd"]
            print(f"\nN={last[0]}: auction mix saves {save:.1%} of the "
                  f"posted-price spend "
                  f"({last[2]['contracts']} contracts struck)")
    return rows


def determinism_check(csv: bool, n: int):
    t0 = time.time()
    _, r1, _ = _run("auction", n)
    _, r2, _ = _run("auction", n)
    wall = time.time() - t0
    identical = r1.stable_repr() == r2.stable_repr()
    if not csv:
        print(f"same-seed auction-market re-run byte-identical: {identical}")
    if not identical:
        raise AssertionError("auction market run is not seed-deterministic")
    return [("auction_determinism", wall * 1e6, int(identical))]


def main(csv: bool = False, smoke: bool = False):
    sweep = SMOKE_SWEEP if smoke else SWEEP
    rows = sweep_table(csv, sweep=sweep)
    out = {
        "bench": "auctions",
        "seed": SEED,
        "n_machines": N_MACHINES,
        "n_jobs_per_user": N_JOBS,
        "sweep": [{"n_users": n, "posted": p, "auction": a}
                  for n, p, a, _ in rows],
    }
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    if not csv:
        print(f"wrote {OUT_PATH}")
    results = []
    for n, p, a, _ in rows:
        results.append((f"auction_market_{n}u", a["wall_s"] * 1e6,
                        a["contracts"]))
    return results + determinism_check(csv, sweep[-1])


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
