"""Adversarial strategy tournament: every registered strategy, one
shared market, four scenario regimes.

The strategy zoo (``repro.core.strategies``) claims each policy earns
its keep somewhere in the economy.  This bench makes the claim
measurable: one broker per registered strategy — identical deadline,
budget and workload, so the *policy* is the only difference — all
competing in the SAME market for the same machines, across four
regimes:

* **posted**  — plain posted-price grid (the PR-1 economy);
* **auction** — frequent double-auction clearing rounds + contract-net
  (negotiating strategies can undercut the price board);
* **churn**   — sites leave/rejoin under a stale-TTL GIS with machine
  failures (reputation has something to observe);
* **resale**  — secondary market with commitment fees and price
  discovery (scavengers have listings to drain).

Each (scenario, strategy) cell reports deadline-hit and G$/job; the
aggregate table ranks strategies by hit rate then cost.  ``GridBank``
must reconcile exactly against every broker ledger in every scenario —
a strategy that breaks the books fails the bench, and CI.

    PYTHONPATH=src python -m benchmarks.bench_tournament           # full
    PYTHONPATH=src python -m benchmarks.bench_tournament --smoke   # CI

Results land in ``BENCH_tournament.json``.  Smoke mode shrinks the
workload, re-checks same-seed determinism and rewrites the committed
JSON's ``smoke`` section.
"""
from __future__ import annotations

import json
import os
import sys
import time

from repro.core import (MarketUser, Marketplace, available_strategies)

HOUR = 3600.0

SEED = 17
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_tournament.json")

#: per-broker workload: identical for every strategy — fairness is the
#: whole point of the tournament
FULL = dict(n_machines=16, n_jobs=24, deadline_h=14.0, budget=9_000.0,
            est_seconds=1800.0)
SMOKE = dict(n_machines=10, n_jobs=8, deadline_h=14.0, budget=3_000.0,
             est_seconds=1800.0)

#: the four regimes: (market kwargs, run kwargs, machine-pool scale).
#: resale runs on a scarcer grid — contention is what makes brokers
#: shed contracted windows and rivals drain the listings
SCENARIOS = {
    "posted": (dict(), dict(), 1.0),
    "auction": (dict(auction_round=1800.0, auction_window=4 * HOUR),
                dict(), 1.0),
    "churn": (dict(gis_ttl=900.0, churn_mean_uptime_h=4.0,
                   churn_mean_downtime_h=1.0),
              dict(churn=True, failures=True), 1.0),
    "resale": (dict(release_fee=0.25, resale=True, ask_fraction=0.15,
                    discovery_gain=0.2, auction_round=1800.0,
                    auction_window=4 * HOUR),
               dict(), 0.75),
}


def build_market(scenario: str, size: dict, seed: int = SEED
                 ) -> Marketplace:
    """One broker per registered strategy, same deadline/budget/jobs,
    one shared grid.  Broker name == strategy name, so reports read as
    a leaderboard."""
    market_kw, _, machines_frac = SCENARIOS[scenario]
    n_machines = max(6, int(size["n_machines"] * machines_frac))
    market = Marketplace(n_machines=n_machines, seed=seed, **market_kw)
    for strat in available_strategies():
        market.add_user(MarketUser(
            name=strat, deadline=size["deadline_h"] * HOUR,
            budget=size["budget"], strategy=strat,
            n_jobs=size["n_jobs"], est_seconds=size["est_seconds"]))
    return market


def run_scenario(scenario: str, size: dict) -> dict:
    _, run_kw, _ = SCENARIOS[scenario]
    market = build_market(scenario, size)
    t0 = time.time()
    rep = market.run(**run_kw)
    wall = time.time() - t0
    # the acceptance criterion CI enforces: NO strategy may break the
    # double-entry books, in ANY regime
    ledgers = {u.name: e.ledger for u, e in zip(market.users,
                                                market.engines)}
    market.bank.reconcile(ledgers)
    rows = []
    for out in rep.outcomes:
        rows.append({
            "strategy": out.user,
            "jobs": out.n_jobs, "done": out.n_done,
            "met_deadline": bool(out.met_deadline),
            "within_budget": bool(out.within_budget),
            "spent": round(out.spent, 6),
            "gdollar_per_job": (round(out.spent / out.n_done, 6)
                                if out.n_done else None),
            "completion_h": (round(out.completion_time / HOUR, 4)
                             if out.completion_time != float("inf")
                             else None),
            "contracts": out.contracts_won,
            "requeues": out.requeues,
            "burned": out.resource_losses,
        })
    return {
        "scenario": scenario, "wall_s": round(wall, 3),
        "events": market.sim.events, "rows": rows,
        "resales": rep.resales, "contracts": rep.contracts_struck,
        "churn_events": len(rep.churn_trace),
    }


def aggregate(scenarios: list) -> dict:
    """Cross-scenario leaderboard: deadline-hit rate, then G$/job."""
    per = {}
    for sc in scenarios:
        for row in sc["rows"]:
            s = per.setdefault(row["strategy"],
                               dict(met=0, runs=0, spent=0.0, done=0,
                                    jobs=0))
            s["runs"] += 1
            s["met"] += int(row["met_deadline"])
            s["spent"] += row["spent"]
            s["done"] += row["done"]
            s["jobs"] += row["jobs"]
    out = {}
    for name, s in sorted(per.items()):
        out[name] = {
            "scenarios": s["runs"],
            "deadline_hit_rate": round(s["met"] / max(s["runs"], 1), 4),
            "gdollar_per_job": (round(s["spent"] / s["done"], 6)
                                if s["done"] else None),
            "done": s["done"], "jobs": s["jobs"],
            "spent": round(s["spent"], 6),
        }
    return out


def check_acceptance(scenarios: list, agg: dict, csv: bool) -> None:
    names = available_strategies()
    assert len(names) >= 6, f"registry too small: {names}"
    for sc in scenarios:
        got = sorted(r["strategy"] for r in sc["rows"])
        assert got == names, (sc["scenario"], got)
        for r in sc["rows"]:
            assert r["gdollar_per_job"] is None or r["gdollar_per_job"] >= 0
    # the regimes must actually exercise their machinery
    by_name = {sc["scenario"]: sc for sc in scenarios}
    if "auction" in by_name:
        assert by_name["auction"]["contracts"] > 0, "no contracts struck"
    if "churn" in by_name:
        assert by_name["churn"]["churn_events"] > 0, "membership never churned"
    if "resale" in by_name:
        assert by_name["resale"]["resales"] > 0, "no resale fills"
    if not csv:
        print("\nstrategy       hit-rate   G$/job      done/jobs   spent")
        ranked = sorted(agg.items(),
                        key=lambda kv: (-kv[1]["deadline_hit_rate"],
                                        kv[1]["gdollar_per_job"] or 0.0))
        for name, s in ranked:
            cpj = (f"{s['gdollar_per_job']:8.2f}"
                   if s["gdollar_per_job"] is not None else "     n/a")
            print(f"{name:14s} {s['deadline_hit_rate']:7.2f} {cpj}   "
                  f"{s['done']:5d}/{s['jobs']:<5d} {s['spent']:10.2f}")


def determinism_check(size: dict, csv: bool):
    t0 = time.time()
    r1 = build_market("resale", size).run()
    r2 = build_market("resale", size).run()
    wall = time.time() - t0
    identical = r1.stable_repr() == r2.stable_repr()
    if not csv:
        print(f"same-seed tournament re-run byte-identical: {identical}")
    if not identical:
        raise AssertionError("tournament run is not seed-deterministic")
    return [("tournament_determinism", wall * 1e6, int(identical))]


def main(csv: bool = False, smoke: bool = False):
    size = SMOKE if smoke else FULL
    scenarios = []
    if not csv:
        print(f"tournament: {len(available_strategies())} strategies x "
              f"{len(SCENARIOS)} scenarios, "
              f"{size['n_jobs']} jobs each on {size['n_machines']} machines")
    for name in SCENARIOS:
        sc = run_scenario(name, size)
        scenarios.append(sc)
        if not csv:
            met = sum(r["met_deadline"] for r in sc["rows"])
            print(f"  {name:8s} wall={sc['wall_s']:6.2f}s "
                  f"met={met}/{len(sc['rows'])} "
                  f"contracts={sc['contracts']} resales={sc['resales']} "
                  f"— books reconcile")
    agg = aggregate(scenarios)
    check_acceptance(scenarios, agg, csv)

    if smoke:
        doc = {}
        if os.path.exists(OUT_PATH):
            with open(OUT_PATH) as f:
                doc = json.load(f)
        doc["smoke"] = {"size": dict(size), "scenarios": scenarios,
                        "per_strategy": agg}
    else:
        doc = {
            "bench": "tournament",
            "seed": SEED,
            "size": dict(size),
            "strategies": available_strategies(),
            "scenarios": scenarios,
            "per_strategy": agg,
        }
        if os.path.exists(OUT_PATH):
            with open(OUT_PATH) as f:
                doc["smoke"] = json.load(f).get("smoke", {})
    with open(OUT_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    if not csv:
        print(f"wrote {OUT_PATH}")

    results = [(f"tournament_{sc['scenario']}", sc["wall_s"] * 1e6,
                sum(r["met_deadline"] for r in sc["rows"]))
               for sc in scenarios]
    return results + determinism_check(size, csv)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
