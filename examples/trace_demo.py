"""Observability demo: trace a whole market run, open it in Perfetto.

One contended posted-price market runs with a ``Tracer`` attached —
every job's lifecycle (dispatch attempts, settlements, requeues) lands
as async spans on its broker's track, every subsystem (GIS, bank,
auctions, churn) emits typed instants, and the metrics registry samples
the market on the watch cadence.  The run then exports:

* a Chrome trace-event JSON — drag it into https://ui.perfetto.dev (or
  chrome://tracing): one track per broker and per site, timestamps in
  sim time, the metrics snapshot in ``otherData``;
* a deterministic JSONL event log — same seed, same bytes, diffable.

    PYTHONPATH=src python examples/trace_demo.py --trace out.json
"""
import argparse
import collections

from repro.core import (Tracer, export_chrome_trace, export_jsonl,
                        standard_market)

HOUR = 3600.0


def main():
    ap = argparse.ArgumentParser(
        description="trace a market run, export for Perfetto")
    ap.add_argument("--trace", metavar="OUT_JSON", default="out.json",
                    help="Chrome trace output path (default: out.json)")
    ap.add_argument("--jsonl", metavar="OUT_JSONL", default=None,
                    help="also export the raw JSONL event log here")
    args = ap.parse_args()

    tracer = Tracer()
    market = standard_market(4, n_machines=8, seed=7, n_jobs=12,
                             demand_elasticity=1.0, tracer=tracer)
    report = market.run()
    print(report.summary())

    events = tracer.events()
    by_cat = collections.Counter(e.cat for e in events)
    spans = sum(1 for e in events if e.ph == "b")
    print(f"\ntrace: {len(events)} events, {spans} spans, "
          f"{tracer.n_dropped()} dropped")
    print("  " + "  ".join(f"{c}={n}" for c, n in sorted(by_cat.items())))

    snap = tracer.metrics.snapshot()
    print(f"\nmetrics registry ({len(snap)} instruments):")
    print(f"  bank.total_spend_gd    {snap['bank.total_spend_gd']:.2f}")
    print(f"  bank.total_revenue_gd  {snap['bank.total_revenue_gd']:.2f}")
    att = snap["broker.attempts_per_job"]
    print(f"  broker.attempts_per_job mean {att['mean']:.2f} "
          f"(n={att['count']})")
    slack = snap["market.deadline_slack_h"]
    print(f"  market.deadline_slack_h mean {slack['mean']:.2f}h "
          f"min {slack['min']:.2f}h")
    print(f"  market.events_per_sec  {snap['market.events_per_sec']:.0f}")

    # books must balance before anything is exported as truth
    total = market.bank.reconcile(
        {u.name: e.ledger for u, e in zip(market.users, market.engines)})
    print(f"\nGridBank reconciles: {total:.2f} G$ spent == earned")

    export_chrome_trace(tracer, args.trace, run_name="trace_demo")
    print(f"wrote {args.trace} — open it at https://ui.perfetto.dev")
    if args.jsonl:
        export_jsonl(tracer, args.jsonl)
        print(f"wrote {args.jsonl} (deterministic JSONL event log)")


if __name__ == "__main__":
    main()
