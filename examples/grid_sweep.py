"""Parametric sweep of REAL training jobs over the local grid.

This is the paper's whole loop, end to end, with genuine JAX payloads:
the plan expands to (arch x lr) training jobs; the Nimrod/G engine
schedules them across "machines" (thread-pool workers with different
slot counts), journals progress, enforces the budget, and collects real
losses back through the dispatcher.

    PYTHONPATH=src python examples/grid_sweep.py
"""
import os
import tempfile

from repro.core import (Dispatcher, Journal, JobSpec, LocalExecutor, NimrodG,
                        PriceSchedule, ResourceDirectory, ResourceSpec,
                        SchedulerConfig, TradeServer, UserRequirements,
                        parse_plan, substitute)
from repro.launch.train import run_training

PLAN = parse_plan("""
parameter arch text select anyof "stablelm-1.6b" "gemma3-1b" "rwkv6-3b"
parameter lr float select anyof 0.003 0.001
task main
    execute train --arch $arch --lr $lr
endtask
""")


def make_payload(point):
    def run():
        r = run_training(point["arch"], smoke=True, steps=6, batch=2,
                         seq=32, lr=point["lr"], verbose=False)
        return {"arch": point["arch"], "lr": point["lr"],
                "final_loss": r.final_loss}
    return run


def main():
    directory = ResourceDirectory()
    directory.register(ResourceSpec(name="workstation-a", site="local",
                                    chips=1, slots=2, base_price=1.0,
                                    mtbf_hours=float("inf")))
    directory.register(ResourceSpec(name="workstation-b", site="local",
                                    chips=1, slots=1, base_price=0.5,
                                    mtbf_hours=float("inf")))
    schedules = {n: PriceSchedule(directory.spec(n))
                 for n in directory.all_names()}
    trade = TradeServer(directory, schedules)
    executor = LocalExecutor(directory, max_workers=3)
    disp = Dispatcher(executor, directory)

    jobs = []
    for i, point in enumerate(PLAN.points()):
        steps = tuple(substitute(s, point, f"j{i:05d}") for s in PLAN.task)
        jobs.append(JobSpec(job_id=f"j{i:05d}", experiment="local-sweep",
                            point=point, steps=steps,
                            est_seconds_base=30.0,
                            payload=make_payload(point)))

    journal_path = os.path.join(tempfile.mkdtemp(), "journal.jsonl")
    req = UserRequirements(deadline=1e9, budget=1e9, strategy="time")
    eng = NimrodG("local-sweep", jobs, req, directory, trade, disp,
                  sim=None, journal=Journal(journal_path),
                  sched_cfg=SchedulerConfig(interval=0.2))
    report = eng.run_local(wall_timeout=1800.0)
    executor.shutdown()

    print(report.summary())
    print(f"journal: {journal_path}")
    results = sorted((j.result for j in eng.jobs.values() if j.result),
                     key=lambda r: r["final_loss"])
    print("\nsweep results (sorted by loss):")
    for r in results:
        print(f"  {r['arch']:16s} lr={r['lr']:<7g} loss={r['final_loss']:.4f}")
    assert report.n_done == len(jobs)
    print(f"\nbest point: {results[0]['arch']} @ lr={results[0]['lr']}")


if __name__ == "__main__":
    main()
