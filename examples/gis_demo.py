"""Grid Information Service demo: discovery under churn and stale views.

Six brokers share a twelve-machine grid, but nobody reads the directory
directly anymore — discovery runs through the hierarchical GIS
(department -> enterprise -> global), liveness is heartbeat-based, and
each broker plans against a cached snapshot with a 15-minute TTL.
Meanwhile whole administrative domains leave and rejoin mid-run: jobs
in flight on a departing site fail over (no attempt burned), voided
contracts are refunded through the bank, and stale views keep sending
work at corpses until a burned dispatch or a refresh teaches better.

    PYTHONPATH=src python examples/gis_demo.py [--trace out.json]
"""
import argparse

from repro.core import Tracer, export_chrome_trace, mixed_auction_market

HOUR = 3600.0


def main():
    ap = argparse.ArgumentParser(description="GIS churn demo")
    ap.add_argument("--trace", metavar="OUT_JSON", default=None,
                    help="export a Perfetto-loadable Chrome trace here")
    args = ap.parse_args()
    tracer = Tracer() if args.trace else None

    market = mixed_auction_market(6, n_machines=12, seed=17, n_jobs=15,
                             demand_elasticity=1.0,
                             gis_ttl=900.0,             # 15-min stale views
                             heartbeat_interval=300.0,  # 5-min beats
                             churn_mean_uptime_h=4.0,
                             churn_mean_downtime_h=1.5,
                             tracer=tracer)
    gis = market.gis
    print("GIS hierarchy (enterprise -> departments):")
    for site, depts in gis.levels().items():
        names = [e.name for e in gis.query(0.0, level="enterprise",
                                           within=site)]
        print(f"  {site:8s} {depts}  ({len(names)} resources)")

    report = market.run(churn=True)
    print()
    print(report.summary())

    print(f"\ninformation layer: {gis.heartbeats} heartbeats, "
          f"{report.gis_refreshes} broker snapshot refreshes, "
          f"{gis.registrations} registrations / "
          f"{gis.deregistrations} deregistrations")
    for t, kind, site in report.churn_trace[:6]:
        print(f"  t={t / HOUR:6.2f}h  {kind:5s} {site}")
    if len(report.churn_trace) > 6:
        print(f"  ... {len(report.churn_trace) - 6} more membership events")

    total = market.bank.reconcile({u.name: e.ledger for u, e in
                                   zip(market.users, market.engines)})
    print(f"\nbank reconciles exactly: {total:.2f}G$ moved, "
          f"{report.refunds:.2f}G$ refunded for broken contracts")
    assert report.total_done == report.total_jobs or any(
        o.stall_reason or not o.met_deadline for o in report.outcomes)
    if tracer is not None:
        export_chrome_trace(tracer, args.trace, run_name="gis_demo")
        print(f"wrote {args.trace} ({tracer.n_events()} trace events, "
              f"churn on the site tracks) — open at "
              f"https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
