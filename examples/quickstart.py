"""Quickstart: the Nimrod/G economy scheduler in 60 lines.

Builds a small grid, writes a parametric plan, runs the same experiment
under the three DBC strategies, and prints the paper's core trade-off.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (Dispatcher, NimrodG, PriceSchedule,
                        ResourceDirectory, SimulatedExecutor, Simulator,
                        TradeServer, UserRequirements, gusto_like_testbed,
                        negotiate_contract, parse_plan)

HOUR = 3600.0

# 1. a declarative parametric plan (the Nimrod plan language)
PLAN = parse_plan("""
parameter temperature float range from 300 to 340 step 2
parameter pressure    float select anyof 1.0 2.5 5.0
task main
    copy reactor.model node:.
    execute simulate --T $temperature --P $pressure
    copy node:trace.out results/$jobname.out
endtask
""")
print(f"plan expands to {PLAN.n_jobs()} jobs "
      f"({[p.name for p in PLAN.parameters]})")

# 2. a grid: heterogeneous, priced, multi-domain, failure-prone
directory = ResourceDirectory()
for spec in gusto_like_testbed(30, seed=7):
    directory.register(spec)
schedules = {n: PriceSchedule(directory.spec(n))
             for n in directory.all_names()}
trade = TradeServer(directory, schedules)

# 3. run the experiment under each strategy
for strategy in ("cost", "time", "conservative"):
    sim = Simulator()
    executor = SimulatedExecutor(sim, directory, seed=0)
    req = UserRequirements(deadline=8 * HOUR, budget=5000.0,
                           strategy=strategy)
    eng = NimrodG.from_plan("reactor-study", PLAN, req, directory, trade,
                            Dispatcher(executor, directory),
                            est_seconds=lambda p: 1500.0, sim=sim)
    report = eng.run_simulated()
    print(report.summary())

# 4. contract mode: "this is what I'm willing to pay — can you do it?"
sim = Simulator()
executor = SimulatedExecutor(sim, directory, seed=0)
req = UserRequirements(deadline=8 * HOUR, budget=5000.0)
eng = NimrodG.from_plan("reactor-study", PLAN, req, directory, trade,
                        Dispatcher(executor, directory),
                        est_seconds=lambda p: 1500.0, sim=sim)
eng._refresh_views()
quote = negotiate_contract(0.0, req, PLAN.n_jobs(), trade, eng.views)
print(f"contract quote: feasible={quote.feasible} "
      f"est_cost={quote.est_cost:.1f}G$ "
      f"est_completion={quote.est_completion / HOUR:.2f}h "
      f"using {quote.n_resources} resources")
