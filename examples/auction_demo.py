"""Negotiated-economy demo: auctions, tenders, arbitrage, owner revenue.

Three acts, all on one seeded virtual clock:

1. a mixed market — auction brokers (double-auction contracts through
   the per-site trade servers) compete head-to-head with posted-price
   brokers for the same machines;
2. a contract-net negotiation — call for tenders, counter-offers from
   every domain, accept-within-validity (and what happens if you wait
   too long);
3. the GridBank's owner revenue statement — every grid-dollar spent by
   a broker reconciles to a grid-dollar earned by a domain.

    PYTHONPATH=src python examples/auction_demo.py [--trace out.json]
"""
import argparse

from repro.core import (NegotiationTimeout, Tracer, export_chrome_trace,
                        mixed_auction_market)

HOUR = 3600.0


def main():
    ap = argparse.ArgumentParser(description="negotiated-economy demo")
    ap.add_argument("--trace", metavar="OUT_JSON", default=None,
                    help="export a Perfetto-loadable Chrome trace here")
    args = ap.parse_args()
    tracer = Tracer() if args.trace else None

    market = mixed_auction_market(8, n_machines=12, seed=42, n_jobs=16,
                                  demand_elasticity=1.0, tracer=tracer)
    report = market.run()

    print("=== act 1: auction brokers vs the price board ===")
    print(report.summary())
    house = market.auction_house
    rounds = [r for r in house.rounds if r.matched_slots]
    print(f"\nclearing rounds that crossed: {len(rounds)} "
          f"(of {len(house.rounds)}); contracts struck: "
          f"{len(house.contracts)}")
    for c in house.contracts[:5]:
        print(f"  #{c.contract_id} {c.user} <- {c.resource} ({c.site}) "
              f"{c.slots} slot(s) @ {c.chip_hour_price:.3f} G$/chip-h "
              f"[{c.start / HOUR:.0f}h, {c.end / HOUR:.0f}h) via {c.via}")

    print("\n=== act 2: contract-net tender ===")
    t = market.sim.now
    offers = house.call_for_tenders(t, "walk-in")
    best = offers[0]
    print(f"{len(offers)} counter-offers; best: {best.resource} "
          f"({best.site}) @ {best.chip_hour_price:.3f} G$/chip-h, "
          f"valid until t={best.valid_until / HOUR:.2f}h")
    contract = house.accept(best, "walk-in", t + 60.0)
    print(f"accepted inside the window -> contract "
          f"#{contract.contract_id} at the offered price")
    stale = offers[1]
    try:
        house.accept(stale, "walk-in", stale.valid_until + HOUR)
    except NegotiationTimeout as e:
        print(f"late acceptance refused: {e}")

    print("\n=== act 3: owner revenue accounting ===")
    print(market.bank.statement())
    total = market.bank.reconcile(
        {u.name: e.ledger for u, e in zip(market.users, market.engines)})
    print(f"books balance: {total:.2f} G$ spent == {total:.2f} G$ earned")
    if tracer is not None:
        export_chrome_trace(tracer, args.trace, run_name="auction_demo")
        print(f"wrote {args.trace} ({tracer.n_events()} trace events) — "
              f"open at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
