"""Live monitoring demo: watchdogs, health rollups, sim-clock steering.

One churny, failure-prone market runs with the full online
observability stack attached: a ``Tracer`` records every event, and an
``ExperimentMonitor`` subscribes to the live stream — folding it into
per-broker and per-site health while its invariant watchdogs (money
conservation, slot accounting, attempt-span balance) check the books
at every event.  A violation would raise at the sim time it happens,
not at run end.

Steering is scheduled on the *sim clock* before the run starts, so the
steered run is an ordinary deterministic run: at t=0.5h one broker
gets a budget top-up and a tighter deadline, and a whole site is
drained out of the grid (in-flight work fails over, contracts void
with breach rebates).  Every action lands in the trace as a ``steer``
instant — re-run with the same seed and the bytes match.

    PYTHONPATH=src python examples/monitor_demo.py --trace out.json

Exits nonzero if any watchdog fired — CI runs this as the monitor
smoke gate.
"""
import argparse
import sys

from repro.core import (ExperimentMonitor, Tracer, export_chrome_trace,
                        standard_market)

HOUR = 3600.0


def main():
    ap = argparse.ArgumentParser(
        description="monitored + steered market run, watchdogs enabled")
    ap.add_argument("--trace", metavar="OUT_JSON", default="out.json",
                    help="Chrome trace output path (default: out.json)")
    ap.add_argument("--no-steer", action="store_true",
                    help="skip the scheduled steering actions")
    args = ap.parse_args()

    tracer = Tracer()
    market = standard_market(4, n_machines=12, seed=5, n_jobs=10,
                             gis_ttl=900.0, churn_mean_uptime_h=3.0,
                             churn_mean_downtime_h=1.0, tracer=tracer)
    monitor = ExperimentMonitor(market, watchdogs=True,
                                on_violation="record")

    if not args.no_steer:
        # scheduled before run(), applied at virtual time by the DES —
        # the steered run stays same-seed byte-reproducible.  Steer the
        # last broker (the most contended one) early enough that it is
        # still running
        user = market.users[-1].name
        eng = market.engines[-1]
        monitor.steer_broker(user, budget=eng.ledger.budget * 1.5,
                             deadline=eng.req.deadline * 0.75,
                             at=0.5 * HOUR)
        # Monash is up at t=0.5h in this seeded scenario (churn takes
        # other sites down around then — draining the last live site
        # would be vetoed)
        monitor.drain_site("Monash", at=0.5 * HOUR)

    report = market.run(failures=True, churn=True)
    print(report.summary())

    print()
    print(monitor.dashboard())

    if monitor.steering_log:
        print("\n-- steering log --")
        for act in monitor.steering_log:
            print(f"  t={act.t / HOUR:5.1f}h {act.kind:12s} "
                  f"{act.target:10s} {act.detail}")

    export_chrome_trace(tracer, args.trace, run_name="monitor_demo")
    print(f"\nwrote {args.trace} — open it at https://ui.perfetto.dev")

    if monitor.violations:
        print(f"\n{len(monitor.violations)} invariant violation(s):",
              file=sys.stderr)
        for v in monitor.violations:
            print(v, file=sys.stderr)
        return 1
    print(f"\nwatchdogs clean: {monitor.events_seen} events checked, "
          f"0 violations")
    return 0


if __name__ == "__main__":
    sys.exit(main())
