"""End-to-end training driver: train a 100M-class model for a few hundred
steps with the full substrate (data pipeline, AdamW+cosine, sharded
checkpoints, exact restart).

On this CPU container the default invocation uses a reduced width so a
few hundred steps complete in minutes; pass --width-scale 1.0 on real
hardware for the full ~100M-parameter configuration.

    PYTHONPATH=src python examples/train_100m.py --steps 200
"""
import argparse
import os

from repro.configs import ModelConfig
from repro.launch.train import run_training
import repro.configs.registry as registry


def config_100m(width_scale: float = 1.0) -> ModelConfig:
    d = int(768 * width_scale) // 16 * 16
    return ModelConfig(
        name="lm-100m",
        family="dense",
        num_layers=12,
        d_model=d,
        num_heads=max(d // 64, 1),
        num_kv_heads=max(d // 128, 1),
        head_dim=64,
        d_ff=4 * d,
        vocab_size=32_768,
        layer_pattern=("full",),
        mlp="swiglu",
        tie_embeddings=True,
        dtype="float32",
        param_dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--width-scale", type=float, default=0.25,
                    help="1.0 = full ~100M params; 0.25 = CPU-friendly")
    ap.add_argument("--ckpt-dir", default="/tmp/train_100m_ckpt")
    args = ap.parse_args()

    cfg = config_100m(args.width_scale)
    print(f"model: {cfg.param_count() / 1e6:.1f}M params "
          f"(width_scale={args.width_scale})")

    # register the custom config so the generic driver can use it
    registry._ARCH_MODULES = dict(registry._ARCH_MODULES)
    import repro.launch.train as train_mod
    orig_get, orig_smoke = train_mod.get_config, train_mod.smoke_config
    train_mod.get_config = lambda a: cfg
    train_mod.smoke_config = lambda a: cfg
    try:
        r = run_training("lm-100m", smoke=False, steps=args.steps,
                         batch=args.batch, seq=args.seq, lr=3e-4,
                         ckpt_dir=args.ckpt_dir, ckpt_every=50)
    finally:
        train_mod.get_config, train_mod.smoke_config = orig_get, orig_smoke
    print(f"\nfinal loss {r.final_loss:.4f} "
          f"(first {r.losses[0]:.4f}) — {r.tokens_per_sec:,.0f} tok/s")
    assert r.final_loss < r.losses[0], "loss did not decrease"
    print(f"checkpoints in {args.ckpt_dir}; re-run to resume from the last "
          f"one (exact data-position restart).")


if __name__ == "__main__":
    main()
