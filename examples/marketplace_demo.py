"""Multi-user marketplace demo: eight brokers, one contended grid.

The paper's distributed-ownership story in one run — independent
deadline/budget brokers (cost-, time- and conservative-optimizing)
compete for ten machines on a single virtual clock.  Demand-responsive
pricing (GRACE supply-and-demand) makes the crowded grid expensive;
slot races are lost and requeued; every broker settles only against its
own ledger.

    PYTHONPATH=src python examples/marketplace_demo.py [--trace out.json]
"""
import argparse

from repro.core import (Marketplace, MarketUser, Tracer,
                        export_chrome_trace)

HOUR = 3600.0


def main():
    ap = argparse.ArgumentParser(description="contended marketplace demo")
    ap.add_argument("--trace", metavar="OUT_JSON", default=None,
                    help="export a Perfetto-loadable Chrome trace here")
    args = ap.parse_args()
    tracer = Tracer() if args.trace else None

    market = Marketplace(n_machines=10, seed=42,
                         demand_elasticity=1.0,     # busy queues cost more
                         dispatch_latency=1.0,      # WAN hop -> real races
                         tracer=tracer)
    for i, strategy in enumerate(("cost", "time", "conservative") * 3):
        if i >= 8:
            break
        market.add_user(MarketUser(
            name=f"user{i}",
            deadline=(10 + 2 * (i % 3)) * HOUR,
            budget=4_000.0,
            strategy=strategy,
            n_jobs=20,
            est_seconds=1500.0))

    idle_quote = market.mean_quote(0.0)
    report = market.run()

    print(report.summary())
    peak_quote = max(p for _, p in report.price_trace)
    print(f"\nmean grid quote: idle {idle_quote:.3f} G$/chip-h -> "
          f"peak under load {peak_quote:.3f} G$/chip-h "
          f"(demand multiplier {peak_quote / idle_quote:.2f}x)")
    print(f"slot races lost market-wide: {report.slot_races_lost} "
          f"(each requeued, none fatal)")
    assert report.total_done == report.total_jobs
    if tracer is not None:
        export_chrome_trace(tracer, args.trace,
                            run_name="marketplace_demo")
        print(f"wrote {args.trace} ({tracer.n_events()} trace events) — "
              f"open at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
