"""The grid as real processes: one OS process per administrative
domain, brokers negotiating over the wire protocol, and a crash
survived mid-run.

    PYTHONPATH=src python examples/distributed_demo.py

What it shows, end to end:

1. spawn one domain process per site (trade server + GIS branch each,
   journaling every mutation);
2. discover through the merged remote GIS, build scheduler views from
   the snapshot, and negotiate a contract with ``negotiate_contract`` —
   the SAME function the in-process simulations call;
3. settle part of the work, then SIGKILL one domain;
4. restart it on its journal and show the books reconcile exactly:
   every reservation is back, the retried settlement is flagged as a
   duplicate, and the domain's revenue rows match the broker's record.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (GISClient, UserRequirements, gusto_like_testbed,
                        negotiate_contract, spawn_domains, views_from_gis)
from repro.core.transport import DomainConfig

HOUR = 3600.0


def main() -> None:
    # -- 1. one process per administrative domain ------------------------
    by_site = {}
    for spec in gusto_like_testbed(10, seed=0):
        by_site.setdefault(spec.site, []).append(spec)
    journal_dir = tempfile.mkdtemp(prefix="grid-domains-")
    configs = [DomainConfig(
        site=site, specs=tuple(specs),
        journal_path=os.path.join(journal_dir, f"{site}.jsonl"))
        for site, specs in sorted(by_site.items())]
    procs, fed, gis = spawn_domains(configs)
    print(f"spawned {len(procs)} domain processes: "
          f"{', '.join(fed.sites())}")

    try:
        # -- 2. discover + negotiate over the wire -----------------------
        client = GISClient(gis, "alice", ttl=600.0)
        snapshot = client.view(0.0)
        print(f"GIS snapshot: {len(snapshot.entries)} resources "
              f"across {len({e.spec.site for e in snapshot.entries.values()})} sites")
        views = views_from_gis(snapshot, est_seconds_base=1800.0)
        req = UserRequirements(deadline=12 * HOUR, budget=5_000.0,
                               strategy="cost", user="alice")
        quote = negotiate_contract(0.0, req, 12, fed, views, accept=True)
        print(f"contract: feasible={quote.feasible} "
              f"est_cost={quote.est_cost:.1f}G$ "
              f"reservations={list(quote.reserved)}")

        # -- 3. settle, then pull the plug on a domain --------------------
        rows = []
        for i, rid in enumerate(quote.reserved):
            r = fed.find_reservation(rid)
            site = fed.directory.spec(r.resource).site
            sid = f"alice:{rid}"
            fed.servers[site].settle(sid, t=HOUR, user="alice",
                                     resource=r.resource,
                                     amount=round(r.locked_price, 6))
            rows.append((site, sid))
        victim = rows[0][0]
        print(f"settled {len(rows)} contracts; SIGKILL domain {victim!r}")
        procs[victim].kill()

        # -- 4. restart on the journal: exact recovery --------------------
        procs[victim].restart()
        alive = all(fed.find_reservation(rid) is not None
                    for rid in quote.reserved)
        dup = fed.servers[rows[0][0]].settle(
            rows[0][1], t=HOUR, user="alice",
            resource=fed.find_reservation(quote.reserved[0]).resource,
            amount=1.0)
        print(f"after restart: reservations intact={alive}, "
              f"retried settlement flagged duplicate={dup.duplicate}")
        total_rows = sum(len(fed.servers[s].revenue_rows())
                         for s in fed.sites())
        print(f"domain ledgers hold {total_rows} settlement rows "
              f"(= {len(rows)} booked once each)")
    finally:
        for p in procs.values():
            p.stop()
    print("all domains stopped cleanly")


if __name__ == "__main__":
    main()
