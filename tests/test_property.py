"""Hypothesis property tests on system invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import MarketUser, Marketplace, available_strategies
from repro.core.economy import BudgetLedger, PriceSchedule
from repro.core.plan import parse_plan
from repro.core.resources import ResourceSpec
from repro.core.scheduler import (ResourceView, ScheduleAdvisor,
                                  SchedulerConfig, cost_per_job)
from repro.core.economy import UserRequirements
from repro.kernels import ops, ref
from repro.roofline.hlo_cost import _parse_rhs, _type_bytes

HOUR = 3600.0
COMMON = dict(deadline=None, max_examples=25)


# ---------------------------------------------------------------------------
# scheduler invariants
# ---------------------------------------------------------------------------

@st.composite
def grids(draw):
    n = draw(st.integers(2, 12))
    views, prices = {}, {}
    for i in range(n):
        name = f"r{i}"
        spec = ResourceSpec(
            name=name, site="s",
            chips=draw(st.integers(1, 8)),
            perf_factor=draw(st.floats(0.25, 4.0)),
            base_price=draw(st.floats(0.1, 5.0)),
            slots=draw(st.integers(1, 3)))
        views[name] = ResourceView(
            spec=spec, est_job_seconds=draw(st.floats(60.0, 7200.0)))
        prices[name] = draw(st.floats(0.05, 10.0))
    return views, prices


@given(grids(), st.integers(1, 500), st.floats(0.5, 48.0),
       st.floats(10.0, 1e6),
       st.sampled_from(["cost", "time", "conservative"]))
@settings(**COMMON)
def test_decision_invariants(grid, n_jobs, deadline_h, budget, strategy):
    views, prices = grid
    adv = ScheduleAdvisor(SchedulerConfig(),
                          UserRequirements(deadline=deadline_h * HOUR,
                                           budget=budget, strategy=strategy))
    led = BudgetLedger(budget=budget)
    d = adv.decide(0.0, views, prices, n_jobs, led, set())
    chosen = set(d.allocate)
    # allocations are real resources, no duplicates with releases
    assert chosen <= set(views)
    assert not (chosen & set(d.release))
    assert d.projected_rate >= 0
    # cost strategy: chosen set is a prefix of the cheapest-per-job ranking
    if strategy in ("cost", "conservative") and chosen:
        ranked = sorted(views, key=lambda n: (cost_per_job(views[n],
                                                           prices[n]), n))
        k = len(chosen)
        assert chosen == set(ranked[:k])
    # time strategy never projects spend over budget — except the
    # min_resources floor (the engine never idles entirely; the ledger's
    # per-dispatch commit guard is the hard budget wall, tested below)
    if strategy == "time" and len(chosen) > SchedulerConfig().min_resources \
            and math.isfinite(d.projected_cost_per_job):
        assert d.projected_cost_per_job * n_jobs <= budget * 1.001 + 1e-6


@given(grids(), st.integers(1, 300), st.floats(1.0, 24.0),
       st.floats(100.0, 1e5))
@settings(**COMMON)
def test_tighter_deadline_never_fewer_resources(grid, n_jobs, dl_h, budget):
    views, prices = grid
    led = BudgetLedger(budget=budget)
    def n_chosen(hours):
        adv = ScheduleAdvisor(SchedulerConfig(),
                              UserRequirements(deadline=hours * HOUR,
                                               budget=budget,
                                               strategy="cost"))
        return len(adv.decide(0.0, views, prices, n_jobs, led,
                              set()).allocate)
    assert n_chosen(dl_h) >= n_chosen(dl_h * 2)   # Figure 3, as a law


@given(st.lists(st.tuples(st.floats(0.0, 100.0), st.floats(0.0, 100.0)),
                min_size=1, max_size=40),
       st.floats(1.0, 1e4))
@settings(**COMMON)
def test_ledger_never_negative(ops_list, budget):
    led = BudgetLedger(budget=budget)
    for commit, actual in ops_list:
        if led.can_commit(commit):
            led.commit(commit)
            led.settle(commit, min(actual, commit))
    assert led.settled <= budget + 1e-6
    assert led.committed >= -1e-9
    assert led.remaining >= -1e-6


# ---------------------------------------------------------------------------
# the strategy zoo under market invariants (whole-market runs: keep
# max_examples low — each example is a full simulation)
# ---------------------------------------------------------------------------

MARKET_EXAMPLES = dict(deadline=None, max_examples=5)


def _zoo_market(seed, mix, *, budgets=None, **market_kw):
    market = Marketplace(n_machines=5, seed=seed, **market_kw)
    for i, strat in enumerate(mix):
        market.add_user(MarketUser(
            name=f"u{i}", deadline=(8.0 + 2.0 * (i % 3)) * HOUR,
            budget=(budgets[i] if budgets else 400.0 * (1 + i % 3)),
            strategy=strat, n_jobs=4, est_seconds=1200.0))
    return market


def _ledgers(market):
    return {u.name: e.ledger
            for u, e in zip(market.users, market.engines)}


@given(st.integers(0, 10_000),
       st.lists(st.sampled_from(available_strategies()),
                min_size=2, max_size=4))
@settings(**MARKET_EXAMPLES)
def test_bank_reconciles_for_any_strategy_mix(seed, mix):
    """Double-entry closure is strategy-independent: whatever policies
    share the market, broker spend equals bank-recorded owner income
    exactly (reconcile raises otherwise)."""
    market = _zoo_market(seed, mix)
    market.run()
    total = market.bank.reconcile(_ledgers(market))
    assert total == pytest.approx(
        sum(e.ledger.settled for e in market.engines))


@given(st.integers(0, 10_000),
       st.lists(st.sampled_from(available_strategies()),
                min_size=2, max_size=4),
       st.booleans(), st.booleans())
@settings(**MARKET_EXAMPLES)
def test_spend_bounded_under_churn_and_resale(seed, mix, churn, resale):
    """No broker's settled spend exceeds its budget, whatever the
    interleaving of churn departures, failures, commitment fees,
    rebates and resale fills — the per-dispatch commit guard is the
    hard wall, and fee/refund flows never tunnel through it."""
    market_kw = dict(gis_ttl=900.0, churn_mean_uptime_h=3.0,
                     churn_mean_downtime_h=1.0)
    if resale:
        market_kw.update(release_fee=0.25, resale=True,
                         ask_fraction=0.15, auction_round=1800.0)
    budgets = [30.0 * (1 + i % 4) for i in range(len(mix))]
    market = _zoo_market(seed, mix, budgets=budgets, **market_kw)
    market.run(churn=churn, failures=True)
    market.bank.reconcile(_ledgers(market))
    for user, eng in zip(market.users, market.engines):
        assert eng.ledger.settled <= user.budget + 1e-6, (
            user.strategy, eng.ledger.settled, user.budget)


@given(st.integers(0, 10_000))
@settings(deadline=None, max_examples=3)
def test_same_seed_tournament_byte_identical(seed):
    """A full all-strategies tournament round (auctions + churn +
    failures + resale live) replays byte-for-byte from the seed."""
    zoo = available_strategies()
    market_kw = dict(release_fee=0.25, resale=True, ask_fraction=0.15,
                     auction_round=1800.0, gis_ttl=900.0)

    def play():
        market = _zoo_market(seed, zoo, **market_kw)
        rep = market.run(churn=True, failures=True)
        market.bank.reconcile(_ledgers(market))
        return rep.stable_repr()

    assert play() == play()


# ---------------------------------------------------------------------------
# economy
# ---------------------------------------------------------------------------

@given(st.floats(0.1, 10.0), st.floats(1.0, 4.0), st.integers(1, 256),
       st.floats(0.0, 72.0))
@settings(**COMMON)
def test_price_positive_and_bounded(base, peak, chips, t_hours):
    spec = ResourceSpec(name="r", site="s", chips=chips, base_price=base,
                        peak_multiplier=peak)
    ps = PriceSchedule(spec)
    p = ps.chip_hour_price(t_hours * HOUR)
    assert base - 1e-9 <= p <= base * peak + 1e-9


# ---------------------------------------------------------------------------
# plan language
# ---------------------------------------------------------------------------

@given(st.integers(1, 5), st.integers(1, 5), st.integers(1, 4))
@settings(**COMMON)
def test_cross_product_size(na, nb, nc):
    plan = parse_plan(f"""
parameter a integer range from 1 to {na} step 1
parameter b integer range from 1 to {nb} step 1
parameter c integer range from 0 to {nc - 1} step 1
task main
    execute run --a $a --b $b --c $c
endtask
""")
    pts = plan.points()
    assert len(pts) == na * nb * nc
    assert len({tuple(sorted(p.items())) for p in pts}) == len(pts)


# ---------------------------------------------------------------------------
# kernels: flash attention == oracle over random shape draws
# ---------------------------------------------------------------------------

@given(st.integers(1, 2), st.integers(1, 4), st.integers(16, 80),
       st.integers(8, 32), st.booleans(), st.integers(0, 1),
       st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=12)
def test_flash_attention_random(B, G, S, D, causal, win_mode, seed):
    K = 2
    H = K * G
    window = 0 if not win_mode else max(4, S // 3)
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, K, S, D))
    v = jax.random.normal(ks[2], (B, K, S, D))
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@given(st.integers(1, 3), st.integers(4, 70), st.integers(4, 40),
       st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=12)
def test_rglru_random(B, S, L, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    log_a = -jnp.exp(jax.random.normal(ks[0], (B, S, L)) * 0.5 - 2)
    b = jax.random.normal(ks[1], (B, S, L))
    h0 = jax.random.normal(ks[2], (B, L))
    out = ops.rglru_scan(log_a, b, h0, block_t=16, block_l=16)
    want = ref.rglru_ref(log_a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# HLO parser
# ---------------------------------------------------------------------------

@given(st.sampled_from(["f32", "bf16", "s32", "pred"]),
       st.lists(st.integers(1, 64), min_size=0, max_size=4))
@settings(**COMMON)
def test_type_bytes_matches_numpy(dt, dims):
    bytes_per = {"f32": 4, "bf16": 2, "s32": 4, "pred": 1}[dt]
    n = int(np.prod(dims)) if dims else 1
    s = f"{dt}[{','.join(map(str, dims))}]"
    assert _type_bytes(s) == n * bytes_per


def test_parse_rhs_tuple_with_index_comments():
    rhs = ("(s32[], bf16[16,4096,1152]{2,1,0}, /*index=5*/f32[4,256]{1,0}) "
           "while(%tuple.1), condition=%c, body=%b")
    rtype, opcode, rest = _parse_rhs(rhs)
    assert opcode == "while"
    assert "bf16[16,4096,1152]" in rtype
    assert "condition=%c" in rest
