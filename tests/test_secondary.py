"""Secondary capacity market + clearing-history price discovery.

Covers the PR-5 economy loop: reservation transfer (admission quotas
preserved), resale listing/fill with exact GridBank mirroring,
commitment fees as the wasted-contract-spend measure, resale offers
merged into the primary price sources, the discovery EMA on
``PriceSchedule``, and whole-market determinism + reconciliation with
everything switched on at once.
"""
import math

import pytest

from repro.core import (AdmissionError, BudgetLedger, ClearingHistory,
                        GridBank, Marketplace, MarketUser, PriceSchedule,
                        ResourceSpec, mixed_auction_market)

from conftest import make_federation as _grid
from conftest import make_secondary as _market
from conftest import make_spec as _spec

HOUR = 3600.0


# ---------------------------------------------------------------------------
# reservation transfer
# ---------------------------------------------------------------------------

def test_transfer_preserves_window_price_and_bumps_book_version():
    d, fed = _grid([_spec("m0", "X", price=1.0)])
    server = fed.servers["X"]
    r = fed.reserve("m0", "alice", 0.0, 4 * HOUR, 0.0, locked_price=0.4)
    v0 = server.book_version
    out = server.transfer(r.reservation_id, "bob", HOUR)
    assert out is r                              # same reservation object
    assert out.user == "bob"
    assert out.locked_price == pytest.approx(0.4)
    assert out.end == pytest.approx(4 * HOUR)
    assert server.book_version > v0              # quote caches must refresh
    # the buyer now draws the locked price; the seller pays spot again
    assert fed.effective_price("m0", "bob", 2 * HOUR) == pytest.approx(0.4)
    assert fed.effective_price("m0", "alice", 2 * HOUR) == pytest.approx(1.0)


def test_transfer_enforces_buyer_admission_quota():
    """A resale is not a quota side-door: the buyer must clear the same
    per-user cap a fresh reservation would."""
    d, fed = _grid([_spec("m0", "X", price=1.0), _spec("m1", "X", price=1.0)],
                   max_reservations_per_user=1)
    server = fed.servers["X"]
    ra = fed.reserve("m0", "alice", 0.0, 4 * HOUR, 0.0)
    fed.reserve("m1", "bob", 0.0, 4 * HOUR, 0.0)     # bob at his quota
    with pytest.raises(AdmissionError):
        server.transfer(ra.reservation_id, "bob", HOUR)
    assert ra.user == "alice"                        # untouched on refusal
    out = server.transfer(ra.reservation_id, "carol", HOUR)
    assert out.user == "carol"


def test_transfer_of_expired_or_cancelled_reservation_returns_none():
    d, fed = _grid([_spec("m0", "X", price=1.0)])
    server = fed.servers["X"]
    r = fed.reserve("m0", "alice", 0.0, HOUR, 0.0)
    assert server.transfer(r.reservation_id, "bob", 2 * HOUR) is None
    r2 = fed.reserve("m0", "alice", 3 * HOUR, 4 * HOUR, 2 * HOUR)
    fed.cancel(r2.reservation_id)
    assert server.transfer(r2.reservation_id, "bob", 2 * HOUR) is None


# ---------------------------------------------------------------------------
# listing, fill, and exact bank mirroring
# ---------------------------------------------------------------------------

def test_fill_transfers_reservation_and_mirrors_bank_exactly():
    bank = GridBank()
    d, fed = _grid([_spec("m0", "X", price=1.0, chips=2)])
    sec = _market(fed, bank, ask_fraction=0.5)
    la, lb = BudgetLedger(budget=100.0), BudgetLedger(budget=100.0)
    sec.register_user("alice", la)
    sec.register_user("bob", lb)
    r = fed.reserve("m0", "alice", 0.0, 4 * HOUR, 0.0, locked_price=0.4)
    assert sec.shed(r.reservation_id, "alice", 0.0) == "listed"
    lst = sec.listings[r.reservation_id]
    assert lst.ask_rate == pytest.approx(0.2)        # 0.5 x locked
    assert lst.all_in_rate == pytest.approx(0.6)
    # fill at t=2h: remaining-window pro-rata = 0.2 x 2 chips x 2h = 0.8
    out = sec.buy(r.reservation_id, "bob", 2 * HOUR)
    assert out is not None and out.user == "bob"
    assert lst.lump(2 * HOUR) == pytest.approx(0.8)
    assert lb.settled == pytest.approx(0.8)          # buyer charged
    assert la.settled == pytest.approx(-0.8)         # seller refunded
    assert bank.user_spend("bob") == pytest.approx(0.8)
    assert bank.user_spend("alice") == pytest.approx(-0.8)
    assert bank.owner_revenue("X") == pytest.approx(0.0)   # net zero
    assert bank.kind_total("resale") == pytest.approx(0.0)
    bank.reconcile({"alice": la, "bob": lb})         # exact, no tolerance
    assert not sec.listings                          # off the book
    assert sec.fills and sec.fills[0].lump == pytest.approx(0.8)


def test_buyer_cannot_fill_own_listing_and_gone_listings_fail_softly():
    bank = GridBank()
    d, fed = _grid([_spec("m0", "X", price=1.0)])
    sec = _market(fed, bank)
    r = fed.reserve("m0", "alice", 0.0, 4 * HOUR, 0.0)
    sec.shed(r.reservation_id, "alice", 0.0)
    assert sec.buy(r.reservation_id, "alice", HOUR) is None
    fed.cancel(r.reservation_id)                 # voided under the listing
    assert sec.buy(r.reservation_id, "bob", HOUR) is None
    assert r.reservation_id not in sec.listings  # dropped on discovery


def test_release_charges_commitment_fee_as_wasted_spend():
    bank = GridBank()
    d, fed = _grid([_spec("m0", "X", price=1.0, chips=2)])
    sec = _market(fed, bank, resale=False, release_fee=0.25)
    led = BudgetLedger(budget=100.0)
    sec.register_user("alice", led)
    r = fed.reserve("m0", "alice", 0.0, 4 * HOUR, 0.0, locked_price=0.5)
    assert sec.shed(r.reservation_id, "alice", 2 * HOUR) == "released"
    # fee = 0.25 x 0.5 G$/ch-h x 2 chips x 2h remaining = 0.5
    assert sec.wasted_spend == pytest.approx(0.5)
    assert led.settled == pytest.approx(0.5)
    assert bank.kind_total("idle") == pytest.approx(0.5)
    assert bank.owner_revenue("X") == pytest.approx(0.5)  # owner keeps fees
    bank.reconcile({"alice": led})
    assert fed.servers["X"].reservations == []   # capacity handed back


def test_unsold_listing_pays_fee_over_listed_idle_span_on_sweep():
    bank = GridBank()
    d, fed = _grid([_spec("m0", "X", price=1.0, chips=1)])
    sec = _market(fed, bank, release_fee=0.25, ask_fraction=0.2)
    led = BudgetLedger(budget=100.0)
    sec.register_user("alice", led)
    r = fed.reserve("m0", "alice", 0.0, 4 * HOUR, 0.0, locked_price=1.0)
    sec.shed(r.reservation_id, "alice", HOUR)    # listed at t=1h
    assert sec.sweep(2 * HOUR) == pytest.approx(0.0)   # still live: no fee
    # window lapses unsold: fee over the listed-idle span [1h, 4h)
    fee = sec.sweep(5 * HOUR)
    assert fee == pytest.approx(0.25 * 1.0 * 1 * 3.0)
    assert sec.wasted_spend == pytest.approx(fee)
    assert not sec.listings
    bank.reconcile({"alice": led})


def test_reclaim_pulls_own_listing_back_without_fee():
    """A seller whose re-plan wants the resource back gets their unsold
    listing off the book fee-free — a window back in use is not idle,
    and must not be sellable or expiry-billed out from under them."""
    bank = GridBank()
    d, fed = _grid([_spec("m0", "X", price=1.0)])
    sec = _market(fed, bank, release_fee=0.25)
    led = BudgetLedger(budget=100.0)
    sec.register_user("alice", led)
    r = fed.reserve("m0", "alice", 0.0, 4 * HOUR, 0.0, locked_price=0.5)
    sec.shed(r.reservation_id, "alice", HOUR)
    v = sec.version
    assert sec.reclaim("m0", "alice", 2 * HOUR) == 1
    assert sec.version > v                       # quote caches refresh
    assert not sec.listings
    # the reservation is still alice's, still priced at the lock
    assert fed.effective_price("m0", "alice", 3 * HOUR) == pytest.approx(0.5)
    # and no fee ever lands: the window is in use, not idle
    assert sec.finalize(5 * HOUR) == pytest.approx(0.0)
    assert led.settled == pytest.approx(0.0)
    # reclaim never touches rivals' listings
    r2 = fed.reserve("m0", "bob", 4 * HOUR, 6 * HOUR, 3.5 * HOUR)
    sec.shed(r2.reservation_id, "bob", 4 * HOUR)
    assert sec.reclaim("m0", "alice", 4 * HOUR) == 0
    assert r2.reservation_id in sec.listings


def test_negotiate_contract_prices_resale_bids_but_never_reserves_them():
    """A resale listing can win the contract-mode quote, but accepting
    must not turn it into a fresh reservation: on a full queue that
    would crash, and anywhere it would pay the seller's premium to the
    owner.  Resale-backed bids are priced, not locked."""
    from repro.core import ResourceView, UserRequirements, negotiate_contract
    d, fed = _grid([_spec("m0", "X", price=2.0)])      # 1 slot
    sec = _market(fed, ask_fraction=0.2)
    fed.servers["X"].secondary = sec
    # the seller's listed reservation fills the only slot of the window
    r = fed.reserve("m0", "alice", 0.0, 40 * HOUR, 0.0, locked_price=0.5)
    sec.shed(r.reservation_id, "alice", 0.0)
    views = {"m0": ResourceView(spec=d.spec("m0"), est_job_seconds=600.0)}
    req = UserRequirements(deadline=30 * HOUR, budget=1e6, user="bob")
    bids = fed.solicit_bids(0.0, "bob", lambda s: 600.0)
    assert any(b.resale_rid for b in bids)       # the listing is on offer
    quote = negotiate_contract(0.0, req, 10, fed.servers["X"], views,
                               accept=True)
    assert quote.feasible                        # and no AdmissionError
    # nothing was double-booked: the seller's reservation is untouched
    # and the only booked window is still theirs
    assert [x.user for x in fed.servers["X"].reservations] == ["alice"]


def test_voided_listing_finalizes_without_fee():
    """Churn voids the contract under a listing: the capacity was taken
    from the holder, not idled by them — finalize drops the listing but
    charges no commitment fee (the breach rebate settled that loss)."""
    bank = GridBank()
    d, fed = _grid([_spec("m0", "X", price=1.0)])
    sec = _market(fed, bank, release_fee=0.25)
    led = BudgetLedger(budget=100.0)
    sec.register_user("alice", led)
    r = fed.reserve("m0", "alice", 0.0, 8 * HOUR, 0.0)
    sec.shed(r.reservation_id, "alice", HOUR)
    fed.cancel(r.reservation_id)                 # the void, mid-window
    assert sec.finalize(2 * HOUR) == pytest.approx(0.0)
    assert sec.wasted_spend == pytest.approx(0.0)
    assert led.settled == pytest.approx(0.0)
    assert not sec.listings
    # but a listing STILL LIVE at an early finalize does pay: the holder
    # chose to idle it from listing time to its end
    r2 = fed.reserve("m0", "alice", 2 * HOUR, 6 * HOUR, 2 * HOUR)
    sec.shed(r2.reservation_id, "alice", 2 * HOUR)
    fee = sec.finalize(3 * HOUR)
    assert fee == pytest.approx(0.25 * r2.locked_price * 1 * 4.0)


def test_resale_offers_merge_into_solicit_bids():
    d, fed = _grid([_spec("m0", "X", price=2.0)])
    sec = _market(fed, ask_fraction=0.2)
    fed.servers["X"].secondary = sec
    r = fed.reserve("m0", "alice", 0.0, 4 * HOUR, 0.0, locked_price=0.5)
    sec.shed(r.reservation_id, "alice", 0.0)
    bids = fed.solicit_bids(HOUR, "bob", lambda spec: 600.0)
    prices = sorted(b.chip_hour_price for b in bids)
    assert prices[0] == pytest.approx(0.6)       # the resale offer leads
    assert any(b.available_slots == 1 and b.chip_hour_price
               == pytest.approx(0.6) for b in bids)
    # the seller never sees their own listing quoted back at them
    own = fed.solicit_bids(HOUR, "alice", lambda spec: 600.0)
    assert all(b.chip_hour_price != pytest.approx(0.6) for b in own)


# ---------------------------------------------------------------------------
# price discovery
# ---------------------------------------------------------------------------

def test_discovery_ema_nudges_posted_base_toward_clearing():
    spec = _spec("m0", "X", price=2.0)
    ps = PriceSchedule(spec, discovery_gain=0.5, discovery_band=0.5)
    for _ in range(40):
        ps.observe_clearing(0.0, 1.5)            # market clears below list
    assert ps.base_price == pytest.approx(1.5, rel=1e-6)
    assert ps.chip_hour_price(0.0) == pytest.approx(1.5, rel=1e-6)


def test_discovery_drift_bounded_by_band():
    spec = _spec("m0", "X", price=2.0)
    ps = PriceSchedule(spec, discovery_gain=0.5, discovery_band=0.25)
    for _ in range(100):
        ps.observe_clearing(0.0, 0.01)           # absurdly low clearing
    assert ps.base_price == pytest.approx(2.0 * 0.75, rel=1e-6)
    for _ in range(100):
        ps.observe_clearing(0.0, 50.0)           # absurdly high clearing
    assert ps.base_price == pytest.approx(2.0 * 1.25, rel=1e-6)


def test_discovery_backs_out_time_of_day_factors():
    """A peak-hour trade must not drag the base around just because the
    peak multiplier inflated both sides: clearing exactly AT the posted
    peak price implies the base is already right."""
    spec = ResourceSpec(name="m0", site="X", chips=1, base_price=2.0,
                        peak_multiplier=3.0, mtbf_hours=float("inf"))
    ps = PriceSchedule(spec, discovery_gain=0.5)
    ps.observe_clearing(12 * HOUR, 6.0)          # 12:00 peak: posted is 6.0
    assert ps.base_price == pytest.approx(2.0)


def test_discovery_off_means_frozen_base():
    ps = PriceSchedule(_spec("m0", "X", price=2.0))    # default gain 0
    ps.observe_clearing(0.0, 0.5)
    assert ps.base_price == pytest.approx(2.0)


def test_clearing_history_gap_by_observation():
    h = ClearingHistory()
    h.append(0.0, "a", 1.0, 2.0, "auction")      # gap 0.5
    h.append(1.0, "a", 1.0, 1.25, "auction")     # gap 0.2
    h.append(2.0, "b", 1.0, 1.0, "auction")      # gap 0.0
    h.append(3.0, "a", 1.0, 1.0, "resale")       # other source: ignored
    gaps = h.gap_by_observation()
    assert gaps[0] == pytest.approx((0.5 + 0.0) / 2)
    assert gaps[1] == pytest.approx(0.2)
    assert len(h.for_resource("a")) == 3
    assert h.last_price("a") == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# whole-market: determinism, reconciliation, the closed loop
# ---------------------------------------------------------------------------

def _resale_market(n_users=8, resale=True, gain=0.2, seed=11):
    return mixed_auction_market(
        n_users, n_machines=24, seed=seed, n_jobs=50,
        est_seconds=2700.0, deadline_h=16.0, budget=10000.0,
        auction_round=1800.0, auction_window=4 * HOUR,
        release_fee=0.25, resale=resale, ask_fraction=0.15,
        discovery_gain=gain)


def test_resale_market_same_seed_byte_identical():
    r1, r2 = _resale_market().run(), _resale_market().run()
    assert r1.stable_repr() == r2.stable_repr()
    assert "secondary=" in r1.stable_repr()      # the new section is pinned
    r3 = _resale_market(seed=12).run()
    assert r1.stable_repr() != r3.stable_repr()


def test_resale_market_reconciles_exactly_with_all_flows():
    """Usage settlements, kill settlements, resale lumps (both signs),
    commitment fees and discovery-adjusted quotes all in one run — and
    the bank still balances against every broker ledger exactly."""
    market = _resale_market()
    rep = market.run()
    assert rep.total_done == rep.total_jobs
    ledgers = {u.name: e.ledger for u, e in zip(market.users,
                                                market.engines)}
    total = market.bank.reconcile(ledgers)
    assert total == pytest.approx(
        math.fsum(l.settled for l in ledgers.values()))
    # resale entries net to zero by construction
    assert market.bank.kind_total("resale") == pytest.approx(0.0, abs=1e-9)
    # the report carries what the run measured
    assert rep.resale_enabled
    assert rep.wasted_spend == pytest.approx(market.secondary.wasted_spend)
    # reports were refreshed after finalize: spend equals the ledger
    for user, engine in zip(market.users, market.engines):
        assert engine.report.total_cost == engine.ledger.settled


def test_resale_reduces_wasted_contract_spend_same_seed():
    off = _resale_market(resale=False)
    on = _resale_market(resale=True)
    r_off, r_on = off.run(), on.run()
    assert r_off.wasted_spend > 0.0
    assert r_on.wasted_spend < r_off.wasted_spend
    assert r_on.resales > 0                      # fills actually happened


def test_discovery_gap_shrinks_monotonically_in_market_run():
    market = _resale_market(gain=0.2)
    market.run()
    gaps = market.history.gap_by_observation()
    assert len(gaps) >= 3
    assert all(b <= a + 1e-9 for a, b in zip(gaps, gaps[1:])), gaps
    assert gaps[-1] < gaps[0]


def test_churn_rebate_follows_resold_window_to_its_buyer():
    """A site departs after a resale fill: the breach rebate for the
    voided window must reach the BUYER who holds it, not the seller who
    already pocketed the lump."""
    specs = [_spec("a0", "A", price=1.0), _spec("b0", "B", price=1.0)]
    market = Marketplace(specs=specs, seed=0, release_fee=0.25,
                         resale=True, ask_fraction=0.2)
    market.add_user(MarketUser(name="seller", deadline=12 * HOUR,
                               budget=1e4, strategy="auction", n_jobs=1))
    market.add_user(MarketUser(name="buyer", deadline=12 * HOUR,
                               budget=1e4, n_jobs=1))
    c = market.auction_house._strike("seller", "a0", "A", 0.5, 1,
                                     0.0, 8 * HOUR, via="auction")
    rid = c.reservation_ids[0]
    assert market.secondary.shed(rid, "seller", 0.0) == "listed"
    assert market.secondary.buy(rid, "buyer", 0.0) is not None
    seller_led = market.engines[0].ledger
    buyer_led = market.engines[1].ledger
    lump = market.secondary.fills[0].lump
    assert buyer_led.settled == pytest.approx(lump)
    assert market._site_leaves("A", rejoin_at=24 * HOUR)
    # rebate = churn_rebate x remaining value, credited to the buyer
    rebate = market.refunds
    assert rebate > 0.0
    assert buyer_led.settled == pytest.approx(lump - rebate)
    assert seller_led.settled == pytest.approx(-lump)   # lump only, no rebate
    ledgers = {"seller": seller_led, "buyer": buyer_led}
    market.bank.reconcile(ledgers)                      # still exact


def test_default_market_has_no_secondary_machinery():
    """The whole subsystem is opt-in: a default marketplace carries no
    secondary market, no fees, and an unchanged stable_repr shape (the
    golden-equivalence hashes pin the bytes themselves)."""
    market = Marketplace(n_machines=4, seed=0)
    market.add_user(MarketUser(name="u", deadline=12 * HOUR, budget=1e4,
                               n_jobs=2))
    rep = market.run()
    assert market.secondary is None
    assert not rep.resale_enabled and rep.wasted_spend == 0.0
    assert "secondary=" not in rep.stable_repr()
