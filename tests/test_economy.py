"""Computational-economy machinery: prices, bids, reservations, ledger."""
import math

import pytest

from repro.core import (AdmissionError, BudgetLedger, PriceSchedule,
                        ResourceDirectory, ResourceSpec, TradeServer)

HOUR = 3600.0


def _spec(name="r0", price=2.0, peak=2.0, chips=4):
    return ResourceSpec(name=name, site="s", chips=chips, base_price=price,
                        peak_multiplier=peak)


def test_peak_offpeak_pricing():
    ps = PriceSchedule(_spec())
    off = ps.chip_hour_price(2 * HOUR)           # 02:00 local
    on = ps.chip_hour_price(12 * HOUR)           # 12:00 local
    assert on == pytest.approx(off * 2.0)


def test_per_user_price_discrimination():
    ps = PriceSchedule(_spec(), user_factors={"vip": 0.5, "rival": 3.0})
    t = 2 * HOUR
    base = ps.chip_hour_price(t)
    assert ps.chip_hour_price(t, "vip") == pytest.approx(0.5 * base)
    assert ps.chip_hour_price(t, "rival") == pytest.approx(3.0 * base)
    assert ps.chip_hour_price(t, "anon") == pytest.approx(base)


def test_spot_fluctuation_bounded_and_deterministic():
    ps = PriceSchedule(_spec(), spot_amplitude=0.2)
    xs = [ps.chip_hour_price(t * 60.0) for t in range(0, 600)]
    base = _spec().base_price
    assert all(0.8 * base - 1e-9 <= x <= 2.0 * 1.2 * base + 1e-9 for x in xs)
    assert xs == [PriceSchedule(_spec(), spot_amplitude=0.2)
                  .chip_hour_price(t * 60.0) for t in range(0, 600)]


def test_job_cost_scales_with_chips_and_time():
    ps = PriceSchedule(_spec(price=1.0, peak=1.0, chips=8))
    assert ps.job_cost(0.0, HOUR) == pytest.approx(8.0)
    assert ps.job_cost(0.0, HOUR / 2) == pytest.approx(4.0)


def _trade(n=4):
    d = ResourceDirectory()
    for i in range(n):
        d.register(_spec(f"r{i}", price=1.0 + i, peak=1.0))
    scheds = {f"r{i}": PriceSchedule(d.spec(f"r{i}")) for i in range(n)}
    return TradeServer(d, scheds), d


def test_bids_sorted_by_price():
    trade, d = _trade()
    bids = trade.solicit_bids(0.0, "u", lambda s: 600.0)
    assert [b.chip_hour_price for b in bids] == sorted(
        b.chip_hour_price for b in bids)
    assert all(b.est_rate == pytest.approx(6.0) for b in bids)


def test_reservation_locks_price():
    trade, d = _trade()
    r = trade.reserve("r0", "u", start=0.0, end=10 * HOUR, t=0.0)
    # owner hikes the price later (peak hours) — reserved user keeps it
    locked = trade.effective_price("r0", "u", 9 * HOUR)
    assert locked == pytest.approx(r.locked_price)
    # other users pay the live price
    assert trade.effective_price("r0", "other", 9 * HOUR) >= locked
    assert trade.cancel(r.reservation_id)
    assert trade.reserved_price("r0", "u", 5 * HOUR) is None


def test_directory_authorization_and_filters():
    d = ResourceDirectory()
    d.register(ResourceSpec(name="open", site="a", chips=2))
    d.register(ResourceSpec(name="closed", site="b", chips=8,
                            authorized_users=("alice",)))
    assert [s.name for s in d.discover("bob")] == ["open"]
    assert {s.name for s in d.discover("alice")} == {"closed", "open"}
    assert [s.name for s in d.discover("alice", min_chips=4)] == ["closed"]
    assert [s.name for s in d.discover("alice", site="a")] == ["open"]
    d.status("open").up = False
    assert [s.name for s in d.discover("alice")] == ["closed"]


def test_price_math_exact_at_known_virtual_times():
    """Every factor of the quote at hand-computed times: base * peak *
    spot * user-factor * demand, all independently verifiable."""
    spec = _spec(price=2.0, peak=3.0)
    period = 4 * HOUR
    ps = PriceSchedule(spec, user_factors={"vip": 0.5},
                       spot_amplitude=0.25, spot_period=period,
                       demand_elasticity=0.8)
    # 02:00 (off-peak), spot sin(2*pi*t/period) at t=period -> sin(2pi)=0
    t = 2 * HOUR                      # == period/2: sin(pi) = 0
    assert ps.chip_hour_price(t) == pytest.approx(2.0)
    # quarter period: sin(pi/2) = 1 -> spot = 1.25; 01:00 still off-peak
    t = period / 4
    assert ps.chip_hour_price(t) == pytest.approx(2.0 * 1.25)
    assert ps.chip_hour_price(t, "vip") == pytest.approx(2.0 * 1.25 * 0.5)
    # 13:00 peak: 2.0 * 3.0; t = 13h = 3.25 periods -> sin(pi/2) = 1
    t = 13 * HOUR
    assert ps.chip_hour_price(t) == pytest.approx(2.0 * 3.0 * 1.25)
    # full house: utilization 1 with elasticity 0.8 -> x1.8
    assert ps.chip_hour_price(t, utilization=1.0) == pytest.approx(
        2.0 * 3.0 * 1.25 * 1.8)
    # job_cost = chip_hour_price * chips * duration/HOUR (4 chips)
    assert ps.job_cost(t, HOUR / 2) == pytest.approx(
        2.0 * 3.0 * 1.25 * 4 * 0.5)


def test_demand_elasticity_defaults_off():
    ps = PriceSchedule(_spec(price=1.0, peak=1.0))
    assert ps.chip_hour_price(0.0, utilization=1.0) == pytest.approx(1.0)


def test_budget_ledger_commit_settle_cycle():
    led = BudgetLedger(budget=100.0)
    assert led.can_commit(60.0)
    led.commit(60.0)
    assert not led.can_commit(50.0)
    assert led.can_commit(40.0)
    led.settle(60.0, 55.0)          # actual cheaper than committed
    assert led.settled == pytest.approx(55.0)
    assert led.committed == pytest.approx(0.0)
    assert led.remaining == pytest.approx(45.0)


def test_ledger_committed_never_negative_and_remaining_monotone():
    """Settling more than was committed clamps committed at zero, and a
    run of commit/settle cycles (actual == committed) drains ``remaining``
    monotonically — no refund can ever grow the pot."""
    led = BudgetLedger(budget=50.0)
    led.commit(10.0)
    led.settle(25.0, 10.0)            # over-settle the commitment
    assert led.committed == 0.0       # clamped, never negative
    seen = [led.remaining]
    for _ in range(6):
        amt = 5.0
        if led.can_commit(amt):
            led.commit(amt)
            led.settle(amt, amt)
        seen.append(led.remaining)
    assert all(b <= a + 1e-9 for a, b in zip(seen, seen[1:])), seen
    assert led.remaining >= -1e-9


def test_ledger_overcommit_refused_but_refund_reopens():
    led = BudgetLedger(budget=10.0)
    led.commit(8.0)
    assert not led.can_commit(3.0)
    led.settle(8.0, 4.0)              # actual half the estimate: refund
    assert led.can_commit(3.0)        # freed headroom is usable again
    assert led.remaining == pytest.approx(6.0)


def test_reservation_locks_price_against_spot_drift():
    """A reservation's locked price holds even while the owner's spot
    component swings the live quote around it."""
    d = ResourceDirectory()
    d.register(_spec("spot", price=1.0, peak=1.0))
    period = 2 * HOUR
    trade = TradeServer(d, {"spot": PriceSchedule(
        d.spec("spot"), spot_amplitude=0.5, spot_period=period)})
    r = trade.reserve("spot", "u", start=0.0, end=10 * HOUR, t=0.0)
    assert r.locked_price == pytest.approx(1.0)      # sin(0) = 0
    t_hi = period / 4                                # sin(pi/2): quote 1.5
    assert trade.quote("spot", t_hi) == pytest.approx(1.5)
    assert trade.effective_price("spot", "u", t_hi) == pytest.approx(1.0)
    t_lo = 3 * period / 4                            # sin(3pi/2): quote 0.5
    assert trade.quote("spot", t_lo) == pytest.approx(0.5)
    # the lock is a contract, not a best-of: user pays it either way
    assert trade.effective_price("spot", "u", t_lo) == pytest.approx(1.0)
    # outside the window the live (drifting) quote applies again
    assert trade.effective_price("spot", "u", 11 * HOUR) == pytest.approx(
        trade.quote("spot", 11 * HOUR))


def test_reservation_admission_capacity():
    """A window holds at most ``slots`` overlapping reservations."""
    d = ResourceDirectory()
    d.register(ResourceSpec(name="r0", site="s", chips=1, slots=2))
    trade = TradeServer(d, {"r0": PriceSchedule(d.spec("r0"))})
    trade.reserve("r0", "a", start=0.0, end=HOUR, t=0.0)
    trade.reserve("r0", "b", start=0.0, end=HOUR, t=0.0)
    with pytest.raises(AdmissionError):
        trade.reserve("r0", "c", start=0.5 * HOUR, end=2 * HOUR, t=0.0)
    # a disjoint window is fine
    r = trade.reserve("r0", "c", start=HOUR, end=2 * HOUR, t=0.0)
    assert trade.cancel(r.reservation_id)


def test_reservation_per_user_quota():
    d = ResourceDirectory()
    for i in range(3):
        d.register(_spec(f"r{i}", price=1.0, peak=1.0))
    trade = TradeServer(d, {f"r{i}": PriceSchedule(d.spec(f"r{i}"))
                            for i in range(3)},
                        max_reservations_per_user=2)
    trade.reserve("r0", "hog", start=0.0, end=HOUR, t=0.0)
    trade.reserve("r1", "hog", start=0.0, end=HOUR, t=0.0)
    with pytest.raises(AdmissionError):
        trade.reserve("r2", "hog", start=0.0, end=HOUR, t=0.0)
    # other users unaffected; expired reservations free the quota
    trade.reserve("r2", "other", start=0.0, end=HOUR, t=0.0)
    r = trade.reserve("r2", "hog", start=2 * HOUR, end=3 * HOUR,
                      t=1.5 * HOUR)   # t past the first two windows' end
    assert r.reservation_id > 0


def test_reservation_book_pruned_on_access():
    """Long market runs must not degrade into scans over every
    reservation ever made: expired windows are dropped on access, while
    live ones keep their cancel semantics."""
    trade, d = _trade(n=1)
    for i in range(50):
        trade.reserve("r0", f"u{i % 5}", start=float(i), end=float(i) + 1.0,
                      t=float(i))
    assert len(trade.reservations) <= 2           # pruned as we went
    live = trade.reserve("r0", "keeper", start=100.0, end=200.0, t=60.0)
    assert trade.reserved_price("r0", "keeper", 150.0) == pytest.approx(
        live.locked_price)
    # access far past every expiry: the book empties, cancel says so
    assert trade.reserved_price("r0", "keeper", 500.0) is None
    assert len(trade.reservations) == 0
    assert not trade.cancel(live.reservation_id)
    # pruning freed capacity and quota: a full history never blocks
    trade.reserve("r0", "keeper", start=600.0, end=700.0, t=600.0)


def test_sealed_bid_price_expires_and_requotes():
    """A sealed bid's price is honored only inside its validity window;
    settlements arriving later get the live price (satellite: the dead
    ``Bid.valid_until`` is now enforced)."""
    d = ResourceDirectory()
    d.register(ResourceSpec(name="r0", site="s", chips=1, base_price=1.0,
                            peak_multiplier=4.0))
    trade = TradeServer(d, {"r0": PriceSchedule(d.spec("r0"))},
                        bid_validity=HOUR)
    sealed = trade.quote("r0", 0.0)               # 00:00, off-peak: 1.0
    assert sealed == pytest.approx(1.0)
    # within validity the sealed price holds, whatever the clock says
    assert trade.honored_price("r0", "u", sealed, 0.0, 0.5 * HOUR) \
        == pytest.approx(1.0)
    # past validity the settlement re-quotes: 09:00 is peak, 4x
    assert trade.honored_price("r0", "u", sealed, 0.0, 9 * HOUR) \
        == pytest.approx(4.0)
    # unless a reservation locks it — contracts survive bid expiry
    trade.reserve("r0", "u", start=0.0, end=12 * HOUR, t=0.0)
    assert trade.honored_price("r0", "u", sealed, 0.0, 9 * HOUR) \
        == pytest.approx(1.0)


def test_solicited_bids_carry_configured_validity():
    trade, d = _trade(n=2)
    trade.bid_validity = 2 * HOUR
    bids = trade.solicit_bids(10.0, "u", lambda s: 600.0)
    assert all(b.valid_until == pytest.approx(10.0 + 2 * HOUR)
               for b in bids)


def test_dispatch_settling_after_bid_expiry_pays_requoted_price():
    """Engine-level regression for the dead ``valid_until``: a job whose
    run outlives the sealed quote settles at the live (peak) price, not
    the stale off-peak one it was dispatched under."""
    from repro.core import (Dispatcher, JobSpec, NimrodG, SchedulerConfig,
                            SimulatedExecutor, Simulator, UserRequirements)
    d = ResourceDirectory()
    d.register(ResourceSpec(name="slow", site="s", chips=1, slots=1,
                            base_price=1.0, peak_multiplier=4.0,
                            perf_factor=1.0, mtbf_hours=float("inf")))
    trade = TradeServer(d, {"slow": PriceSchedule(d.spec("slow"))},
                        bid_validity=HOUR)
    sim = Simulator()
    ex = SimulatedExecutor(sim, d, noise_sigma=0.0)
    jobs = [JobSpec(job_id="j0", experiment="e", point={}, steps=(),
                    est_seconds_base=9 * HOUR,      # outlives the quote
                    stage_in_bytes=0, stage_out_bytes=0)]
    req = UserRequirements(deadline=24 * HOUR, budget=1e6, user="u")
    eng = NimrodG("e", jobs, req, d, trade, Dispatcher(ex, d), sim=sim,
                  sched_cfg=SchedulerConfig())
    rep = eng.run_simulated(failures=False)
    assert rep.n_done == 1
    # dispatched ~00:00 (off-peak, sealed 1.0) but settled ~09:00 (peak):
    # 9 chip-hours at the re-quoted 4.0, not at the stale 1.0
    assert rep.total_cost == pytest.approx(9.0 * 4.0, rel=1e-6)


def test_quote_reflects_live_utilization():
    d = ResourceDirectory()
    d.register(ResourceSpec(name="r0", site="s", chips=1, slots=4,
                            base_price=1.0, peak_multiplier=1.0))
    trade = TradeServer(d, {"r0": PriceSchedule(d.spec("r0"),
                                                demand_elasticity=1.0)})
    assert trade.quote("r0", 0.0) == pytest.approx(1.0)
    st, spec = d.status("r0"), d.spec("r0")
    assert st.acquire(spec) and st.acquire(spec)
    assert trade.quote("r0", 0.0) == pytest.approx(1.5)   # util 0.5
    st.release()
    assert trade.quote("r0", 0.0) == pytest.approx(1.25)  # util 0.25
