"""Computational-economy machinery: prices, bids, reservations, ledger."""
import math

import pytest

from repro.core import (BudgetLedger, PriceSchedule, ResourceDirectory,
                        ResourceSpec, TradeServer)

HOUR = 3600.0


def _spec(name="r0", price=2.0, peak=2.0, chips=4):
    return ResourceSpec(name=name, site="s", chips=chips, base_price=price,
                        peak_multiplier=peak)


def test_peak_offpeak_pricing():
    ps = PriceSchedule(_spec())
    off = ps.chip_hour_price(2 * HOUR)           # 02:00 local
    on = ps.chip_hour_price(12 * HOUR)           # 12:00 local
    assert on == pytest.approx(off * 2.0)


def test_per_user_price_discrimination():
    ps = PriceSchedule(_spec(), user_factors={"vip": 0.5, "rival": 3.0})
    t = 2 * HOUR
    base = ps.chip_hour_price(t)
    assert ps.chip_hour_price(t, "vip") == pytest.approx(0.5 * base)
    assert ps.chip_hour_price(t, "rival") == pytest.approx(3.0 * base)
    assert ps.chip_hour_price(t, "anon") == pytest.approx(base)


def test_spot_fluctuation_bounded_and_deterministic():
    ps = PriceSchedule(_spec(), spot_amplitude=0.2)
    xs = [ps.chip_hour_price(t * 60.0) for t in range(0, 600)]
    base = _spec().base_price
    assert all(0.8 * base - 1e-9 <= x <= 2.0 * 1.2 * base + 1e-9 for x in xs)
    assert xs == [PriceSchedule(_spec(), spot_amplitude=0.2)
                  .chip_hour_price(t * 60.0) for t in range(0, 600)]


def test_job_cost_scales_with_chips_and_time():
    ps = PriceSchedule(_spec(price=1.0, peak=1.0, chips=8))
    assert ps.job_cost(0.0, HOUR) == pytest.approx(8.0)
    assert ps.job_cost(0.0, HOUR / 2) == pytest.approx(4.0)


def _trade(n=4):
    d = ResourceDirectory()
    for i in range(n):
        d.register(_spec(f"r{i}", price=1.0 + i, peak=1.0))
    scheds = {f"r{i}": PriceSchedule(d.spec(f"r{i}")) for i in range(n)}
    return TradeServer(d, scheds), d


def test_bids_sorted_by_price():
    trade, d = _trade()
    bids = trade.solicit_bids(0.0, "u", lambda s: 600.0)
    assert [b.chip_hour_price for b in bids] == sorted(
        b.chip_hour_price for b in bids)
    assert all(b.est_rate == pytest.approx(6.0) for b in bids)


def test_reservation_locks_price():
    trade, d = _trade()
    r = trade.reserve("r0", "u", start=0.0, end=10 * HOUR, t=0.0)
    # owner hikes the price later (peak hours) — reserved user keeps it
    locked = trade.effective_price("r0", "u", 12 * HOUR)
    assert locked == pytest.approx(r.locked_price)
    # other users pay the live price
    assert trade.effective_price("r0", "other", 12 * HOUR) >= locked
    assert trade.cancel(r.reservation_id)
    assert trade.reserved_price("r0", "u", 5 * HOUR) is None


def test_directory_authorization_and_filters():
    d = ResourceDirectory()
    d.register(ResourceSpec(name="open", site="a", chips=2))
    d.register(ResourceSpec(name="closed", site="b", chips=8,
                            authorized_users=("alice",)))
    assert [s.name for s in d.discover("bob")] == ["open"]
    assert {s.name for s in d.discover("alice")} == {"closed", "open"}
    assert [s.name for s in d.discover("alice", min_chips=4)] == ["closed"]
    assert [s.name for s in d.discover("alice", site="a")] == ["open"]
    d.status("open").up = False
    assert [s.name for s in d.discover("alice")] == ["closed"]


def test_budget_ledger_commit_settle_cycle():
    led = BudgetLedger(budget=100.0)
    assert led.can_commit(60.0)
    led.commit(60.0)
    assert not led.can_commit(50.0)
    assert led.can_commit(40.0)
    led.settle(60.0, 55.0)          # actual cheaper than committed
    assert led.settled == pytest.approx(55.0)
    assert led.committed == pytest.approx(0.0)
    assert led.remaining == pytest.approx(45.0)
