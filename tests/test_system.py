"""End-to-end behaviour tests for the paper's system: the full Nimrod/G
loop (plan -> farm -> economy-scheduled execution -> results) in both
virtual-time and real-payload modes, plus the dry-run path on a tiny cell.
"""
import jax
import numpy as np
import pytest

from repro.core import (Dispatcher, Journal, JobSpec, LocalExecutor, NimrodG,
                        PriceSchedule, ResourceDirectory, ResourceSpec,
                        SchedulerConfig, SimulatedExecutor, Simulator,
                        TradeServer, UserRequirements, gusto_like_testbed,
                        parse_plan, substitute)

HOUR = 3600.0


def test_full_virtual_experiment(tmp_path):
    """Plan -> 24 jobs -> cost-opt scheduling over a 20-machine grid with
    failures -> all complete within deadline & budget, fully journaled."""
    directory = ResourceDirectory()
    for spec in gusto_like_testbed(20, seed=5):
        directory.register(spec)
    schedules = {n: PriceSchedule(directory.spec(n), spot_amplitude=0.1)
                 for n in directory.all_names()}
    trade = TradeServer(directory, schedules)
    sim = Simulator()
    disp = Dispatcher(SimulatedExecutor(sim, directory, seed=1), directory)
    plan = parse_plan("""
parameter alpha float range from 0.1 to 0.8 step 0.1
parameter mode text select anyof "fast" "slow" "safe"
task main
    copy in.dat node:.
    execute sim --alpha $alpha --mode $mode
    copy node:out.dat res/$jobname
endtask
""")
    assert plan.n_jobs() == 24
    req = UserRequirements(deadline=12 * HOUR, budget=10_000.0,
                           strategy="cost")
    eng = NimrodG.from_plan("e2e", plan, req, directory, trade, disp,
                            est_seconds=lambda p: 1200.0, sim=sim,
                            journal=Journal(str(tmp_path / "j.jsonl")))
    rep = eng.run_simulated()
    assert rep.n_done == 24
    assert rep.met_deadline
    assert rep.within_budget
    assert rep.total_cost > 0


def test_real_payloads_through_the_grid():
    """The dispatcher runs genuine jit'd JAX payloads and returns results
    through the job-wrapper path (LocalExecutor thread grid)."""
    directory = ResourceDirectory()
    directory.register(ResourceSpec(name="w0", site="l", chips=1, slots=2,
                                    mtbf_hours=float("inf")))
    trade = TradeServer(directory, {"w0": PriceSchedule(
        directory.spec("w0"))})
    executor = LocalExecutor(directory, max_workers=2)
    disp = Dispatcher(executor, directory)

    def payload(seed):
        def run():
            x = jax.random.normal(jax.random.PRNGKey(seed), (64, 64))
            return float(jax.jit(lambda a: (a @ a.T).trace())(x))
        return run

    jobs = [JobSpec(job_id=f"j{i}", experiment="real", point={"seed": i},
                    steps=(), est_seconds_base=5.0, payload=payload(i))
            for i in range(4)]
    req = UserRequirements(deadline=1e9, budget=1e9, strategy="time")
    eng = NimrodG("real", jobs, req, directory, trade, disp, sim=None,
                  sched_cfg=SchedulerConfig(interval=0.1))
    rep = eng.run_local(wall_timeout=300.0)
    executor.shutdown()
    assert rep.n_done == 4
    results = [j.result for j in eng.jobs.values()]
    assert all(isinstance(r, float) and np.isfinite(r) for r in results)


def test_dryrun_cell_on_local_device():
    """The dry-run path (lower+compile+roofline) works end to end on a
    reduced config and the local 1x1 mesh."""
    from repro.configs import SMOKE_SHAPE, smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.optim import AdamWConfig
    from repro.roofline import analysis as ra
    from repro.train import steps as steps_mod

    cfg = smoke_config("gemma3-1b")
    mesh = make_local_mesh()
    cs = steps_mod.cell_shardings(cfg, SMOKE_SHAPE, mesh, AdamWConfig())
    fn = steps_mod.make_train_step(cfg, AdamWConfig(), mesh=mesh)
    with mesh:
        lowered = jax.jit(fn).lower(cs["params"], cs["opt"], cs["batch"])
        compiled = lowered.compile()
    cell = ra.cell_from_compiled("gemma3-1b", SMOKE_SHAPE, "1x1", 1, cfg,
                                 compiled)
    assert cell.flops_global > 0
    assert cell.bytes_global > 0
    assert cell.bottleneck in ("compute", "memory", "collective")
    assert 0 < cell.useful_flops_fraction < 10
