"""Per-strategy conformance suite.

Every entry in the strategy registry is run through the same battery —
budget safety, deadline-pressure monotonicity, determinism — by
parametrizing over ``available_strategies()``.  A new strategy gains
this coverage the moment it is ``@register``-ed; nothing here names the
built-in zoo explicitly (the registry-shape test below is the one
exception, and it only asserts a lower bound plus the legacy flags).
"""
import pytest

from conftest import make_spec
from repro.core import (BudgetLedger, MarketUser, Marketplace,
                        ScheduleAdvisor, SchedulerConfig,
                        UserRequirements, available_strategies,
                        strategy_class)
from repro.core.scheduler import ResourceView
from repro.core.strategies import (Strategy, accumulate_rate, create,
                                   register, unregister)

HOUR = 3600.0

ALL_STRATEGIES = available_strategies()


# ---------------------------------------------------------------------------
# fixtures: a deterministic advisor-level grid and tiny shared markets
# ---------------------------------------------------------------------------

def _views(n: int = 8):
    """A fixed heterogeneous grid: varied price, speed and chip count so
    rankings are non-trivial, with deliberately non-monotone quote order."""
    views, prices = {}, {}
    for i in range(n):
        name = f"r{i}"
        spec = make_spec(name, f"s{i % 3}", chips=1 + i % 3,
                         perf=0.5 + 0.25 * i, price=0.5 + 0.3 * i)
        views[name] = ResourceView(spec=spec,
                                   est_job_seconds=900.0 + 200.0 * i)
        prices[name] = 0.4 + 0.35 * ((i * 7) % 5)
    return views, prices


def _advisor(name: str, deadline_h: float = 12.0,
             budget: float = 500.0) -> ScheduleAdvisor:
    return ScheduleAdvisor(
        SchedulerConfig(),
        UserRequirements(deadline=deadline_h * HOUR, budget=budget,
                         strategy=name, user="probe"))


def _market(strategy: str, *, budget: float, seed: int = 0,
            n_jobs: int = 6, **market_kw) -> Marketplace:
    """The strategy under test vs a fixed ``cost`` rival on a small
    shared grid — contention without tournament-scale runtime."""
    market = Marketplace(n_machines=6, seed=seed, **market_kw)
    market.add_user(MarketUser(name="probe", deadline=10.0 * HOUR,
                               budget=budget, strategy=strategy,
                               n_jobs=n_jobs, est_seconds=1200.0))
    market.add_user(MarketUser(name="rival", deadline=12.0 * HOUR,
                               budget=5_000.0, strategy="cost",
                               n_jobs=n_jobs, est_seconds=1200.0))
    return market


def _reconcile(market: Marketplace) -> None:
    market.bank.reconcile({u.name: e.ledger
                           for u, e in zip(market.users, market.engines)})


# ---------------------------------------------------------------------------
# the conformance battery: every registered strategy, same bar
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_STRATEGIES)
class TestStrategyConformance:

    def test_budget_never_exceeded(self, name):
        """A starved broker may stall, but its settled spend never
        crosses the budget line — the ledger guard holds regardless of
        how aggressive the policy is."""
        budget = 35.0
        market = _market(name, budget=budget)
        market.run()
        probe = market.engines[0]
        assert probe.ledger.settled <= budget + 1e-6
        _reconcile(market)

    def test_deadline_pressure_monotone(self, name):
        """Paper Figure 3 as a per-strategy law: shrinking time-to-
        deadline never *reduces* the resource count the policy asks
        for.  (Budget-first policies may plateau; they must not dip.)"""
        views, prices = _views()
        ledger = BudgetLedger(budget=1e6)

        def n_alloc(deadline_h):
            adv = _advisor(name, deadline_h=deadline_h, budget=1e6)
            return len(adv.decide(0.0, views, prices, 60, ledger,
                                  set()).allocate)

        counts = [n_alloc(h) for h in (48.0, 12.0, 3.0, 1.0)]
        assert all(later >= earlier
                   for earlier, later in zip(counts, counts[1:])), counts

    def test_decide_deterministic(self, name):
        """Same advisor, same inputs, same decision — no hidden state
        or iteration-order dependence in the policy."""
        views, prices = _views()
        ledger = BudgetLedger(budget=800.0)
        adv = _advisor(name)
        d1 = adv.decide(0.0, views, prices, 40, ledger, set())
        d2 = adv.decide(0.0, views, prices, 40, ledger, set())
        assert d1.allocate == d2.allocate
        assert d1.release == d2.release
        assert d1.projected_rate == d2.projected_rate
        assert d1.projected_cost_per_job == d2.projected_cost_per_job

    def test_same_seed_market_byte_identical(self, name):
        """Whole-market determinism with every economy hook live
        (auctions, churn, failures, resale) — reruns are byte-equal."""
        rich = dict(release_fee=0.25, resale=True, ask_fraction=0.15,
                    auction_round=1800.0, gis_ttl=900.0)
        run_kw = dict(churn=True, failures=True)
        r1 = _market(name, budget=200.0, seed=4, **rich).run(**run_kw)
        r2 = _market(name, budget=200.0, seed=4, **rich).run(**run_kw)
        assert r1.stable_repr() == r2.stable_repr()


# ---------------------------------------------------------------------------
# registry shape and the commit-guard seam
# ---------------------------------------------------------------------------

def test_registry_holds_the_zoo():
    assert len(ALL_STRATEGIES) >= 6
    assert {"cost", "time", "conservative", "auction", "reputation",
            "adaptive", "scavenger"} <= set(ALL_STRATEGIES)
    legacy = {n for n in ALL_STRATEGIES if strategy_class(n).legacy}
    assert legacy == {"cost", "time", "conservative"}


def test_create_returns_fresh_instances():
    a, b = create("cost"), create("cost")
    assert type(a) is type(b)
    assert a is not b


def test_unknown_strategy_fails_at_build_time():
    with pytest.raises(KeyError, match="unknown strategy"):
        strategy_class("definitely-not-registered")
    # the advisor surfaces the same error at construction, not silently
    # falling through to the cost policy as the old if/elif chain did
    with pytest.raises(KeyError, match="definitely-not-registered"):
        ScheduleAdvisor(SchedulerConfig(),
                        UserRequirements(deadline=HOUR, budget=10.0,
                                         strategy="definitely-not-registered"))


def test_duplicate_name_rejected():
    class Impostor(Strategy):
        name = "cost"

        def select(self, ctx):  # pragma: no cover - never called
            return set()

    with pytest.raises(ValueError, match="already registered"):
        register(Impostor)


def test_conservative_commit_guard_via_advisor():
    """may_commit flows through the strategy: conservative reserves a
    per-unfinished-job budget share, cost only checks the ledger."""
    ledger = BudgetLedger(budget=100.0)
    conservative = _advisor("conservative", budget=100.0)
    assert conservative.may_commit(9.0, 10, ledger)
    assert not conservative.may_commit(11.0, 10, ledger)
    assert _advisor("cost", budget=100.0).may_commit(11.0, 10, ledger)


def test_registration_is_all_it_takes():
    """A brand-new strategy participates in a full market run (and the
    conformance battery, on the next collection) by registration alone —
    no scheduler, marketplace or bench edits."""

    @register
    class EagerToy(Strategy):
        name = "toy-eager"
        description = "cost ranking, double the needed rate"

        def select(self, ctx):
            return accumulate_rate(ctx.ranked, ctx.views,
                                   2.0 * ctx.needed_rate)

    try:
        assert "toy-eager" in available_strategies()
        market = _market("toy-eager", budget=2_000.0)
        report = market.run()
        _reconcile(market)
        probe = next(o for o in report.outcomes if o.user == "probe")
        assert probe.n_done == probe.n_jobs
    finally:
        unregister("toy-eager")
    assert "toy-eager" not in available_strategies()
