import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own
# flag before importing jax; never set it globally here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

from repro.core import (GridBank, GridInformationService, MarketUser,  # noqa: E402
                        Marketplace, PriceSchedule, ResourceDirectory,
                        ResourceSpec, SecondaryMarket, TradeFederation)

HOUR = 3600.0


@pytest.fixture(scope="session")
def local_mesh():
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh()


# ---------------------------------------------------------------------------
# shared grid/market builders — the setup test_gis / test_secondary /
# test_marketplace / test_strategies used to duplicate.  Plain functions
# (importable from conftest for module-level helpers) with fixture
# wrappers below for tests that prefer injection.
# ---------------------------------------------------------------------------

def make_spec(name, site, department="", *, price=1.0, slots=1, chips=1,
              perf=1.0, users=()):
    """A reliable (never-failing, flat-price) resource — the economy
    tests' default, so price/fee arithmetic stays exact."""
    return ResourceSpec(name=name, site=site, department=department,
                        chips=chips, slots=slots, base_price=price,
                        perf_factor=perf, peak_multiplier=1.0,
                        mtbf_hours=float("inf"),
                        authorized_users=tuple(users))


def make_gis(specs, **gis_kw):
    """Directory + information service with every spec registered at
    t=0."""
    directory = ResourceDirectory()
    for s in specs:
        directory.register(s)
    gis = GridInformationService(directory, **gis_kw)
    for s in specs:
        gis.register(s, 0.0)
    return directory, gis


def make_federation(specs, **server_kw):
    """Directory + per-site trade-server federation (flat schedules)."""
    directory = ResourceDirectory()
    for s in specs:
        directory.register(s)
    schedules = {n: PriceSchedule(directory.spec(n))
                 for n in directory.all_names()}
    fed = TradeFederation.from_directory(directory, schedules, **server_kw)
    return directory, fed


def make_secondary(fed, bank=None, **kw):
    """A resale-enabled secondary market with the tests' default fees."""
    kw.setdefault("release_fee", 0.25)
    kw.setdefault("resale", True)
    kw.setdefault("ask_fraction", 0.2)
    return SecondaryMarket(fed, bank if bank is not None else GridBank(),
                           **kw)


def tight_specs(n=3, slots=1, perf=1.0):
    """A deliberately scarce grid: n reliable identical machines."""
    return [make_spec(f"m{i}", "x", slots=slots, chips=1, perf=perf)
            for i in range(n)]


def crowded_market(n_users=6, n_machines=3, seed=0, n_jobs=8,
                   sched=None, **kw):
    """More brokers than slots: the contention scenario."""
    market = Marketplace(specs=tight_specs(n_machines), seed=seed, **kw)
    for i in range(n_users):
        market.add_user(MarketUser(
            name=f"u{i}", deadline=30 * HOUR, budget=1e6,
            strategy=("cost", "time")[i % 2], n_jobs=n_jobs,
            est_seconds=1200.0), sched_cfg=sched)
    return market


@pytest.fixture
def spec_factory():
    return make_spec


@pytest.fixture
def gis_factory():
    return make_gis


@pytest.fixture
def federation_factory():
    return make_federation


@pytest.fixture
def secondary_factory():
    return make_secondary


@pytest.fixture
def crowded_market_factory():
    return crowded_market
