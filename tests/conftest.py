import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own
# flag before importing jax; never set it globally here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def local_mesh():
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh()
