"""Fault-tolerance integration: training checkpoint/restart equivalence +
grid-level failure recovery + elastic re-meshing helpers."""
import os

import jax
import numpy as np
import pytest

from repro.launch.train import run_training


def test_train_restart_bit_exact(tmp_path):
    """Crash/restart at step 10 must produce the same final loss as an
    uninterrupted run (deterministic data + exact state restore)."""
    arch = "stablelm-1.6b"
    d1 = str(tmp_path / "run_once")
    r_full = run_training(arch, smoke=True, steps=20, batch=2, seq=32,
                          ckpt_dir=None, verbose=False, seed=3)

    d2 = str(tmp_path / "run_twice")
    run_training(arch, smoke=True, steps=10, batch=2, seq=32,
                 ckpt_dir=d2, ckpt_every=10, verbose=False, seed=3)
    r_resumed = run_training(arch, smoke=True, steps=20, batch=2, seq=32,
                             ckpt_dir=d2, ckpt_every=10, verbose=False,
                             seed=3)
    assert r_resumed.restored_from is not None
    np.testing.assert_allclose(r_resumed.final_loss, r_full.final_loss,
                               rtol=1e-4)


def test_quantized_moments_train(tmp_path):
    """int8 Adam moments (ZeRO-memory trick) still converge."""
    r = run_training("gemma3-1b", smoke=True, steps=12, batch=2, seq=32,
                     quantized_moments=True, verbose=False, lr=3e-3)
    assert np.isfinite(r.final_loss)
    assert r.final_loss < r.losses[0]


def test_elastic_mesh_helper():
    from repro.launch.mesh import make_mesh_for
    m = make_mesh_for(1)
    assert m.devices.size == 1
    assert set(m.axis_names) == {"data", "model"}
