"""Serving integration: prefill+decode consistency vs full forward,
per-arch cache correctness (ring buffers, MLA latents, recurrent states)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, smoke_config
from repro.models import transformer as tfm

DECODE_ARCHS = ARCH_IDS  # all ten are decoders


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_then_decode_matches_full_forward(arch, local_mesh):
    """Logits for position t from incremental decode must match the
    full-sequence forward (the cache correctness law)."""
    cfg = smoke_config(arch).replace(attn_impl="reference")
    key = jax.random.PRNGKey(3)
    params = tfm.init_model(cfg, key)
    B, S_p, S_total = 2, 8, 12
    if cfg.input_kind == "tokens":
        toks = jax.random.randint(key, (B, S_total), 0, cfg.vocab_size)
        full_batch = {"tokens": toks}
        pre_batch = {"tokens": toks[:, :S_p]}
        step_in = lambda t: {"tokens": toks[:, t:t + 1]}
    else:
        emb = jax.random.normal(key, (B, S_total, cfg.d_model))
        full_batch = {"embeds": emb}
        pre_batch = {"embeds": emb[:, :S_p]}
        step_in = lambda t: {"embeds": emb[:, t:t + 1]}

    # ground truth: full forward
    logits_full, _, _ = tfm.forward(cfg, params, full_batch, mode="train",
                                    mesh=local_mesh)

    # prefill + decode
    cache = tfm.init_cache(cfg, B, S_total)
    logits_pre, cache, _ = tfm.forward(cfg, params, pre_batch, mode="prefill",
                                       cache=cache, mesh=local_mesh)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1]), np.asarray(logits_full[:, S_p - 1]),
        atol=2e-2, rtol=2e-2)

    for t in range(S_p, S_total):
        logits_t, cache, _ = tfm.forward(cfg, params, step_in(t),
                                         mode="decode", cache=cache,
                                         mesh=local_mesh)
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0]), np.asarray(logits_full[:, t]),
            atol=2e-2, rtol=2e-2,
            err_msg=f"{arch}: decode step {t} diverged from full forward")


@pytest.mark.parametrize("arch", ["gemma3-1b", "recurrentgemma-2b"])
def test_local_ring_buffer_eviction(arch, local_mesh):
    """Sequences longer than the window still decode correctly (ring
    eviction must keep exactly the last W keys)."""
    cfg = smoke_config(arch).replace(attn_impl="reference")
    W = cfg.window_size
    assert W and W <= 16
    key = jax.random.PRNGKey(5)
    params = tfm.init_model(cfg, key)
    B, S_total = 1, W + 6   # forces eviction
    toks = jax.random.randint(key, (B, S_total), 0, cfg.vocab_size)
    logits_full, _, _ = tfm.forward(cfg, params, {"tokens": toks},
                                    mode="train", mesh=local_mesh)
    cache = tfm.init_cache(cfg, B, S_total)
    _, cache, _ = tfm.forward(cfg, params, {"tokens": toks[:, :2]},
                              mode="prefill", cache=cache, mesh=local_mesh)
    for t in range(2, S_total):
        logits_t, cache, _ = tfm.forward(cfg, params,
                                         {"tokens": toks[:, t:t + 1]},
                                         mode="decode", cache=cache,
                                         mesh=local_mesh)
    np.testing.assert_allclose(
        np.asarray(logits_t[:, 0]), np.asarray(logits_full[:, -1]),
        atol=2e-2, rtol=2e-2)


def test_serve_driver_end_to_end():
    from repro.launch.serve import serve_batch
    r = serve_batch("stablelm-1.6b", batch=2, prompt_len=16, gen=6,
                    verbose=False)
    assert r.tokens.shape == (2, 6)
    assert np.isfinite(r.tokens).all()
