"""GRACE auction house: double-auction clearing, contract-net tenders,
cross-domain arbitrage, owner revenue accounting (paper §7 + cs/0111048).
"""
import math

import pytest

from repro.core import (AuctionBid, AuctionBroker, AuctionHouse,
                        BudgetLedger, GridBank, Marketplace, MarketUser,
                        NegotiationTimeout, PriceSchedule,
                        ReconciliationError, ResourceDirectory,
                        ResourceSpec, TradeFederation, TradeServer,
                        mixed_auction_market)

HOUR = 3600.0


def _spec(name, site, price, slots=1, chips=1, perf=1.0):
    return ResourceSpec(name=name, site=site, chips=chips, slots=slots,
                        perf_factor=perf, base_price=price,
                        peak_multiplier=1.0, mtbf_hours=float("inf"))


def _grid(specs, **server_kw):
    d = ResourceDirectory()
    for s in specs:
        d.register(s)
    schedules = {n: PriceSchedule(d.spec(n)) for n in d.all_names()}
    fed = TradeFederation.from_directory(d, schedules, **server_kw)
    return d, fed


# ---------------------------------------------------------------------------
# double-auction clearing properties
# ---------------------------------------------------------------------------

def test_uniform_clearing_price_within_bid_ask_bounds():
    """All matched units trade at ONE price that no matched bidder finds
    too high and no matched owner finds too low."""
    d, fed = _grid([_spec("m0", "X", 0.8), _spec("m1", "X", 1.2),
                    _spec("m2", "X", 4.0)])
    house = AuctionHouse(fed, idle_discount=0.25)
    house.submit_bid("X", AuctionBid(user="alice", chip_hour_price=1.0,
                                     slots=2, valid_until=10.0))
    house.submit_bid("X", AuctionBid(user="bob", chip_hour_price=0.5,
                                     slots=1, valid_until=10.0))
    struck = house.clear_all(0.0)
    # idle asks: 0.6 (m0), 0.9 (m1), 3.0 (m2); bid units 1.0,1.0,0.5 —
    # exactly alice's two units cross, at the (1.0 + 0.9)/2 midpoint
    assert len(struck) == 2
    price = struck[0].chip_hour_price
    assert price == pytest.approx(0.95)
    assert all(c.chip_hour_price == price for c in struck)      # uniform
    assert all(c.user == "alice" for c in struck)
    assert {c.resource for c in struck} == {"m0", "m1"}
    # within every matched party's limits
    assert price <= 1.0 + 1e-12          # alice's limit
    assert price >= 0.75 * 1.2 - 1e-12   # marginal ask (m1 idle)
    # the lock is live on the owning trade server at the struck price
    assert fed.reserved_price("m0", "alice", HOUR) == pytest.approx(price)
    assert fed.effective_price("m1", "alice", HOUR) == pytest.approx(price)
    # rivals still pay the posted quote
    assert fed.effective_price("m0", "bob", HOUR) == pytest.approx(0.8)


def test_no_cross_no_contract():
    """Bids below every ask clear nothing (and price stays zero)."""
    d, fed = _grid([_spec("m0", "X", 2.0)])
    house = AuctionHouse(fed)
    house.submit_bid("X", AuctionBid(user="cheapskate",
                                     chip_hour_price=0.1, slots=3,
                                     valid_until=10.0))
    assert house.clear_all(0.0) == []
    assert house.rounds[-1].matched_slots == 0


def test_expired_bids_are_ignored_and_books_clear_each_round():
    d, fed = _grid([_spec("m0", "X", 1.0)])
    house = AuctionHouse(fed)
    house.submit_bid("X", AuctionBid(user="late", chip_hour_price=9.0,
                                     slots=1, valid_until=5.0))
    assert house.clear_all(100.0) == []          # bid long dead
    # book drained: nothing lingers into the next round either
    assert house.clear_all(200.0) == []


def test_contracted_commitments_never_exceed_budget():
    """The broker caps its bid so worst-case contracted slot-hours stay
    inside the remaining budget, round after round."""
    d, fed = _grid([_spec(f"m{i}", "X", 1.0, chips=4) for i in range(6)])
    house = AuctionHouse(fed, round_interval=HOUR, window=2 * HOUR)
    ledger = BudgetLedger(budget=30.0)
    broker = AuctionBroker(house, "alice")
    est = {f"m{i}": 1800.0 for i in range(6)}
    t = 0.0
    for _ in range(5):
        broker.step(t, est, remaining_jobs=100, ledger=ledger)
        house.clear_all(t)
        committed = house.outstanding_commitment("alice", t)
        assert committed <= ledger.budget + 1e-9
        assert committed <= ledger.remaining + 1e-9
        t += HOUR
    assert broker.contracts                      # it did trade


def test_broke_broker_places_no_bid():
    d, fed = _grid([_spec("m0", "X", 1.0, chips=8)])
    house = AuctionHouse(fed)
    broker = AuctionBroker(house, "poor")
    bid = broker.step(0.0, {"m0": 1800.0}, remaining_jobs=10,
                      ledger=BudgetLedger(budget=0.01))
    assert bid is None
    assert house.clear_all(0.0) == []


# ---------------------------------------------------------------------------
# contract-net / tender negotiation
# ---------------------------------------------------------------------------

def test_tender_counter_offers_sorted_across_domains():
    d, fed = _grid([_spec("a0", "ANL", 3.0), _spec("i0", "ISI", 1.0),
                    _spec("i1", "ISI", 2.0)])
    house = AuctionHouse(fed, tender_discount=0.2)
    offers = house.call_for_tenders(0.0, "u")
    prices = [o.chip_hour_price for o in offers]
    assert prices == sorted(prices)
    assert offers[0].resource == "i0"            # cheap domain leads
    assert offers[0].chip_hour_price == pytest.approx(0.8)   # 20% off idle


def test_tender_accept_within_window_locks_offer_price():
    d, fed = _grid([_spec("m0", "X", 2.0)])
    house = AuctionHouse(fed, tender_discount=0.25,
                         tender_validity=0.5 * HOUR)
    offer = house.call_for_tenders(0.0, "u")[0]
    c = house.accept(offer, "u", t=600.0)        # well inside validity
    assert c.via == "tender"
    assert c.chip_hour_price == pytest.approx(1.5)
    assert fed.effective_price("m0", "u", HOUR) == pytest.approx(1.5)


def test_tender_acceptance_after_timeout_forces_resolicit():
    """The negotiation timeout path: a stale counter-offer cannot be
    exercised; the broker must go back to the market."""
    d, fed = _grid([_spec("m0", "X", 2.0)])
    house = AuctionHouse(fed, tender_validity=0.5 * HOUR)
    offer = house.call_for_tenders(0.0, "u")[0]
    with pytest.raises(NegotiationTimeout):
        house.accept(offer, "u", t=HOUR)         # validity long gone
    assert house.contracts == []                 # nothing was struck
    fresh = house.call_for_tenders(HOUR, "u")    # re-solicit works
    assert fresh and fresh[0].valid_until == pytest.approx(1.5 * HOUR)
    assert house.accept(fresh[0], "u", t=HOUR).slots >= 1


# ---------------------------------------------------------------------------
# cross-domain arbitrage
# ---------------------------------------------------------------------------

def _two_site_market(seed=0):
    """CHEAP's machines undercut DEAR's five-fold, same hardware."""
    specs = ([_spec(f"c{i}", "CHEAP", 0.5, chips=1) for i in range(3)]
             + [_spec(f"d{i}", "DEAR", 2.5, chips=1) for i in range(3)])
    market = Marketplace(specs=specs, seed=seed, demand_elasticity=0.5)
    market.add_user(MarketUser(name="arb", deadline=30 * HOUR, budget=1e6,
                               strategy="auction", n_jobs=8,
                               est_seconds=1200.0))
    return market


def test_arbitrage_routes_jobs_and_contracts_to_cheap_domain():
    market = _two_site_market()
    rep = market.run()
    assert rep.total_done == rep.total_jobs
    # the auction broker steered its bids at the cheap domain only
    assert all(c.site == "CHEAP" for c in market.auction_house.contracts)
    # and the money followed: the dear domain earned nothing
    assert market.bank.owner_revenue("CHEAP") > 0.0
    assert market.bank.owner_revenue("DEAR") == 0.0
    assert len(market.trade.servers) == 2        # genuinely two books


def test_federation_reservation_ids_unique_across_sites():
    d, fed = _grid([_spec("a0", "A", 1.0), _spec("b0", "B", 1.0)])
    ra = fed.reserve("a0", "u", 0.0, HOUR, 0.0)
    rb = fed.reserve("b0", "u", 0.0, HOUR, 0.0)
    assert ra.reservation_id != rb.reservation_id
    # cancelling one never touches the other domain's book
    assert fed.cancel(ra.reservation_id)
    assert fed.reserved_price("b0", "u", 10.0) is not None


# ---------------------------------------------------------------------------
# whole-market runs: determinism, settlement, accounting
# ---------------------------------------------------------------------------

def test_mixed_market_is_seed_deterministic():
    r1 = mixed_auction_market(6, n_machines=10, seed=7, n_jobs=8).run()
    r2 = mixed_auction_market(6, n_machines=10, seed=7, n_jobs=8).run()
    assert r1.stable_repr() == r2.stable_repr()
    assert any(o.strategy == "auction" for o in r1.outcomes)
    r3 = mixed_auction_market(6, n_machines=10, seed=8, n_jobs=8).run()
    assert r1.stable_repr() != r3.stable_repr()


def test_bank_reconciles_owner_revenue_with_broker_spend():
    market = mixed_auction_market(6, n_machines=10, seed=5, n_jobs=8)
    rep = market.run()
    ledgers = {u.name: e.ledger for u, e in zip(market.users,
                                                market.engines)}
    total = market.bank.reconcile(ledgers)
    assert total == pytest.approx(
        math.fsum(market.bank.owner_revenue(o)
                  for o in market.bank.owners()))
    assert total == pytest.approx(
        math.fsum(l.settled for l in ledgers.values()))
    assert rep.owner_revenue                     # surfaced in the report


def test_bank_reconcile_catches_tampering():
    bank = GridBank()
    bank.record(t=0.0, user="u", owner="X", resource="m0", amount=5.0)
    led = BudgetLedger(budget=10.0)
    led.settle(0.0, 5.0)
    bank.reconcile({"u": led})                   # balanced: fine
    led.settle(0.0, 1.0)                         # spend the bank never saw
    with pytest.raises(ReconciliationError):
        bank.reconcile({"u": led})


def test_finished_brokers_withdraw_their_bids():
    market = _two_site_market(seed=1)
    market.run()
    assert all(not book.bids for book in market.auction_house.books.values())


def test_contract_discount_covers_only_reserved_slots():
    """One contracted slot must not discount the whole queue: dispatches
    beyond the contracted draw-down pay spot."""
    from repro.core import (Dispatcher, JobSpec, NimrodG, SchedulerConfig,
                            SimulatedExecutor, Simulator, TradeServer,
                            UserRequirements)
    d = ResourceDirectory()
    d.register(_spec("big", "X", 1.0, slots=4))
    trade = TradeServer(d, {"big": PriceSchedule(d.spec("big"))})
    # negotiated contract: ONE slot at a quarter of the posted price
    trade.reserve("big", "u", start=0.0, end=10 * HOUR, t=0.0,
                  locked_price=0.25)
    sim = Simulator()
    ex = SimulatedExecutor(sim, d, noise_sigma=0.0)
    jobs = [JobSpec(job_id=f"j{i}", experiment="e", point={}, steps=(),
                    est_seconds_base=1800.0, stage_in_bytes=0,
                    stage_out_bytes=0) for i in range(4)]
    req = UserRequirements(deadline=20 * HOUR, budget=1e6, user="u")
    eng = NimrodG("e", jobs, req, d, trade, Dispatcher(ex, d), sim=sim,
                  sched_cfg=SchedulerConfig())
    rep = eng.run_simulated(failures=False)
    assert rep.n_done == 4
    # 4 concurrent half-hour jobs on 1 chip: 1 at the contracted 0.25,
    # the other 3 at the posted 1.0 — not 4 x 0.25
    assert rep.total_cost == pytest.approx(0.5 * (0.25 + 3 * 1.0))


def test_withdraw_releases_unexpired_contract_capacity():
    d, fed = _grid([_spec("m0", "X", 1.0)])
    house = AuctionHouse(fed)
    broker = AuctionBroker(house, "quitter")
    house.submit_bid("X", AuctionBid(user="quitter", chip_hour_price=2.0,
                                     slots=1, valid_until=10.0))
    house.clear_all(0.0)
    assert broker.contracts
    server = fed.servers["X"]
    assert server.reservable_slots("m0", 0.0, HOUR) == 0   # capacity held
    broker.withdraw(t=0.0)                                 # leaves early
    assert server.reservable_slots("m0", 0.0, HOUR) == 1   # freed for rivals


def test_negotiate_contract_requotes_expired_sealed_bids():
    """A user who deliberates past the sealed bids' validity signs at
    the live price, not the stale one."""
    from repro.core import (ResourceView, TradeServer, UserRequirements,
                            negotiate_contract)
    d = ResourceDirectory()
    d.register(ResourceSpec(name="r0", site="s", chips=1, base_price=1.0,
                            peak_multiplier=4.0, mtbf_hours=float("inf")))
    trade = TradeServer(d, {"r0": PriceSchedule(d.spec("r0"))},
                        bid_validity=HOUR)
    views = {"r0": ResourceView(spec=d.spec("r0"), est_job_seconds=600.0)}
    req = UserRequirements(deadline=30 * HOUR, budget=1e6, user="u")
    t = 2 * HOUR                                 # 02:00: off-peak, quote 1.0
    prompt = negotiate_contract(t, req, 10, trade, views, accept=True,
                                accept_at=t + 0.5 * HOUR)   # inside validity
    assert trade.reservations[0].locked_price == pytest.approx(1.0)
    for rid in prompt.reserved:
        trade.cancel(rid)
    lazy = negotiate_contract(t, req, 10, trade, views, accept=True,
                              accept_at=9 * HOUR)   # expired; 09:00 is peak
    assert trade.reservations[0].locked_price == pytest.approx(4.0)


def test_overlapping_contracts_each_bill_their_own_price():
    """Two live contracts at different prices on one resource: each
    reserved slot prices exactly one concurrent job; the rest pay spot."""
    from repro.core import (Dispatcher, JobSpec, NimrodG, SchedulerConfig,
                            SimulatedExecutor, Simulator, TradeServer,
                            UserRequirements)
    d = ResourceDirectory()
    d.register(_spec("big", "X", 1.0, slots=4))
    trade = TradeServer(d, {"big": PriceSchedule(d.spec("big"))})
    trade.reserve("big", "u", 0.0, 10 * HOUR, 0.0, locked_price=0.25)
    trade.reserve("big", "u", 0.0, 10 * HOUR, 0.0, locked_price=0.5)
    sim = Simulator()
    ex = SimulatedExecutor(sim, d, noise_sigma=0.0)
    jobs = [JobSpec(job_id=f"j{i}", experiment="e", point={}, steps=(),
                    est_seconds_base=1800.0, stage_in_bytes=0,
                    stage_out_bytes=0) for i in range(4)]
    req = UserRequirements(deadline=20 * HOUR, budget=1e6, user="u")
    eng = NimrodG("e", jobs, req, d, trade, Dispatcher(ex, d), sim=sim,
                  sched_cfg=SchedulerConfig())
    rep = eng.run_simulated(failures=False)
    assert rep.n_done == 4
    # half-hour jobs on 1 chip: one at 0.25, one at 0.5, two at spot 1.0
    assert rep.total_cost == pytest.approx(0.5 * (0.25 + 0.5 + 2 * 1.0))


def test_auction_never_contracts_unauthorized_resources():
    """Asks are user-agnostic, so authorization is enforced at signing:
    a stranger's matched bid dies instead of locking a restricted
    machine, and tenders never offer it in the first place."""
    d = ResourceDirectory()
    d.register(ResourceSpec(name="vip", site="X", chips=1, base_price=1.0,
                            peak_multiplier=1.0, mtbf_hours=float("inf"),
                            authorized_users=("alice",)))
    schedules = {"vip": PriceSchedule(d.spec("vip"))}
    fed = TradeFederation.from_directory(d, schedules)
    house = AuctionHouse(fed)
    house.submit_bid("X", AuctionBid(user="mallory", chip_hour_price=9.0,
                                     slots=1, valid_until=10.0))
    assert house.clear_all(0.0) == []            # matched, refused at sign
    assert fed.servers["X"].reservable_slots("vip", 0.0, HOUR) == 1
    assert house.call_for_tenders(0.0, "mallory") == []
    offers = house.call_for_tenders(0.0, "alice")
    assert [o.resource for o in offers] == ["vip"]


def test_federating_used_servers_never_rewinds_reservation_ids():
    """Wrapping servers that already issued reservations must not
    recycle their ids (cancel would hit the wrong domain's book)."""
    d = ResourceDirectory()
    for name, site in (("a0", "A"), ("b0", "B")):
        d.register(_spec(name, site, 1.0))
    sa = TradeServer(d, {"a0": PriceSchedule(d.spec("a0"))}, site="A")
    sb = TradeServer(d, {"b0": PriceSchedule(d.spec("b0"))}, site="B")
    pre = sa.reserve("a0", "u", 0.0, HOUR, 0.0)   # rid 1, pre-federation
    fed = TradeFederation({"A": sa, "B": sb})
    post_a = fed.reserve("a0", "v", 2 * HOUR, 3 * HOUR, 0.0)
    post_b = fed.reserve("b0", "w", 0.0, HOUR, 0.0)
    rids = {pre.reservation_id, post_a.reservation_id,
            post_b.reservation_id}
    assert len(rids) == 3                         # all distinct
    assert fed.cancel(post_b.reservation_id)
    assert fed.reserved_price("a0", "u", 0.5 * HOUR) is not None  # untouched


def test_remove_server_recomputes_federation_bid_validity():
    """Churn regression: after the longest-validity domain leaves, the
    federation must stop honoring sealed bids for the departed site's
    window — ``bid_validity`` is recomputed over LIVE members on
    removal, exactly as ``add_server`` recomputes it on (re)join."""
    d = ResourceDirectory()
    for name, site in (("a0", "A"), ("b0", "B")):
        d.register(_spec(name, site, 1.0))
    sa = TradeServer(d, {"a0": PriceSchedule(d.spec("a0"))}, site="A",
                     bid_validity=HOUR)
    sb = TradeServer(d, {"b0": PriceSchedule(d.spec("b0"))}, site="B",
                     bid_validity=6 * HOUR)
    fed = TradeFederation({"A": sa, "B": sb})
    assert fed.bid_validity == pytest.approx(6 * HOUR)
    fed.remove_server("B")                       # longest validity churns out
    assert fed.bid_validity == pytest.approx(HOUR)
    # rejoin with a FRESH short-validity server: still the live max
    sb2 = TradeServer(d, {"b0": PriceSchedule(d.spec("b0"))}, site="B",
                      bid_validity=0.5 * HOUR)
    fed.add_server("B", sb2)
    assert fed.bid_validity == pytest.approx(HOUR)
    fed.remove_server("A")                       # only sb2 (0.5h) remains
    assert fed.bid_validity == pytest.approx(0.5 * HOUR)
    # removing the LAST server must not blow up (max over empty): the
    # final window simply stops shrinking
    fed.remove_server("B")
    assert fed.bid_validity == pytest.approx(0.5 * HOUR)


def test_realized_revenue_extends_patron_reservation_quota():
    """Admission driven by realized revenue: an owner grants proven
    patrons extra reservation quota that strangers don't get."""
    bank = GridBank()
    d, fed = _grid([_spec(f"m{i}", "X", 1.0) for i in range(4)],
                   max_reservations_per_user=1, bank=bank,
                   patron_spend_threshold=10.0, patron_quota_bonus=2)
    from repro.core import AdmissionError
    fed.reserve("m0", "stranger", 0.0, HOUR, 0.0)
    with pytest.raises(AdmissionError):
        fed.reserve("m1", "stranger", 0.0, HOUR, 0.0)   # base quota: 1
    bank.record(t=0.0, user="patron", owner="X", resource="m0", amount=25.0)
    fed.reserve("m1", "patron", 0.0, HOUR, 0.0)
    fed.reserve("m2", "patron", 0.0, HOUR, 0.0)
    fed.reserve("m3", "patron", 0.0, HOUR, 0.0)         # 1 + bonus 2
    with pytest.raises(AdmissionError):
        fed.reserve("m0", "patron", 2 * HOUR, 3 * HOUR, 0.0)


def test_auction_broker_in_contention_still_finishes():
    """Auction users mixed with posted-price rivals on a scarce grid:
    everyone completes, contracts only ever cover reservable capacity."""
    specs = [_spec(f"m{i}", "X" if i % 2 else "Y", 1.0 + 0.5 * i)
             for i in range(4)]
    market = Marketplace(specs=specs, seed=3, demand_elasticity=1.0)
    for i in range(5):
        market.add_user(MarketUser(
            name=f"u{i}", deadline=40 * HOUR, budget=1e5,
            strategy=("auction", "cost")[i % 2], n_jobs=6,
            est_seconds=1500.0))
    rep = market.run()
    assert rep.total_done == rep.total_jobs, rep.summary()
    for c in market.auction_house.contracts:
        assert c.slots <= market.directory.spec(c.resource).slots
