"""The declarative parametric plan language."""
import pytest

from repro.core.plan import PlanError, parse_plan, substitute


GOOD = """
# ionization study
parameter angle float range from 0.5 to 2.0 step 0.5
parameter mesh integer range from 1 to 3 step 1
parameter solver text select anyof "cg" "gmres"
parameter tag text default "v1"
task main
    copy model.bin node:.
    execute sim --angle $angle --mesh $mesh --solver $solver --tag ${tag}
    copy node:out.dat results/$jobname.dat
endtask
"""


def test_parse_and_cross_product():
    p = parse_plan(GOOD)
    assert [q.name for q in p.parameters] == ["angle", "mesh", "solver", "tag"]
    assert p.parameters[0].values == (0.5, 1.0, 1.5, 2.0)
    assert p.parameters[1].values == (1, 2, 3)
    assert p.parameters[2].values == ("cg", "gmres")
    assert p.n_jobs() == 4 * 3 * 2 * 1
    pts = p.points()
    assert len(pts) == 24
    assert pts[0] == {"angle": 0.5, "mesh": 1, "solver": "cg", "tag": "v1"}
    assert len({tuple(sorted(pt.items())) for pt in pts}) == 24  # unique


def test_substitution():
    p = parse_plan(GOOD)
    step = p.task[1]
    out = substitute(step, {"angle": 0.5, "mesh": 2, "solver": "cg",
                            "tag": "v1"}, "j00001")
    assert "--angle 0.5" in " ".join(out.args)
    assert "${tag}" not in " ".join(out.args)
    out2 = substitute(p.task[2], {"angle": 1.0, "mesh": 1, "solver": "cg",
                                  "tag": "v1"}, "j00042")
    assert out2.args[-1] == "results/j00042.dat"


def test_stage_direction_detection():
    p = parse_plan(GOOD)
    assert p.task[0].is_stage_in
    assert p.task[2].is_stage_out
    assert not p.task[1].is_stage_in


@pytest.mark.parametrize("bad,msg", [
    ("task main\nexecute x\nendtask", "no parameters"),
    ("parameter a float range from 0 to 1 step 0.5", "no task"),
    ("parameter a float range from 0 to 1 step -1\ntask t\nexecute x\nendtask",
     "step must be positive"),
    ("parameter a blob default 3\ntask t\nexecute x\nendtask", "unknown type"),
    ("parameter a float default 1\nparameter a float default 2\n"
     "task t\nexecute x\nendtask", "duplicate"),
    ("parameter a float default 1\ntask t\nexecute x", "unterminated"),
    ("parameter a float default 1\nfrobnicate\ntask t\nexecute x\nendtask",
     "unknown directive"),
])
def test_parse_errors(bad, msg):
    with pytest.raises(PlanError, match=msg):
        parse_plan(bad)


def test_undefined_variable_raises():
    p = parse_plan(GOOD)
    with pytest.raises(PlanError, match="undefined"):
        substitute(p.task[1], {"angle": 1.0}, "j0")
