"""Journal + exact experiment restart (the paper's persistence contract)."""
import json
import os

import pytest

from repro.core import (Dispatcher, Journal, NimrodG, PriceSchedule,
                        ResourceDirectory, SchedulerConfig, SimulatedExecutor,
                        Simulator, TradeServer, UserRequirements,
                        gusto_like_testbed, load_events, parse_plan)

HOUR = 3600.0

PLAN = """
parameter i integer range from 1 to 20 step 1
task main
    execute run --i $i
endtask
"""


def _build(tmp_path, journal_name="journal.jsonl", horizon_stop=None,
           seed=0):
    directory = ResourceDirectory()
    for spec in gusto_like_testbed(10, seed=2):
        directory.register(spec)
    schedules = {n: PriceSchedule(directory.spec(n))
                 for n in directory.all_names()}
    trade = TradeServer(directory, schedules)
    sim = Simulator()
    ex = SimulatedExecutor(sim, directory, seed=seed)
    disp = Dispatcher(ex, directory)
    req = UserRequirements(deadline=20 * HOUR, budget=1e5, strategy="cost")
    journal = Journal(str(tmp_path / journal_name))
    eng = NimrodG.from_plan("restartable", parse_plan(PLAN), req, directory,
                            trade, disp, est_seconds=lambda p: 1800.0,
                            sim=sim, journal=journal, seed=seed)
    return eng, sim


def test_journal_records_lifecycle(tmp_path):
    eng, sim = _build(tmp_path)
    rep = eng.run_simulated(failures=False)
    assert rep.n_done == 20
    events = load_events(str(tmp_path / "journal.jsonl"))
    kinds = [e["kind"] for e in events]
    assert kinds[0] == "EXP_CREATED"
    assert kinds.count("JOB_CREATED") == 20
    assert kinds.count("DONE") >= 20
    assert "EXP_DONE" in kinds
    assert kinds.count("DISPATCH") >= 20
    # every DONE has a matching DISPATCH
    dispatched = {e["job_id"] for e in events if e["kind"] == "DISPATCH"}
    done = {e["job_id"] for e in events if e["kind"] == "DONE"}
    assert done <= dispatched


def test_restart_resumes_not_repeats(tmp_path):
    # phase 1: run the experiment but kill it (stop sim) partway
    eng, sim = _build(tmp_path)
    eng.sim.after(0.0, eng.tick)
    sim.run(until=2.2 * HOUR)       # "node running Nimrod goes down"
    done_before = sum(1 for e in load_events(str(tmp_path / "journal.jsonl"))
                      if e["kind"] == "DONE")
    assert 0 < done_before < 20
    eng.journal.close()

    # phase 2: new engine (fresh process), restore from the journal
    eng2, sim2 = _build(tmp_path, journal_name="journal2.jsonl")
    recovered = eng2.restore_from(str(tmp_path / "journal.jsonl"))
    assert recovered == done_before
    rep = eng2.run_simulated(failures=False)
    assert rep.n_done == 20
    # the restarted engine only ran the remainder
    redone = sum(1 for e in load_events(str(tmp_path / "journal2.jsonl"))
                 if e["kind"] == "DONE")
    assert redone == 20 - recovered
    # spend carried over
    assert rep.total_cost >= eng2.ledger.settled - 1e-9


def test_torn_tail_line_is_ignored(tmp_path):
    p = tmp_path / "j.jsonl"
    with Journal(str(p)) as j:
        j.append("EXP_CREATED", n_jobs=1, deadline=1.0, budget=1.0,
                 strategy="cost", user="u")
        j.append("DONE", job_id="j00000", cost=2.5)
    with open(p, "a") as f:
        f.write('{"kind": "DONE", "job_id": "j00001", "co')  # torn write
    events = load_events(str(p))
    assert len(events) == 2
    st = NimrodG.replay_journal(str(p))
    assert st["done"] == {"j00000": 2.5}
    assert st["spent"] == 2.5


def test_duplicate_done_events_counted_once(tmp_path):
    p = tmp_path / "j.jsonl"
    with Journal(str(p)) as j:
        j.append("DONE", job_id="j00000", cost=2.0)
        j.append("DONE", job_id="j00000~1", cost=1.0)   # duplicate attempt
    st = NimrodG.replay_journal(str(p))
    assert st["done"] == {"j00000": 2.0}
    assert st["spent"] == 2.0


def test_journal_seq_monotonic_across_reopen(tmp_path):
    p = str(tmp_path / "j.jsonl")
    with Journal(p) as j:
        j.append("A")
        j.append("B")
    with Journal(p) as j:
        j.append("C")
    seqs = [e["seq"] for e in load_events(p)]
    assert seqs == [0, 1, 2]


def test_reopen_large_journal_reads_only_the_tail(tmp_path):
    # seq recovery must be O(tail), not O(file): build a journal far
    # larger than the tail window and prove reopen never reads most of
    # it (a read-counting file object would be invasive; instead bound
    # wall work by checking the recovered seq is exact and the torn-
    # tail clip logic leaves earlier bytes untouched)
    import json as _json

    from repro.core.persistence import _TAIL_BLOCK, _recover_tail

    p = str(tmp_path / "big.jsonl")
    n = 50_000
    with open(p, "w") as f:
        for i in range(n):
            f.write(_json.dumps({"seq": i, "kind": "E",
                                 "pad": "x" * 64}) + "\n")
    size = os.path.getsize(p)
    assert size > 20 * _TAIL_BLOCK      # genuinely larger than one block
    assert _recover_tail(p) == n
    with Journal(p) as j:
        ev = j.append("NEXT")
    assert ev["seq"] == n
    assert os.path.getsize(p) > size    # append-only: nothing rewritten


def test_reopen_after_torn_tail_recovers_seq_and_clips_fragment(tmp_path):
    p = str(tmp_path / "j.jsonl")
    with Journal(p) as j:
        for _ in range(5):
            j.append("E")
    with open(p, "a") as f:
        f.write('{"seq": 5, "kind": "E", "tr')    # crash mid-write
    with Journal(p) as j:
        ev = j.append("AFTER")
    # the torn fragment was clipped, not glued onto the new line
    events = load_events(p)
    assert [e["seq"] for e in events] == [0, 1, 2, 3, 4, 5]
    assert events[-1]["kind"] == "AFTER"
    assert ev["seq"] == 5


def test_reopen_torn_tail_without_any_newline(tmp_path):
    p = str(tmp_path / "j.jsonl")
    with open(p, "w") as f:
        f.write('{"seq": 0, "ki')                 # torn very first line
    with Journal(p) as j:
        j.append("FIRST")
    events = load_events(p)
    assert [(e["seq"], e["kind"]) for e in events] == [(0, "FIRST")]


def test_recover_tail_skips_lines_without_int_seq(tmp_path):
    p = str(tmp_path / "j.jsonl")
    with open(p, "w") as f:
        f.write('{"seq": 7, "kind": "E"}\n')
        f.write('["not", "a", "dict"]\n')         # well-formed, wrong shape
        f.write('{"kind": "no_seq"}\n')
    with Journal(p) as j:
        ev = j.append("NEXT")
    assert ev["seq"] == 8                          # last line WITH a seq
