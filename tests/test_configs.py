"""Config integrity: the 40-cell table, parameter counts, stack plans."""
import pytest

from repro.configs import (ARCH_IDS, SHAPES, all_configs, cells, get_config,
                           shape_applicable, smoke_config)


def test_ten_archs_registered():
    assert len(ARCH_IDS) == 10


def test_cell_table_is_40():
    assert sum(1 for _ in cells(include_skipped=True)) == 40


def test_long_context_skips_are_the_documented_six():
    skipped = [a for a, s, ok in cells(include_skipped=True) if not ok]
    assert len(skipped) == 6
    assert set(skipped) == {"stablelm-1.6b", "nemotron-4-15b",
                            "musicgen-medium", "deepseek-v2-236b",
                            "kimi-k2-1t-a32b", "llava-next-34b"}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_stack_plan_covers_all_layers(arch):
    cfg = get_config(arch)
    pro, n, epi = cfg.stack_plan()
    assert len(pro) + n * cfg.period + len(epi) == cfg.num_layers
    assert len(cfg.expanded_kinds()) == cfg.num_layers


@pytest.mark.parametrize("arch,lo,hi", [
    ("gemma3-1b", 0.8e9, 1.4e9),
    ("gemma3-27b", 22e9, 32e9),
    ("stablelm-1.6b", 1.2e9, 2.1e9),
    ("nemotron-4-15b", 12e9, 18e9),
    ("recurrentgemma-2b", 2.0e9, 3.3e9),
    ("musicgen-medium", 1.2e9, 2.2e9),
    ("deepseek-v2-236b", 200e9, 260e9),
    ("kimi-k2-1t-a32b", 0.9e12, 1.2e12),
    ("llava-next-34b", 30e9, 38e9),
    ("rwkv6-3b", 2.6e9, 3.6e9),
])
def test_param_counts_match_model_class(arch, lo, hi):
    n = get_config(arch).param_count()
    assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B outside [{lo / 1e9}, {hi / 1e9}]B"


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "kimi-k2-1t-a32b"])
def test_moe_active_params_much_smaller(arch):
    cfg = get_config(arch)
    assert cfg.active_param_count() < 0.12 * cfg.param_count()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_is_tiny_same_family(arch):
    full, sm = get_config(arch), smoke_config(arch)
    assert sm.family == full.family
    assert sm.layer_pattern == full.layer_pattern
    assert (sm.moe is None) == (full.moe is None)
    assert (sm.mla is None) == (full.mla is None)
    assert sm.param_count() < 10_000_000


def test_tokens_per_step():
    assert SHAPES["train_4k"].tokens_per_step == 4096 * 256
    assert SHAPES["decode_32k"].tokens_per_step == 128
    assert SHAPES["long_500k"].tokens_per_step == 1
