"""MoE: expert-parallel shard_map path vs the dense oracle, routing
invariants, aux loss, capacity drops, gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MoECfg, smoke_config
from repro.models import moe as moe_mod

KEY = jax.random.PRNGKey(11)


def _cfg(top_k=2, experts=8, cf=8.0):
    cfg = smoke_config("deepseek-v2-236b")
    return cfg.replace(moe=MoECfg(num_experts=experts, top_k=top_k,
                                  d_ff_expert=32, num_shared=1,
                                  d_ff_dense=128, first_k_dense=1,
                                  capacity_factor=cf,
                                  eval_capacity_factor=cf))


def _params(cfg, key):
    from repro.models.common import init_params
    return init_params(moe_mod.moe_specs(cfg), key, "float32")


def test_ep_matches_dense_oracle_when_no_drops(local_mesh):
    cfg = _cfg(cf=8.0)   # capacity high enough that nothing drops
    p = _params(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_dense, aux_d = moe_mod.moe_dense(cfg, p, x)
    y_ep, aux_e = moe_mod.moe_ep(cfg, p, x, mesh=local_mesh, train=True)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(float(aux_e), float(aux_d), rtol=1e-5)


def test_ep_gradients_match_dense(local_mesh):
    cfg = _cfg(cf=8.0)
    p = _params(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model))

    def loss_dense(p_):
        y, aux = moe_mod.moe_dense(cfg, p_, x)
        return jnp.sum(y ** 2) + aux

    def loss_ep(p_):
        y, aux = moe_mod.moe_ep(cfg, p_, x, mesh=local_mesh, train=True)
        return jnp.sum(y ** 2) + aux

    gd = jax.grad(loss_dense)(p)
    ge = jax.grad(loss_ep)(p)
    for k in ("w_gate", "w_up", "w_down", "router"):
        np.testing.assert_allclose(np.asarray(ge[k]), np.asarray(gd[k]),
                                   atol=5e-4, rtol=5e-4), k


def test_capacity_drops_zero_out_overflow(local_mesh):
    # capacity_factor so small that most assignments drop; output must be
    # finite and strictly smaller in norm than the undropped version.
    # (T*k must exceed the 256 dropless-serving threshold for capacity to
    # bind at all.)
    cfg_lo = _cfg(cf=0.25)
    cfg_hi = _cfg(cf=8.0)
    p = _params(cfg_hi, KEY)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 256, cfg_hi.d_model))
    y_lo, _ = moe_mod.moe_ep(cfg_lo, p, x, mesh=local_mesh, train=True)
    y_hi, _ = moe_mod.moe_ep(cfg_hi, p, x, mesh=local_mesh, train=True)
    assert np.isfinite(np.asarray(y_lo)).all()
    assert float(jnp.linalg.norm(y_lo)) < float(jnp.linalg.norm(y_hi))


def test_router_topk_normalized():
    cfg = _cfg(top_k=3)
    router = jax.random.normal(KEY, (cfg.d_model, cfg.moe.num_experts))
    x = jax.random.normal(jax.random.PRNGKey(4), (64, cfg.d_model))
    probs, ids, logits = moe_mod.router_topk(cfg, router, x)
    assert probs.shape == (64, 3) and ids.shape == (64, 3)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, atol=1e-5)
    assert int(ids.max()) < cfg.moe.num_experts
    # top-k ids unique per token
    for row in np.asarray(ids):
        assert len(set(row.tolist())) == len(row)


def test_aux_loss_prefers_balance():
    cfg = _cfg(top_k=1, experts=4)
    E = 4
    balanced = jnp.eye(E)[jnp.arange(64) % E] * 10.0       # uniform routing
    skewed = jnp.broadcast_to(jnp.eye(E)[0] * 10.0, (64, E))
    ids_b = jnp.argmax(balanced, -1, keepdims=True)
    ids_s = jnp.argmax(skewed, -1, keepdims=True)
    lb = moe_mod.aux_load_balance_loss(cfg, balanced, ids_b)
    ls = moe_mod.aux_load_balance_loss(cfg, skewed, ids_s)
    assert float(lb) < float(ls)
