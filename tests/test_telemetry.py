"""Grid telemetry: sim-clock tracing, the metrics registry, and the
Perfetto-exportable run (the observability tentpole).

Three layers under test:

* unit — the registry instruments (Counter/Gauge/MultiGauge/Histogram),
  the tracer's ring bounding and ordering guarantees;
* determinism — tracing is purely observational: a traced market
  reproduces the untraced golden bytes, and two same-seed traced runs
  export byte-identical JSONL;
* integration — a traced market's Chrome export is structurally valid
  (balanced async spans, thread metadata, sim-time timestamps) and its
  metrics snapshot reconciles exactly with the GridBank books.
"""
import json
import math

import pytest

from repro.core import (Counter, Gauge, GridBank, Histogram,
                        MetricsRegistry, MultiGauge, ReconciliationError,
                        Tracer, export_chrome_trace, export_jsonl,
                        load_chrome_trace, mixed_auction_market,
                        stable_dumps, standard_market)

from test_golden_equivalence import GOLDEN, _contention_market, _sha

HOUR = 3600.0


def _traced_market(seed=7, tracer=None, **kw):
    kw.setdefault("n_machines", 8)
    kw.setdefault("n_jobs", 12)
    kw.setdefault("demand_elasticity", 1.0)
    return standard_market(4, seed=seed, tracer=tracer, **kw)


# ---------------------------------------------------------------------------
# registry instruments
# ---------------------------------------------------------------------------

def test_counter_monotone_and_shared_by_name():
    m = MetricsRegistry()
    c = m.counter("hits")
    c.inc()
    c.inc(2.5)
    assert m.counter("hits") is c          # get-or-create shares
    assert c.get() == 3.5


def test_gauge_set_and_derived_fn():
    m = MetricsRegistry()
    g = m.gauge("depth")
    g.set(4.0)
    assert g.get() == 4.0
    live = {"v": 1.0}
    d = m.gauge("live", fn=lambda: live["v"])
    live["v"] = 9.0
    assert d.get() == 9.0                  # evaluated at read time


def test_multi_gauge_sorted_labels():
    m = MetricsRegistry()
    fam = m.multi_gauge("rev", fn=lambda: {"b/kill": 2.0, "a/settle": 1.0})
    assert list(fam.get()) == ["a/settle", "b/kill"]


def test_histogram_buckets_and_summary():
    h = Histogram("lat", bounds=(1.0, 2.0, 5.0))
    for v in (0.5, 1.5, 1.5, 4.0, 99.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 5
    assert s["sum"] == pytest.approx(106.5)
    assert s["min"] == 0.5 and s["max"] == 99.0
    assert s["buckets"] == {"le_1.0": 1, "le_2.0": 2, "le_5.0": 1,
                            "overflow": 1}


def test_registry_type_clash_raises():
    m = MetricsRegistry()
    m.counter("x")
    with pytest.raises(TypeError):
        m.gauge("x")


def test_registry_snapshot_sorted_and_typed():
    m = MetricsRegistry()
    m.counter("b.count").inc(3)
    m.gauge("a.gauge").set(1.5)
    m.histogram("c.h").observe(2.0)
    snap = m.snapshot()
    assert list(snap) == sorted(snap)
    assert snap["b.count"] == 3.0
    assert snap["c.h"]["count"] == 1


# ---------------------------------------------------------------------------
# tracer mechanics
# ---------------------------------------------------------------------------

def test_tracer_orders_events_globally_across_categories():
    tr = Tracer()
    tr.instant(1.0, "t1", "a", "first")
    tr.instant(2.0, "t2", "b", "second")
    tr.instant(3.0, "t1", "a", "third")
    evs = tr.events()
    assert [e.name for e in evs] == ["first", "second", "third"]
    assert [e.seq for e in evs] == [0, 1, 2]


def test_ring_bounds_per_category_and_counts_drops():
    tr = Tracer(ring=4)
    for i in range(10):
        tr.instant(float(i), "t", "flood", "ev", i=i)
    tr.instant(99.0, "t", "calm", "ok")
    assert tr.n_events() == 5              # 4 retained + 1 other cat
    assert tr.dropped == {"flood": 6}
    assert [e.args["i"] for e in tr.events() if e.cat == "flood"] == \
        [6, 7, 8, 9]                       # oldest evicted first
    chrome = tr.to_chrome("bounded")
    assert chrome["otherData"]["dropped"] == {"flood": 6}


def test_ring_capacity_must_be_positive():
    with pytest.raises(ValueError):
        Tracer(ring=0)


def test_event_json_is_key_sorted():
    tr = Tracer()
    tr.span_begin(1.5, "trk", "job", "attempt", "e/j1/a1",
                  zeta=1, alpha=2)
    ev = tr.events()[0]
    d = ev.to_json()
    assert list(d["args"]) == ["alpha", "zeta"]
    assert d["span"] == "e/j1/a1" and d["ph"] == "b"
    # stable_dumps of the dict is what jsonl_lines emits
    assert next(iter(tr.jsonl_lines())) == stable_dumps(d)


# ---------------------------------------------------------------------------
# determinism: tracing is purely observational
# ---------------------------------------------------------------------------

def test_traced_run_reproduces_untraced_golden_bytes():
    """The golden contention hash was captured with telemetry OFF; a
    traced run of the same seed must produce the same report bytes —
    instrumentation draws no RNG and reorders nothing."""
    market = _contention_market()
    market.tracer = None                   # untraced baseline path
    tr = Tracer()
    traced = standard_market(4, n_machines=8, seed=7, n_jobs=12,
                             demand_elasticity=1.0, tracer=tr)
    rep = traced.run(failures=True)
    assert _sha(rep.stable_repr()) == GOLDEN["contention"]
    assert tr.n_events() > 0


def test_same_seed_traced_runs_export_identical_jsonl():
    streams = []
    for _ in range(2):
        tr = Tracer()
        _traced_market(tracer=tr).run()
        streams.append("\n".join(tr.jsonl_lines()))
    assert streams[0] == streams[1]
    assert streams[0]                      # and not trivially empty


def test_jsonl_contains_no_wall_clock_values():
    """Wall-derived gauges (events_per_sec, wall_seconds) register only
    AFTER the final snapshot — nothing nondeterministic may reach the
    event stream."""
    tr = Tracer()
    _traced_market(tracer=tr).run()
    for line in tr.jsonl_lines():
        assert "events_per_sec" not in line
        assert "wall_seconds" not in line
    # ... but they do land in the registry for the Chrome otherData
    assert tr.metrics.get("market.events_per_sec").get() > 0


# ---------------------------------------------------------------------------
# the traced market, structurally
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_run():
    tr = Tracer()
    market = _traced_market(tracer=tr)
    report = market.run()
    return tr, market, report


def test_every_job_gets_a_balanced_lifecycle_span(traced_run):
    tr, market, report = traced_run
    opens = {}
    for e in tr.events():
        if e.ph == "b":
            opens[e.span] = opens.get(e.span, 0) + 1
        elif e.ph == "e":
            opens[e.span] = opens.get(e.span, 0) - 1
            assert opens[e.span] >= 0, f"end before begin: {e.span}"
    unbalanced = {k: v for k, v in opens.items() if v != 0}
    assert not unbalanced
    job_spans = {e.span for e in tr.events()
                 if e.cat == "job" and e.name == "job" and e.ph == "b"}
    assert len(job_spans) == report.total_jobs


def test_every_subsystem_emits_typed_events(traced_run):
    tr, market, report = traced_run
    cats = {e.cat for e in tr.events()}
    assert {"job", "gis", "market", "metric"} <= cats
    names = {(e.cat, e.name) for e in tr.events()}
    assert ("gis", "register") in names            # t=0 registrations
    assert ("gis", "heartbeat_pump") in names
    assert ("market", "broker_finish") in names
    finishes = [e for e in tr.events() if e.name == "broker_finish"]
    assert len(finishes) == len(market.users)


def test_chrome_export_is_perfetto_shaped(traced_run, tmp_path):
    tr, market, report = traced_run
    doc = tr.to_chrome("unit-test-run")
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    threads = {e["args"]["name"] for e in meta
               if e["name"] == "thread_name"}
    assert any(t.startswith("broker:") for t in threads)
    assert "gis" in threads
    tids = {e["tid"] for e in meta if e["name"] == "thread_name"}
    for e in evs:
        if e["ph"] == "M":
            continue
        assert e["pid"] == 1 and e["tid"] in tids
        if e["ph"] in ("b", "e"):
            assert e["id"]                 # async spans carry their id
        if e["ph"] == "i":
            assert e["s"] == "t"
    # ts is sim-time microseconds: a 12-job day-scale market spans hours
    span_us = max(e["ts"] for e in evs if e["ph"] != "M")
    assert span_us > 1 * HOUR * 1e6
    # and the file round-trips through the exporters
    p = tmp_path / "trace.json"
    export_chrome_trace(tr, str(p), run_name="unit-test-run")
    loaded = load_chrome_trace(str(p))
    assert loaded["otherData"]["run"] == "unit-test-run"
    assert len(loaded["traceEvents"]) == len(evs)
    jl = tmp_path / "trace.jsonl"
    export_jsonl(tr, str(jl))
    assert jl.read_text().count("\n") == tr.n_events()


def test_metrics_snapshot_reconciles_with_gridbank(traced_run):
    tr, market, report = traced_run
    snap = tr.metrics.snapshot()
    bank = market.bank
    assert snap["bank.total_spend_gd"] == pytest.approx(
        bank.total_spend(), abs=1e-9)
    assert snap["bank.total_revenue_gd"] == pytest.approx(
        bank.total_revenue(), abs=1e-9)
    # the two-sided audit passes against the live broker ledgers
    total = bank.reconcile(
        {u.name: e.ledger for u, e in zip(market.users, market.engines)})
    assert total == pytest.approx(snap["bank.total_spend_gd"])
    # per-owner revenue-by-kind family sums back to the grand total
    by_kind = snap["bank.revenue_by_kind_gd"]
    assert math.fsum(by_kind.values()) == pytest.approx(total)
    # completion metrics populated
    assert snap["broker.attempts_per_job"]["count"] == report.total_done
    assert snap["market.sim_events"] > 0


def test_auction_market_emits_auction_events():
    tr = Tracer()
    rep = mixed_auction_market(4, n_machines=8, seed=3, n_jobs=8,
                               tracer=tr).run()
    assert rep.contracts_struck > 0
    names = {(e.cat, e.name) for e in tr.events()}
    assert any(cat == "auction" for cat, _ in names)
    assert tr.metrics.get("auction.contracts").get() > 0


# ---------------------------------------------------------------------------
# reconciliation error diagnostics (satellite: per-kind breakdown)
# ---------------------------------------------------------------------------

def test_reconciliation_error_carries_per_kind_breakdown():
    bank = GridBank()
    bank.record(t=1.0, user="u0", owner="ANL", resource="m0", amount=5.0)
    bank.record(t=2.0, user="u0", owner="ANL", resource="m0", amount=2.0,
                kind="kill")
    bank._spend["u0"] += 1.0               # corrupt one side of the books
    with pytest.raises(ReconciliationError) as err:
        bank.reconcile()
    msg = str(err.value)
    assert "per-kind totals" in msg
    assert "settle" in msg and "kill" in msg
    assert "delta" in msg


def test_ledger_mismatch_breakdown_names_the_user():
    bank = GridBank()
    bank.record(t=1.0, user="u1", owner="SDSC", resource="m1", amount=3.0)

    class FakeLedger:
        settled = 4.0

    with pytest.raises(ReconciliationError) as err:
        bank.reconcile({"u1": FakeLedger()})
    msg = str(err.value)
    assert "'u1'" in msg and "per-kind totals" in msg
