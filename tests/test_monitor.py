"""ExperimentMonitor: streaming bus, online watchdogs, steering, and
the causal post-mortem tooling.

The soundness contract has two halves, both tested here:

* **No false positives** — monitored runs of the golden scenarios stay
  violation-free AND reproduce the untraced golden hashes byte-for-byte
  (the monitor is purely observational).
* **No false negatives** — an injected ledger skim and an injected
  double slot-release are each caught at the exact sim time of the
  offending event (not at run end), with a causal context window.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.core import (ExperimentMonitor, InvariantViolation, Tracer,
                        export_chrome_trace, standard_market)
from repro.core.telemetry import Histogram, TraceEvent

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.make_report import (_percentile_from_summary, explain_job,  # noqa: E402
                                    market_dashboard)
from tests.test_golden_equivalence import GOLDEN, _sha  # noqa: E402

HOUR = 3600.0
ROOT = os.path.join(os.path.dirname(__file__), "..")


def _contention(tracer=None):
    return standard_market(4, n_machines=8, seed=7, n_jobs=12,
                           demand_elasticity=1.0, tracer=tracer)


def _churn(tracer=None):
    return standard_market(4, n_machines=12, seed=5, n_jobs=10,
                           gis_ttl=900.0, churn_mean_uptime_h=3.0,
                           churn_mean_downtime_h=1.0, tracer=tracer)


# ---------------------------------------------------------------------------
# streaming subscriber bus
# ---------------------------------------------------------------------------

class TestSubscriberBus:
    def test_category_and_wildcard_delivery_in_seq_order(self):
        tr = Tracer()
        jobs, everything = [], []
        tr.subscribe("job", jobs.append)
        tr.subscribe("*", everything.append)
        tr.instant(1.0, "t", "job", "a")
        tr.instant(2.0, "t", "bank", "b")
        tr.span_begin(3.0, "t", "job", "attempt", "s1")
        assert [e.name for e in jobs] == ["a", "attempt"]
        assert [e.name for e in everything] == ["a", "b", "attempt"]
        assert [e.seq for e in everything] == [0, 1, 2]
        assert all(isinstance(e, TraceEvent) for e in everything)

    def test_raw_delivery_passes_plain_tuples(self):
        tr = Tracer()
        seen = []
        tr.subscribe("*", seen.append, raw=True)
        tr.instant(1.0, "t", "job", "a", x=1)
        assert seen == [(0, 1.0, "t", "job", "a", "i", "", {"x": 1})]
        assert type(seen[0]) is tuple

    def test_unsubscribe_detaches(self):
        tr = Tracer()
        seen = []
        sub = tr.subscribe("job", seen.append)
        tr.instant(1.0, "t", "job", "a")
        sub.cancel()
        sub.cancel()                      # idempotent
        tr.instant(2.0, "t", "job", "b")
        assert [e.name for e in seen] == ["a"]
        assert not tr._have_subs          # record path back to one bool

    def test_reentrant_record_queues_behind_current_event(self):
        tr = Tracer()
        order = []

        def echo(ev):
            order.append(ev.name)
            if ev.name == "trigger":      # a steering-style reaction
                tr.instant(ev.t, "t", "steer", "reaction")

        tr.subscribe("*", echo)
        tr.instant(1.0, "t", "job", "trigger")
        # the reaction was recorded and delivered AFTER the triggering
        # event finished delivering, in seq order
        assert order == ["trigger", "reaction"]
        assert [e.name for e in tr.events()] == ["trigger", "reaction"]

    def test_subscriber_exception_propagates_to_record_site(self):
        tr = Tracer()

        def boom(ev):
            raise RuntimeError("watchdog says no")

        tr.subscribe("job", boom)
        with pytest.raises(RuntimeError, match="watchdog says no"):
            tr.instant(1.0, "t", "job", "a")


# ---------------------------------------------------------------------------
# Histogram percentiles (live instrument + exported-summary mirror)
# ---------------------------------------------------------------------------

class TestPercentile:
    def test_percentile_interpolates_and_clamps(self):
        h = Histogram("x", bounds=(10.0, 20.0, 30.0))
        for v in (1.0, 12.0, 14.0, 25.0, 29.0):
            h.observe(v)
        assert h.percentile(0) == pytest.approx(1.0)     # exact min
        assert h.percentile(100) == pytest.approx(29.0)  # exact max
        assert 1.0 <= h.percentile(50) <= 20.0
        assert 20.0 <= h.percentile(95) <= 29.0
        assert h.percentile(50) <= h.percentile(90) <= h.percentile(99)

    def test_percentile_rejects_out_of_range_and_empty(self):
        h = Histogram("x")
        assert h.percentile(50) == 0.0
        with pytest.raises(ValueError):
            h.percentile(101)

    def test_summary_mirror_matches_live_instrument(self):
        h = Histogram("x", bounds=(5.0, 10.0, 50.0))
        for v in (2.0, 3.0, 7.0, 8.0, 9.0, 12.0, 40.0, 60.0):
            h.observe(v)
        summary = h.summary()
        for p in (0, 25, 50, 75, 90, 100):
            assert _percentile_from_summary(summary, p) == \
                pytest.approx(h.percentile(p))


# ---------------------------------------------------------------------------
# soundness: no false positives on golden scenarios, bytes unchanged
# ---------------------------------------------------------------------------

class TestSoundness:
    def test_monitored_runs_reproduce_golden_hashes(self):
        for kind, build, kw in (
                ("contention", _contention, {"failures": True}),
                ("churn", _churn, {"failures": True, "churn": True})):
            market = build(Tracer())
            monitor = ExperimentMonitor(market)
            rep = market.run(**kw)
            assert _sha(rep.stable_repr()) == GOLDEN[kind], kind
            assert monitor.violations == []
            assert monitor.events_seen > 0
            monitor.assert_clean()

    def test_monitor_requires_traced_market(self):
        with pytest.raises(ValueError, match="traced market"):
            ExperimentMonitor(_contention(None))
        with pytest.raises(ValueError, match="on_violation"):
            ExperimentMonitor(_contention(Tracer()), on_violation="explode")

    def test_health_rollups_cover_every_broker_and_site(self):
        market = _churn(Tracer())
        monitor = ExperimentMonitor(market)
        market.run(failures=True, churn=True)
        healths = monitor.broker_health()
        assert [h.user for h in healths] == \
            sorted(u.name for u in market.users)
        assert all(h.deadline_risk == "done" and h.finished
                   for h in healths)
        assert all(h.spent <= h.budget for h in healths)
        one = monitor.broker_health(market.users[0].name)
        assert one.outcomes.get("settled") == one.jobs
        sites = {s.site for s in monitor.site_health()}
        assert sites >= set(market.directory.sites())
        dash = monitor.dashboard()
        assert "0 violation(s)" in dash
        for u in market.users:
            assert u.name in dash


# ---------------------------------------------------------------------------
# soundness: injected bugs are caught AT the offending sim time
# ---------------------------------------------------------------------------

class TestInjectedBugs:
    def test_ledger_skim_caught_at_first_settlement(self):
        market = _contention(Tracer())
        monitor = ExperimentMonitor(market)
        bank = market.bank
        real_record = bank.record
        skimmed = []

        def skimming_record(*, t, user, owner, resource, amount,
                            kind="settle"):
            if kind == "settle":
                if not skimmed:
                    skimmed.append(t)
                amount *= 0.5            # the bank pockets half
            real_record(t=t, user=user, owner=owner, resource=resource,
                        amount=amount, kind=kind)

        bank.record = skimming_record
        with pytest.raises(InvariantViolation) as exc:
            market.run(failures=True)
        v = exc.value
        assert v.invariant == "money_conservation"
        # caught at the sim time of the FIRST skimmed settlement — the
        # run died mid-flight, long before its clean completion time
        assert v.t == skimmed[0]
        assert market.sim.now == v.t
        assert v.context, "violation must carry a causal context window"
        assert any(e.track == v.track for e in v.context)
        assert "ledger settled" in str(v)

    def test_double_release_caught_at_that_finish(self):
        market = _contention(Tracer())
        monitor = ExperimentMonitor(market)
        executor = market.engines[0].dispatcher.executor
        real_finish = executor._finish
        rogue = []

        def double_releasing_finish(job, resource, token):
            held_before = job.slot_held
            real_finish(job, resource, token)
            if held_before and not rogue:
                rogue.append(market.sim.now)
                # frees a slot out from under whoever holds it
                market.directory.status(resource).release()

        executor._finish = double_releasing_finish
        with pytest.raises(InvariantViolation) as exc:
            market.run(failures=True)
        v = exc.value
        assert v.invariant == "slot_accounting"
        assert v.t == rogue[0]
        assert market.sim.now == v.t
        assert v.context

    def test_span_imbalance_detected(self):
        market = _contention(Tracer())
        monitor = ExperimentMonitor(market, on_violation="record")
        tr = market.tracer
        track = f"broker:{market.users[0].name}"
        tr.span_end(10.0, track, "job", "attempt", "X/j0/a9",
                    outcome="failed")
        tr.span_begin(11.0, track, "job", "attempt", "X/j1/a1")
        tr.span_begin(12.0, track, "job", "attempt", "X/j1/a1")
        kinds = [(v.invariant, v.t) for v in monitor.violations]
        assert ("attempt_span_balance", 10.0) in kinds
        assert ("attempt_span_balance", 12.0) in kinds
        with pytest.raises(InvariantViolation):
            monitor.assert_clean()


# ---------------------------------------------------------------------------
# steering: deterministic, recorded, and actually effective
# ---------------------------------------------------------------------------

class TestSteering:
    @staticmethod
    def _steered_run():
        tracer = Tracer()
        market = _churn(tracer)
        monitor = ExperimentMonitor(market)
        user = market.users[-1].name
        monitor.steer_broker(user, budget=9999.0, deadline=9.0 * HOUR,
                             at=0.5 * HOUR)
        monitor.drain_site("Monash", at=0.5 * HOUR)
        rep = market.run(failures=True, churn=True)
        return rep, tracer, monitor

    def test_steered_runs_are_byte_identical(self):
        (r1, t1, m1), (r2, t2, m2) = self._steered_run(), self._steered_run()
        assert r1.stable_repr() == r2.stable_repr()
        assert "\n".join(t1.jsonl_lines()) == "\n".join(t2.jsonl_lines())
        assert m1.steering_log == m2.steering_log
        assert m1.violations == [] and m2.violations == []

    def test_steering_changes_outcome_and_is_recorded(self):
        steered, tracer, monitor = self._steered_run()
        baseline = _churn(Tracer())
        base_rep = baseline.run(failures=True, churn=True)
        assert steered.stable_repr() != base_rep.stable_repr()
        kinds = [a.kind for a in monitor.steering_log]
        assert kinds == ["steer_broker", "drain_site"]
        assert all(a.t == 0.5 * HOUR for a in monitor.steering_log)
        steers = [e for e in tracer.events() if e.cat == "steer"]
        assert any(e.name == "drain_site" and e.args["applied"]
                   for e in steers)
        assert any(e.name == "adjust" and e.args["budget"] == 9999.0
                   for e in steers)

    def test_steering_finished_broker_is_a_noop(self):
        market = _contention(Tracer())
        monitor = ExperimentMonitor(market)
        market.run(failures=True)
        monitor.steer_broker(market.users[0].name, budget=1.0, at=None)
        assert monitor.steering_log == []


# ---------------------------------------------------------------------------
# post-mortems + dashboard percentiles + corrupt-trace handling
# ---------------------------------------------------------------------------

class TestReportTooling:
    @pytest.fixture(scope="class")
    def trace_path(self, tmp_path_factory):
        path = tmp_path_factory.mktemp("trace") / "churn.json"
        tracer = Tracer()
        market = _churn(tracer)
        market.run(failures=True, churn=True)
        export_chrome_trace(tracer, str(path), run_name="test")
        return str(path)

    def test_explain_job_renders_a_post_mortem(self, trace_path):
        out = explain_job(trace_path, "auto")
        assert "Post-mortem" in out
        assert "## Attempts" in out
        assert "## Attribution" in out
        assert "bought the result" in out

    def test_explain_job_unknown_job_exits_3(self, trace_path):
        with pytest.raises(SystemExit) as exc:
            explain_job(trace_path, "nope/never")
        assert exc.value.code == 3

    def test_dashboard_has_attempt_latency_percentiles(self, trace_path):
        out = market_dashboard(trace_path)
        assert "attempt latency" in out
        assert "p50" in out and "p95" in out and "p99" in out

    @pytest.mark.parametrize("payload", [
        "this is not json{{{",
        json.dumps({"no": "traceEvents"}),
        json.dumps({"traceEvents": []}),
    ])
    def test_corrupt_trace_exits_2_with_one_line_error(self, tmp_path,
                                                       payload):
        bad = tmp_path / "bad.json"
        bad.write_text(payload)
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.make_report",
             "--market-trace", str(bad)],
            capture_output=True, text=True, cwd=ROOT,
            env={**os.environ,
                 "PYTHONPATH": os.path.join(ROOT, "src")})
        assert proc.returncode == 2
        assert len(proc.stderr.strip().splitlines()) == 1
        assert "corrupt trace" in proc.stderr or "empty trace" in proc.stderr


class TestSparklineDownsampling:
    """100k-job traces emit one price sample per clearing round; the
    sparkline must downsample instead of walking every round."""

    def test_huge_series_is_capped_and_keeps_endpoints(self):
        from benchmarks.make_report import _sparkline
        n = 400_000
        samples = [(float(i), float(i)) for i in range(n)]
        import time as _time
        t0 = _time.time()
        line, lo, hi = _sparkline(samples, width=64)
        wall = _time.time() - t0
        assert len(line) == 64
        # monotone ramp: first and last samples pin the rendered range
        assert lo <= samples[0][1] + n / 64 and hi >= samples[-1][1] - n / 64
        assert line[0] == "▁" and line[-1] == "█"
        assert wall < 1.0          # stride cap, not a 400k-point walk

    def test_small_series_unchanged_by_the_cap(self):
        from benchmarks.make_report import _sparkline
        samples = [(float(i), float(i % 7)) for i in range(200)]
        line, lo, hi = _sparkline(samples, width=32)
        assert len(line) == 32 and 0.0 <= lo <= hi <= 6.0
