"""DBC scheduler behaviour: the paper's core claims.

* Figure 3: tighter deadline => more resources allocated, all deadlines met.
* cost-opt picks cheap resources; time-opt minimizes completion time
  within budget; conservative never over-commits the budget.
* failures requeue; stragglers get duplicated; measured rates adapt.
"""
import pytest

from repro.core import (Dispatcher, NimrodG, PriceSchedule,
                        ResourceDirectory, ResourceSpec, SchedulerConfig,
                        SimulatedExecutor, Simulator, TradeServer,
                        UserRequirements, gusto_like_testbed, parse_plan,
                        negotiate_contract)

HOUR = 3600.0

PLAN_165 = """
parameter angle float range from 1 to 165 step 1
task main
    copy model.bin node:.
    execute ionize --angle $angle
    copy node:out.dat res/$jobname.dat
endtask
"""


def build_engine(deadline_h, strategy="cost", budget=30_000.0, n_jobs_plan=PLAN_165,
                 n_machines=70, seed=0, est=2400.0, sched=None,
                 failures_seed=0, testbed_seed=1):
    directory = ResourceDirectory()
    for spec in gusto_like_testbed(n_machines, seed=testbed_seed):
        directory.register(spec)
    schedules = {n: PriceSchedule(directory.spec(n))
                 for n in directory.all_names()}
    trade = TradeServer(directory, schedules)
    sim = Simulator()
    ex = SimulatedExecutor(sim, directory, seed=failures_seed)
    disp = Dispatcher(ex, directory)
    req = UserRequirements(deadline=deadline_h * HOUR, budget=budget,
                           strategy=strategy)
    eng = NimrodG.from_plan(
        "exp", parse_plan(n_jobs_plan), req, directory, trade, disp,
        est_seconds=lambda p: est, sim=sim,
        sched_cfg=sched or SchedulerConfig(), seed=seed)
    return eng


def test_figure3_deadline_vs_resources():
    peaks, met = {}, {}
    for dl in (10, 15, 20):
        rep = build_engine(dl).run_simulated()
        peaks[dl] = rep.peak_allocation
        met[dl] = rep.met_deadline
        assert rep.n_done == 165
    assert all(met.values()), met
    assert peaks[10] > peaks[15] >= peaks[20], peaks


def test_time_opt_faster_but_costlier_than_cost_opt():
    rc = build_engine(15, "cost").run_simulated()
    rt = build_engine(15, "time").run_simulated()
    assert rt.completion_time < rc.completion_time
    assert rt.total_cost > rc.total_cost
    assert rt.n_done == rc.n_done == 165


def test_all_strategies_respect_budget():
    for strat in ("cost", "time", "conservative"):
        rep = build_engine(12, strat, budget=500.0).run_simulated()
        assert rep.total_cost <= 500.0 + 1e-6, (strat, rep.total_cost)


def test_conservative_stalls_instead_of_overspending():
    # budget far too small to finish: engine must stop with a stall reason,
    # never a negative ledger
    rep = build_engine(10, "conservative", budget=3.0).run_simulated()
    assert rep.n_done < 165
    assert rep.total_cost <= 3.0 + 1e-6
    assert rep.stall_reason in ("budget_exhausted", "horizon_reached")


def test_infeasible_deadline_still_terminates():
    rep = build_engine(0.05, "cost", budget=1e9).run_simulated()
    assert rep.completion_time > 0.05 * HOUR  # missed, but finished/stopped
    assert not rep.met_deadline or rep.n_done == 165


def test_failures_requeue_and_complete():
    # very unreliable testbed: every job still completes exactly once
    directory = ResourceDirectory()
    for i in range(10):
        directory.register(ResourceSpec(
            name=f"r{i:02d}", site="x", chips=1, perf_factor=1.0,
            base_price=1.0, mtbf_hours=2.0, mttr_hours=0.2))
    schedules = {n: PriceSchedule(directory.spec(n))
                 for n in directory.all_names()}
    trade = TradeServer(directory, schedules)
    sim = Simulator()
    ex = SimulatedExecutor(sim, directory, seed=3)
    disp = Dispatcher(ex, directory)
    plan = parse_plan("""
parameter i integer range from 1 to 30 step 1
task main
    execute run --i $i
endtask
""")
    req = UserRequirements(deadline=40 * HOUR, budget=1e6, strategy="time")
    eng = NimrodG.from_plan("flaky", plan, req, directory, trade, disp,
                            est_seconds=lambda p: 1800.0, sim=sim,
                            sched_cfg=SchedulerConfig(max_attempts=50))
    rep = eng.run_simulated()
    assert rep.n_done == 30
    assert rep.requeues > 0   # failures actually happened and were retried


def test_straggler_duplication_first_wins():
    # two-machine grid: one fast, one pathologically slow; straggler
    # duplication should rescue jobs stuck on the slow machine
    directory = ResourceDirectory()
    directory.register(ResourceSpec(name="fast", site="a", chips=1,
                                    perf_factor=4.0, base_price=1.0,
                                    mtbf_hours=float("inf")))
    directory.register(ResourceSpec(name="slow", site="a", chips=1,
                                    perf_factor=0.05, base_price=0.1,
                                    mtbf_hours=float("inf")))
    schedules = {n: PriceSchedule(directory.spec(n))
                 for n in directory.all_names()}
    trade = TradeServer(directory, schedules)
    sim = Simulator()
    ex = SimulatedExecutor(sim, directory, seed=0, noise_sigma=0.0)
    disp = Dispatcher(ex, directory)
    plan = parse_plan("""
parameter i integer range from 1 to 6 step 1
task main
    execute run --i $i
endtask
""")
    req = UserRequirements(deadline=6 * HOUR, budget=1e6, strategy="time")
    eng = NimrodG.from_plan(
        "strag", plan, req, directory, trade, disp,
        est_seconds=lambda p: 1200.0, sim=sim,
        sched_cfg=SchedulerConfig(straggler_factor=2.0, interval=60.0))
    rep = eng.run_simulated(failures=False)
    assert rep.n_done == 6
    assert rep.duplicates_launched > 0
    assert rep.met_deadline


def test_rates_adapt_from_measurements():
    eng = build_engine(10)
    rep = eng.run_simulated()
    measured = [v for v in eng.views.values() if v.measured_rate is not None]
    assert measured, "no consumption rates were learned"
    assert all(v.completions > 0 for v in measured)


def test_contract_quote_cost_hand_computed_multi_slot():
    """Regression: the per-job contract cost once multiplied by
    ``spec.slots`` twice (est_rate already counts every slot), so a
    4-slot resource quoted 4x the true cost and feasible contracts
    looked budget-infeasible.  Hand-computed single-resource case:
    2 chips at 1 G$/chip-hour = 2 G$/hour for the whole resource;
    4 slots x 1800s jobs = 8 jobs/hour; so 8 jobs cost exactly 2 G$."""
    from repro.core import ResourceView, TradeServer
    directory = ResourceDirectory()
    directory.register(ResourceSpec(
        name="quad", site="s", chips=2, slots=4, base_price=1.0,
        peak_multiplier=1.0, mtbf_hours=float("inf")))
    trade = TradeServer(directory,
                        {"quad": PriceSchedule(directory.spec("quad"))})
    views = {"quad": ResourceView(spec=directory.spec("quad"),
                                  est_job_seconds=1800.0)}
    req = UserRequirements(deadline=HOUR, budget=2.5, user="u")
    quote = negotiate_contract(0.0, req, 8, trade, views)
    assert quote.n_resources == 1
    assert quote.est_cost == pytest.approx(2.0)      # was 8.0 pre-fix
    assert quote.est_completion == pytest.approx(HOUR)
    assert quote.feasible                            # 2.0 <= budget 2.5


def test_contract_negotiation_modes():
    eng = build_engine(10)
    eng._refresh_views()
    quote = negotiate_contract(0.0, eng.req, 165, eng.trade, eng.views)
    assert quote.feasible
    assert quote.est_cost < eng.req.budget
    # renegotiate with an impossible deadline
    tight = UserRequirements(deadline=30.0, budget=eng.req.budget)
    q2 = negotiate_contract(0.0, tight, 165, eng.trade, eng.views)
    assert not q2.feasible
    # accepting locks reservations
    q3 = negotiate_contract(0.0, eng.req, 165, eng.trade, eng.views,
                            accept=True)
    assert q3.reserved
    locked = eng.trade.reserved_price(
        eng.trade.reservations[0].resource, eng.req.user, 100.0)
    assert locked is not None
