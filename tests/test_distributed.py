"""The sharded grid: loopback byte-identity, per-domain OS processes,
crash/recovery with exact reconciliation, and the distributed clock.

The central claims under test:

* ``wire="loopback"`` re-plumbs every cross-domain interaction through
  the canonical protocol codec and the market's output stays
  byte-identical to the direct-call goldens;
* the SAME scheduler / auction / GIS code runs unchanged when each
  administrative domain is its own OS process;
* SIGKILL a domain mid-run, restart it on its journal, and the books
  reconcile exactly — no lost reservation, no double settlement.
"""
import hashlib
import os

import pytest

from repro.core import protocol as P
from repro.core.economy import AdmissionError, TradeFederation
from repro.core.gis import GISClient
from repro.core.marketplace import standard_market
from repro.core.resources import gusto_like_testbed
from repro.core.scheduler import negotiate_contract, views_from_gis
from repro.core.simulator import ConservativeClock, WallClockSimulator
from repro.core.transport import (DomainConfig, DomainEndpoint,
                                  DomainProcess, LoopbackTransport,
                                  RemoteTradeServer, TransportError,
                                  WireFederation, build_domain,
                                  spawn_domains, wrap_federation_loopback)
from repro.core.economy import UserRequirements
from tests.test_golden_equivalence import GOLDEN, _contention_market

HOUR = 3600.0


def _sha(s: str) -> str:
    return hashlib.sha256(s.encode()).hexdigest()


def _domain_configs(tmp_path=None, n_machines=8, seed=0):
    by_site = {}
    for s in gusto_like_testbed(n_machines, seed=seed):
        by_site.setdefault(s.site, []).append(s)
    return [DomainConfig(
        site=site, specs=tuple(ss),
        journal_path=(str(tmp_path / f"{site}.jsonl")
                      if tmp_path is not None else None))
        for site, ss in sorted(by_site.items())]


# ---------------------------------------------------------------------------
# loopback: the protocol plumbing must be bit-invisible
# ---------------------------------------------------------------------------

def test_loopback_market_reproduces_the_golden_bytes():
    # the pinned contention golden, with EVERY cross-domain call routed
    # through encode -> stable_dumps -> parse: the wire layer proved
    # lossless on a full market run, not just on unit corpus messages
    market = standard_market(4, n_machines=8, seed=7, n_jobs=12,
                             demand_elasticity=1.0, wire="loopback")
    rep = market.run(failures=True)
    assert _sha(rep.stable_repr()) == GOLDEN["contention"]


def test_loopback_differential_with_resale_and_churn():
    def run(wire):
        mk = standard_market(3, n_machines=10, seed=11, n_jobs=8,
                             resale=True, release_fee=0.1,
                             churn_mean_uptime_h=3.0,
                             churn_mean_downtime_h=1.0, wire=wire)
        return mk.run(churn=True).stable_repr()
    assert run("loopback") == run("direct")


def test_loopback_counts_real_message_traffic():
    market = standard_market(2, n_machines=6, seed=1, n_jobs=6,
                             wire="loopback")
    market.run()
    transports = [s._transport for s in market.trade.servers.values()]
    assert sum(t.messages for t in transports) > 100
    assert all(t.bytes_out > 0 for t in transports)


def test_marketplace_rejects_unknown_wire():
    with pytest.raises(ValueError, match="wire"):
        standard_market(1, wire="carrier-pigeon")


def test_wire_federation_restrides_like_the_direct_one():
    fed = TradeFederation.from_directory(*_fed_parts(seed=2))
    wire = wrap_federation_loopback(
        TradeFederation.from_directory(*_fed_parts(seed=2)))
    for made in range(6):
        for f in (fed, wire):
            bids = f.solicit_bids(0.0, "u0", lambda spec: 1800.0)
            f.reserve(bids[made % len(bids)].resource, "u0",
                      made * HOUR, (made + 1) * HOUR, 0.0)
    direct_rids = sorted(r.reservation_id for r in fed.reservations)
    wire_rids = sorted(
        s._transport.endpoint.server.reservations[i].reservation_id
        for s in wire.servers.values()
        for i in range(len(s._transport.endpoint.server.reservations)))
    assert wire_rids == direct_rids
    assert len(set(wire_rids)) == len(wire_rids)


def _fed_parts(seed=0, n=8):
    from repro.core.economy import PriceSchedule
    from repro.core.resources import ResourceDirectory
    directory = ResourceDirectory()
    for spec in gusto_like_testbed(n, seed=seed):
        directory.register(spec)
    schedules = {name: PriceSchedule(directory.spec(name))
                 for name in directory.all_names()}
    return directory, schedules


def test_endpoint_surfaces_admission_errors_over_the_wire():
    directory, schedules = _fed_parts()
    from repro.core.economy import TradeServer
    name = directory.all_names()[0]
    site = directory.spec(name).site
    server = TradeServer(directory, schedules, site=site)
    proxy = RemoteTradeServer(LoopbackTransport(DomainEndpoint(server)))
    slots = directory.spec(name).slots
    for _ in range(slots):
        proxy.reserve(name, "u0", 0.0, HOUR, 0.0)
    with pytest.raises(AdmissionError, match="overlap"):
        proxy.reserve(name, "u0", 0.0, HOUR, 0.0)


# ---------------------------------------------------------------------------
# process mode: same code, separate OS processes per domain
# ---------------------------------------------------------------------------

def test_scheduler_negotiates_unchanged_across_processes(tmp_path):
    procs, fed, gis = spawn_domains(_domain_configs(tmp_path))
    try:
        # discovery through the merged remote GIS, exactly as a broker
        # does it on the in-process grid
        client = GISClient(gis, "u0", ttl=600.0)
        snap = client.view(0.0)
        assert len(snap.entries) == 8
        views = views_from_gis(snap, est_seconds_base=1800.0)
        req = UserRequirements(deadline=12 * HOUR, budget=5_000.0,
                               strategy="cost", user="u0")
        quote = negotiate_contract(0.0, req, 10, fed, views, accept=True)
        assert quote.feasible
        assert quote.reserved
        # the contract's reservations are really on the remote books
        for rid in quote.reserved:
            assert fed.find_reservation(rid) is not None
    finally:
        for p in procs.values():
            p.stop()


def test_gis_heartbeats_pump_per_domain(tmp_path):
    procs, fed, gis = spawn_domains(_domain_configs(tmp_path))
    try:
        assert gis.pump(600.0) == len(procs)
        entries = gis.query(600.0, include_suspected=True)
        assert all(e.last_heartbeat > 0.0 for e in entries)
        # a killed domain goes silent: queries skip it instead of dying
        victim = sorted(procs)[0]
        procs[victim].kill()
        remaining = gis.query(1200.0, include_suspected=True)
        assert ({e.spec.site for e in remaining}
                == set(sorted(procs)[1:]))
    finally:
        for p in procs.values():
            p.stop()


def test_sigkill_recovery_reconciles_exactly(tmp_path):
    """The crash/recovery acceptance test: SIGKILL a domain process
    mid-auction (reservations + settlements journaled), restart it on
    the same journal, and the broker-side and domain-side books agree
    entry-for-entry — retried settlements are detected as duplicates,
    never double-booked."""
    procs, fed, gis = spawn_domains(_domain_configs(tmp_path))
    broker_rows = []
    try:
        bids = fed.solicit_bids(0.0, "u0", lambda spec: 1800.0)
        # reserve across several domains, settle each reservation once
        taken = []
        for b in bids[:4]:
            r = fed.reserve(b.resource, "u0", 0.0, HOUR, 0.0,
                            locked_price=b.chip_hour_price)
            taken.append(r)
        victim = fed.directory.spec(taken[0].resource).site
        srv = fed.servers[victim]
        for i, r in enumerate(taken):
            site = fed.directory.spec(r.resource).site
            amount = round(r.locked_price * 2.0, 6)
            sid = f"u0:{r.reservation_id}:{i}"
            rep = fed.servers[site].settle(sid, t=HOUR, user="u0",
                                           resource=r.resource,
                                           amount=amount)
            assert rep.ok and not rep.duplicate
            broker_rows.append((site, sid, "u0", r.resource, amount,
                                "settle", HOUR))

        # -- crash: no warning, no flush beyond the journal's fsync ----
        procs[victim].kill()
        assert not procs[victim].alive()
        with pytest.raises(TransportError):
            srv.quote(taken[0].resource, 0.0)

        # -- restart on the same journal -------------------------------
        procs[victim].restart()
        assert procs[victim].restarts == 1

        # every reservation survived, ids intact
        for r in taken:
            assert fed.find_reservation(r.reservation_id) == r
        # a retried settlement is a duplicate, not a second booking
        for site, sid, user, resource, amount, kind, t in broker_rows:
            rep = fed.servers[site].settle(sid, t=t, user=user,
                                           resource=resource,
                                           amount=amount, kind=kind)
            assert rep.ok and rep.duplicate

        # exact reconciliation: domain revenue rows == broker's record
        domain_rows = []
        for site in fed.sites():
            for row in fed.servers[site].revenue_rows():
                domain_rows.append((site,) + tuple(row))
        assert sorted(domain_rows) == sorted(broker_rows)

        # the revived domain keeps issuing NEW ids above every old one
        b = fed.solicit_bids(2 * HOUR, "u0", lambda spec: 1800.0)
        fresh = fed.reserve(b[0].resource, "u0", 2 * HOUR, 3 * HOUR,
                            2 * HOUR)
        assert fresh.reservation_id not in {r.reservation_id
                                            for r in taken}
    finally:
        for p in procs.values():
            p.stop()


def test_domain_journal_replay_is_idempotent(tmp_path):
    # kill/restart twice: replaying an already-replayed journal must
    # not duplicate reservations or settlements
    jp = str(tmp_path / "d.jsonl")
    specs = tuple(s for s in gusto_like_testbed(8, seed=0)
                  if s.site == "ANL")
    cfg = DomainConfig(site="ANL", specs=specs, journal_path=jp)
    proc = DomainProcess(cfg)
    try:
        proxy = RemoteTradeServer(proc)
        r = proxy.reserve(specs[0].name, "u0", 0.0, HOUR, 0.0)
        proxy.settle("s1", t=0.0, user="u0", resource=specs[0].name,
                     amount=1.0)
        for _ in range(2):
            proc.restart()
            assert proxy.find_reservation(r.reservation_id) == r
            assert proxy.revenue_rows() == [
                ("s1", "u0", specs[0].name, 1.0, "settle", 0.0)]
    finally:
        proc.stop()


# ---------------------------------------------------------------------------
# clock layer: conservative LBTS + wall-clock pacing
# ---------------------------------------------------------------------------

def test_conservative_clock_lbts_and_grants():
    clk = ConservativeClock()
    clk.add_link("ANL", lookahead=10.0)
    clk.add_link("ISI", lookahead=5.0)
    assert clk.lbts() == 5.0
    # ANL may advance to the other links' bound, excluding itself
    assert clk.grant("ANL") == 5.0
    clk.advance("ISI", 20.0)
    assert clk.grant("ANL") == 25.0
    clk.advance("ANL", 25.0)
    assert clk.grant("ISI") == 35.0
    assert not clk.blocked("ISI")
    clk.advance("ISI", 35.0)
    # both at their grant: each is blocked until the other moves (the
    # deadlock null messages break in a real distributed run)
    assert clk.blocked("ANL") or clk.grant("ANL") > 25.0


def test_conservative_clock_rejects_backward_motion():
    clk = ConservativeClock()
    clk.add_link("A", lookahead=1.0)
    clk.advance("A", 5.0)
    with pytest.raises(ValueError):
        clk.advance("A", 4.0)
    with pytest.raises(ValueError):
        clk.add_link("A", lookahead=1.0)      # duplicate link


def test_wall_clock_simulator_paces_virtual_time():
    sleeps = []
    wall = [0.0]

    def fake_sleep(dt):
        sleeps.append(dt)
        wall[0] += dt

    sim = WallClockSimulator(time_scale=100.0, sleep=fake_sleep,
                             wall=lambda: wall[0])
    fired = []
    for t in (100.0, 200.0, 400.0):
        sim.at(t, lambda t=t: fired.append(t))
    sim.run()
    assert fired == [100.0, 200.0, 400.0]
    # 400 virtual seconds at 100x -> ~4 wall seconds, slept not spun
    assert abs(sum(sleeps) - 4.0) < 1e-6
    assert sim.now == 400.0


def test_wall_clock_simulator_rejects_bad_scale():
    with pytest.raises(ValueError):
        WallClockSimulator(time_scale=0.0)


def test_wall_clock_simulator_matches_virtual_order():
    # same event set, same order, same final clock as the pure-virtual
    # simulator — wall pacing must never reorder the market
    from repro.core.simulator import Simulator
    order_v, order_w = [], []
    sim_v = Simulator()
    sim_w = WallClockSimulator(time_scale=1e12, sleep=lambda dt: None,
                               wall=lambda: 0.0)
    for sim, order in ((sim_v, order_v), (sim_w, order_w)):
        for t in (5.0, 1.0, 3.0, 1.0):
            sim.at(t, lambda t=t, o=order: o.append(t))
        sim.run()
    assert order_w == order_v == [1.0, 1.0, 3.0, 5.0]
