"""Grid Information Service: hierarchical discovery, heartbeat liveness,
TTL-stale broker views, site churn, and the fail-over + refund economics
of scheduling against imperfect information (cs/0203019's GIS layer)."""
import math

import pytest

from repro.core import (ChurnProcess, FailureProcess, GISClient,
                        GridInformationService, Marketplace, MarketUser,
                        ResourceDirectory, ResourceSpec, SchedulerConfig,
                        department_of, standard_market)

from conftest import make_gis as _gis
from conftest import make_spec as _spec

HOUR = 3600.0


# ---------------------------------------------------------------------------
# hierarchy + attribute queries
# ---------------------------------------------------------------------------

def test_hierarchical_query_scopes():
    """The abstract's three levels: a department query sees only its
    lab, an enterprise query the whole domain, global everything."""
    d, gis = _gis([
        _spec("a0", "ANL", "cs"), _spec("a1", "ANL", "cs"),
        _spec("a2", "ANL", "physics"),
        _spec("i0", "ISI", "grid"),
    ])
    assert [e.name for e in gis.query(0.0)] == ["a0", "a1", "a2", "i0"]
    assert [e.name for e in gis.query(0.0, level="enterprise",
                                      within="ANL")] == ["a0", "a1", "a2"]
    assert [e.name for e in gis.query(0.0, level="department",
                                      within="ANL/cs")] == ["a0", "a1"]
    assert gis.levels() == {"ANL": ["ANL/cs", "ANL/physics"],
                            "ISI": ["ISI/grid"]}
    # a spec without a department lands in its site's main registry
    assert department_of(_spec("x", "UVA")) == "UVA/main"


def test_query_attribute_filters():
    d, gis = _gis([
        _spec("cheap", "X", price=0.5, chips=2),
        _spec("dear", "X", price=5.0, chips=8),
        _spec("vip", "X", price=1.0, users=("alice",)),
    ])
    assert [e.name for e in gis.query(0.0, min_chips=4)] == ["dear"]
    assert [e.name for e in gis.query(0.0, max_price=1.0,
                                      user="alice")] == ["cheap", "vip"]
    # authorization: strangers never discover restricted machines
    assert [e.name for e in gis.query(0.0, user="mallory")
            ] == ["cheap", "dear"]


def test_query_price_is_advertised_not_live():
    """max_price filters on the price the resource last *advertised*
    (at its heartbeat), not the owner's live quote."""
    prices = {"m0": 1.0}
    d, gis = _gis([_spec("m0", "X")],
                  price_fn=lambda n, t: prices[n])
    assert [e.name for e in gis.query(0.0, max_price=2.0)] == ["m0"]
    prices["m0"] = 9.0               # owner repriced...
    assert [e.name for e in gis.query(10.0, max_price=2.0)] == ["m0"]
    gis.heartbeat("m0", 20.0)        # ...but only the beat publishes it
    assert gis.query(30.0, max_price=2.0) == []


# ---------------------------------------------------------------------------
# heartbeat liveness: death is detected, never observed
# ---------------------------------------------------------------------------

def test_missed_heartbeats_create_detection_latency():
    from repro.core import Simulator
    d, gis = _gis([_spec("r0", "X")], heartbeat_interval=100.0,
                  suspect_after=2)
    sim = Simulator()
    gis.start(sim)
    sim.at(250.0, lambda: setattr(d.status("r0"), "up", False))
    sim.run(until=1000.0)
    # last successful beat at t=200; grace = 2 beats = 200s
    assert not gis.suspected("r0", 390.0)    # the corpse still advertised
    assert gis.suspected("r0", 410.0)        # ...until the grace lapses
    # deregistration is definitive at any time
    gis.deregister("r0", 1000.0)
    assert gis.suspected("r0", 0.0)


def test_failure_process_publishes_repair_eta():
    """Satellite: ``ResourceStatus.next_transition`` is written on
    failure (the scheduled repair time), cleared on repair, and the GIS
    serves it as "ETA back up" for suspected resources only."""
    from repro.core import Simulator
    d = ResourceDirectory()
    d.register(ResourceSpec(name="r0", site="X", chips=1, mtbf_hours=1.0,
                            mttr_hours=2.0))
    gis = GridInformationService(d, heartbeat_interval=60.0)
    gis.register(d.spec("r0"), 0.0)
    sim = Simulator()
    gis.start(sim)
    downs, ups = [], []
    fp = FailureProcess(sim, d, seed=4,
                        on_down=lambda r: downs.append(
                            (sim.now, d.status(r).next_transition)),
                        on_up=lambda r: ups.append(sim.now))
    fp.install("r0")
    # poll the GIS while the run unfolds (post-hoc queries would see
    # only the final record state)
    answers = []
    sim.every(10 * 60.0, lambda: answers.append(
        (sim.now, gis.eta_back_up("r0", sim.now))), start_delay=0.0)
    sim.run(until=50 * HOUR)
    assert downs and ups
    for (t_down, eta), t_up in zip(downs, ups):
        assert eta == pytest.approx(t_up)    # published ETA = actual fix
        assert eta > t_down
    served = [(t, eta) for t, eta in answers if eta is not None]
    assert served                            # the GIS did answer "when?"
    published = {eta for _, eta in downs}
    for t, eta in served:
        assert eta > t                       # always a *future* repair
        assert eta in published              # ...from the outage's record
    if len(ups) == len(downs):               # ended repaired: ETA cleared
        assert d.status("r0").next_transition == math.inf


# ---------------------------------------------------------------------------
# cached broker views
# ---------------------------------------------------------------------------

def test_client_view_is_cached_until_ttl():
    d, gis = _gis([_spec("m0", "X"), _spec("m1", "X")])
    client = GISClient(gis, "u", ttl=500.0)
    v1 = client.view(0.0)
    assert set(v1.entries) == {"m0", "m1"}
    gis.deregister("m0", 100.0)              # the world moves on...
    v2 = client.view(400.0)
    assert v2 is v1                          # ...the broker doesn't know
    assert "m0" in v2.entries
    assert client.refreshes == 1
    v3 = client.view(600.0)                  # TTL lapsed: refresh
    assert v3 is not v1
    assert "m0" not in v3.entries
    assert client.is_suspected("m0")         # gone = not schedulable


def test_local_suspicion_lasts_until_next_refresh():
    d, gis = _gis([_spec("m0", "X")])
    client = GISClient(gis, "u", ttl=500.0)
    client.view(0.0)
    assert not client.is_suspected("m0")
    client.suspect("m0")                     # a dispatch burned on it
    assert client.is_suspected("m0")
    client.view(100.0)                       # within TTL: opinion holds
    assert client.is_suspected("m0")
    client.view(600.0)                       # fresh snapshot: re-trust
    assert not client.is_suspected("m0")


def test_stale_view_dispatch_burns_and_requeues_without_attempt():
    """The acceptance scenario in miniature: a site dies right after the
    broker refreshed its view.  With max_attempts=1 every burned
    dispatch would be fatal if it cost an attempt — yet all jobs finish
    on the surviving site."""
    specs = [_spec("x0", "X", price=0.1, slots=2),
             _spec("y0", "Y", price=2.0, slots=2)]
    market = Marketplace(specs=specs, seed=0, gis_ttl=2 * HOUR,
                         noise_sigma=0.0)
    eng = market.add_user(
        MarketUser(name="u", deadline=20 * HOUR, budget=1e6, n_jobs=6,
                   est_seconds=900.0),
        sched_cfg=SchedulerConfig(max_attempts=1))
    # cheap site X vanishes mid-run (in-flight jobs evicted too)
    market.sim.at(1000.0, lambda: market._site_leaves("X", 40 * HOUR))
    rep = market.run()
    out = rep.outcomes[0]
    assert rep.evictions > 0                   # in-flight work failed over
    assert out.resource_losses > 0             # stale view burned dispatches
    assert out.n_done == out.n_jobs, rep.summary()
    assert out.stall_reason is None
    # the ledger holds no stranded commitments and the bank balances
    assert eng.ledger.committed == pytest.approx(0.0)
    market.bank.reconcile({"u": eng.ledger})


# ---------------------------------------------------------------------------
# churn: whole sites leave and rejoin
# ---------------------------------------------------------------------------

def _churn_events(seed, veto=False):
    from repro.core import Simulator
    d = ResourceDirectory()
    for name, site in (("a0", "A"), ("b0", "B")):
        d.register(_spec(name, site))
    sim = Simulator()
    cp = ChurnProcess(sim, d, seed=seed, mean_uptime_hours=2.0,
                      mean_downtime_hours=1.0,
                      on_leave=(lambda s, eta: not veto))
    for site in d.sites():
        cp.install(site)
    sim.run(until=40 * HOUR)
    return cp.events


def test_churn_process_deterministic_and_vetoable():
    e1 = _churn_events(seed=9)
    e2 = _churn_events(seed=9)
    assert e1 and e1 == e2
    assert e1 != _churn_events(seed=10)
    # leaves and joins alternate per site
    per_site = {}
    for _, kind, site in e1:
        assert per_site.get(site) != kind
        per_site[site] = kind
    # a vetoed departure never happens (and never deadlocks the process)
    assert _churn_events(seed=9, veto=True) == []


def test_departing_site_voids_contracts_and_refunds_through_bank():
    """Satellite: a price-locked contract on a dying site is voided, its
    reservations cancelled, and the owner's breach rebate flows through
    the bank — with the books still reconciling exactly."""
    specs = [_spec("x0", "X"), _spec("y0", "Y")]
    market = Marketplace(specs=specs, seed=0, churn_rebate=0.25)
    eng = market.add_user(MarketUser(name="u0", deadline=10 * HOUR,
                                     budget=1e4, n_jobs=2))
    offer = [o for o in market.auction_house.call_for_tenders(0.0, "u0")
             if o.site == "X"][0]
    c = market.auction_house.accept(offer, "u0", t=0.0)
    assert market.trade.reserved_price("x0", "u0", HOUR) is not None
    settled_before = eng.ledger.settled
    assert market._site_leaves("X", rejoin_at=8 * HOUR)
    assert c.voided_at == 0.0
    assert market.refunds > 0.0
    assert eng.ledger.settled == pytest.approx(settled_before
                                               - market.refunds)
    refund_entries = [e for e in market.bank.entries if e.kind == "refund"]
    assert refund_entries and all(e.amount < 0 for e in refund_entries)
    market.bank.reconcile({"u0": eng.ledger})
    # the domain is untradeable while gone...
    from repro.core import AdmissionError
    with pytest.raises(AdmissionError):
        market.trade.reserve("x0", "u0", HOUR, 2 * HOUR, 0.0)
    assert market.trade.quote("x0", 0.0) > 0.0     # stale quotes still price
    # ...and fully tradeable again after rejoining (fresh book, no locks)
    market.sim.at(0.0, lambda: None)
    market._site_joins("X")
    assert market.gis.is_registered("x0")
    assert market.trade.reserved_price("x0", "u0", HOUR) is None
    market.trade.reserve("x0", "u0", HOUR, 2 * HOUR, 0.0)


def test_churn_market_completes_and_reconciles():
    """Acceptance: a churning market with a finite TTL ends with every
    broker either meeting its constraints or reporting the miss — no
    crashes, no lost jobs, no unreconciled G$."""
    market = standard_market(6, n_machines=10, seed=5, n_jobs=8,
                             gis_ttl=900.0, churn_mean_uptime_h=3.0,
                             churn_mean_downtime_h=2.0)
    rep = market.run(churn=True)
    assert rep.churn_trace                       # membership really churned
    assert all(e.finished for e in market.engines)
    for user, engine in zip(market.users, market.engines):
        statuses = [j.status.value for j in engine.jobs.values()]
        assert len(statuses) == user.n_jobs      # no job vanished
        done = sum(1 for s in statuses if s == "done")
        out = next(o for o in rep.outcomes if o.user == user.name)
        assert out.n_done == done
        if done < user.n_jobs:                   # a miss must be reported
            assert not out.met_deadline or out.stall_reason is not None
        assert engine.ledger.committed == pytest.approx(0.0)
    market.bank.reconcile({u.name: e.ledger
                           for u, e in zip(market.users, market.engines)})


def test_churn_market_run_is_seed_deterministic():
    """Satellite: the churn path (like the failure path) must be
    byte-identical across same-seed runs."""
    kw = dict(n_machines=10, seed=7, n_jobs=6, gis_ttl=600.0,
              churn_mean_uptime_h=3.0, churn_mean_downtime_h=1.5)
    r1 = standard_market(6, **kw).run(churn=True, failures=True)
    r2 = standard_market(6, **kw).run(churn=True, failures=True)
    assert r1.stable_repr() == r2.stable_repr()
    r3 = standard_market(6, **dict(kw, seed=8)).run(churn=True,
                                                    failures=True)
    assert r1.stable_repr() != r3.stable_repr()


def test_rejoined_site_never_reissues_retired_reservation_ids():
    """A site that rejoins gets a FRESH trade server, but ids its old
    server issued live on in voided contracts and audit trails — the
    new book must never reuse them (a later cancel would destroy a
    rival's reservation)."""
    specs = [_spec("x0", "X"), _spec("y0", "Y")]
    market = Marketplace(specs=specs, seed=0)
    issued = set()
    for _ in range(3):
        r = market.trade.reserve("x0", "u", 0.0, 60.0, 0.0)
        issued.add(r.reservation_id)
        market.trade.cancel(r.reservation_id)
    assert market._site_leaves("X", rejoin_at=HOUR)
    market._site_joins("X")
    fresh = market.trade.reserve("x0", "v", 0.0, HOUR, 0.0)
    assert fresh.reservation_id not in issued
    # the retired ids resolve to nothing: cancelling one is a no-op
    held = market.trade.reserved_price("x0", "v", 30 * 60.0)
    for rid in issued:
        market.trade.cancel(rid)
    assert market.trade.reserved_price("x0", "v", 30 * 60.0) == held


def test_withdraw_after_void_leaves_rival_reservations_alone():
    """The depart→void→rejoin→withdraw chain: a broker shutting down
    must not cancel reservations behind contracts a departing site
    already voided (their ids may since belong to someone else)."""
    specs = [_spec("x0", "X"), _spec("y0", "Y")]
    market = Marketplace(specs=specs, seed=0, churn_rebate=0.0)
    eng = market.add_user(MarketUser(name="u", deadline=10 * HOUR,
                                     budget=1e4, strategy="auction",
                                     n_jobs=2))
    offer = [o for o in market.auction_house.call_for_tenders(0.0, "u")
             if o.site == "X"][0]
    c = market.auction_house.accept(offer, "u", t=0.0)
    eng.auction._live.append(c)              # broker tracks its contract
    assert market._site_leaves("X", rejoin_at=HOUR)
    market._site_joins("X")
    rival = market.trade.reserve("x0", "rival", 0.0, offer.end, 0.0)
    eng.auction.withdraw(t=0.0)              # u's experiment ends
    assert market.trade.reserved_price(
        "x0", "rival", 30 * 60.0) is not None  # rival's lock survives


def test_tender_accept_after_site_departed_is_refused_not_crash():
    from repro.core import AdmissionError
    specs = [_spec("x0", "X"), _spec("y0", "Y")]
    market = Marketplace(specs=specs, seed=0)
    market.add_user(MarketUser(name="u", deadline=10 * HOUR, budget=1e4,
                               n_jobs=2))
    offer = [o for o in market.auction_house.call_for_tenders(0.0, "u")
             if o.site == "X"][0]
    assert market._site_leaves("X", rejoin_at=HOUR)
    with pytest.raises(AdmissionError):      # inside validity, site gone
        market.auction_house.accept(offer, "u", t=60.0)


def test_trade_federation_membership_tracks_gis():
    market = Marketplace(specs=[_spec("x0", "X"), _spec("y0", "Y")],
                         seed=0)
    assert set(market.gis.trade_servers()) == {"X", "Y"}
    assert market._site_leaves("X", rejoin_at=HOUR)
    assert set(market.gis.trade_servers()) == {"Y"}
    assert market.trade.sites() == ["Y"]
    assert market.trade.departed_sites() == ["X"]
    # the last site standing may never leave
    assert not market._site_leaves("Y", rejoin_at=HOUR)
    market._site_joins("X")
    assert set(market.gis.trade_servers()) == {"X", "Y"}
    assert market.trade.sites() == ["X", "Y"]
