"""The indexed hot path's new mechanics: cancellable timers, strided
timelines, and version-stamped quote caching — the machinery behind the
O(active-work) broker tick (scheduling behavior itself is pinned by
tests/test_golden_equivalence.py)."""
import math

import pytest

from repro.core import (PriceSchedule, ResourceDirectory, ResourceSpec,
                        SchedulerConfig, Simulator, TradeServer,
                        standard_market)

HOUR = 3600.0


# ---------------------------------------------------------------------------
# simulator: cancellable handles
# ---------------------------------------------------------------------------

def test_cancelled_timer_never_fires():
    sim = Simulator()
    fired = []
    h = sim.at(10.0, lambda: fired.append("a"))
    sim.at(20.0, lambda: fired.append("b"))
    h.cancel()
    sim.run()
    assert fired == ["b"]
    assert sim.now == 20.0


def test_cancelled_timer_does_not_advance_clock_or_count_events():
    sim = Simulator()
    h = sim.at(50.0, lambda: None)
    h.cancel()
    sim.run(until=math.inf)
    assert sim.now == 0.0                    # skipped, not fired
    assert sim.events == 0


def test_cancelled_head_does_not_distort_until_boundary_clock():
    """A dead timer sitting first in the heap must not cap the final
    clock clamp at run(until=...)."""
    sim = Simulator()
    h = sim.at(5.0, lambda: None)
    sim.at(30.0, lambda: None)
    h.cancel()
    sim.run(until=10.0)
    assert sim.now == 10.0                   # clamped by until, not by 5.0


def test_every_handle_cancels_the_chain():
    sim = Simulator()
    ticks = []
    handle = sim.every(10.0, lambda: ticks.append(sim.now))
    sim.at(35.0, handle.cancel)
    sim.run(until=100.0)
    assert ticks == [10.0, 20.0, 30.0]
    assert sim.pending_events() == 0


def test_finished_engines_leave_the_heap():
    """A marketplace engine that finishes cancels its pending tick: the
    heap must not keep popping dead brokers' wakeups for the rest of a
    long run."""
    market = standard_market(2, n_machines=6, seed=1, n_jobs=4,
                             est_seconds=600.0)
    for eng in market.engines:
        assert eng._tick_handle is None
    market.run()
    for eng in market.engines:
        assert eng.finished
        assert eng._tick_handle is None      # cancelled and dropped


# ---------------------------------------------------------------------------
# timeline stride
# ---------------------------------------------------------------------------

def test_timeline_stride_bounds_report_growth_without_changing_schedule():
    dense = standard_market(2, n_machines=6, seed=3, n_jobs=8,
                            sched_cfg=SchedulerConfig()).run()
    strided = standard_market(2, n_machines=6, seed=3, n_jobs=8,
                              sched_cfg=SchedulerConfig(
                                  timeline_stride=8)).run()
    # identical scheduling: stable_repr covers every economic outcome
    assert dense.stable_repr() == strided.stable_repr()
    assert len(dense.price_trace) == len(strided.price_trace)


def test_timeline_stride_engine_level():
    m1 = standard_market(1, n_machines=4, seed=5, n_jobs=6,
                         sched_cfg=SchedulerConfig())
    r1 = m1.run()
    m2 = standard_market(1, n_machines=4, seed=5, n_jobs=6,
                         sched_cfg=SchedulerConfig(timeline_stride=4))
    r2 = m2.run()
    t1 = m1.engines[0].report.timeline
    t2 = m2.engines[0].report.timeline
    assert len(t1) > len(t2) >= math.ceil(len(t1) / 4)
    assert t2 == t1[::4]                     # every 4th tick, first kept
    assert r1.stable_repr() == r2.stable_repr()


def test_timeline_stride_must_not_change_behavior_under_churn():
    kw = dict(n_machines=10, seed=9, n_jobs=6, gis_ttl=900.0,
              churn_mean_uptime_h=3.0, churn_mean_downtime_h=1.0)
    r1 = standard_market(3, sched_cfg=SchedulerConfig(), **kw).run(
        failures=True, churn=True)
    r2 = standard_market(3, sched_cfg=SchedulerConfig(timeline_stride=16),
                         **kw).run(failures=True, churn=True)
    assert r1.stable_repr() == r2.stable_repr()


# ---------------------------------------------------------------------------
# version-stamped quote cache
# ---------------------------------------------------------------------------

def _one_machine():
    d = ResourceDirectory()
    d.register(ResourceSpec(name="m0", site="s", chips=2, slots=2,
                            base_price=1.0, peak_multiplier=1.0))
    sched = {"m0": PriceSchedule(d.spec("m0"), demand_elasticity=1.0)}
    return d, TradeServer(d, sched)


def test_status_version_bumps_on_acquire_release():
    d, _ = _one_machine()
    st, spec = d.status("m0"), d.spec("m0")
    v0 = st.version
    assert st.acquire(spec)
    assert st.version == v0 + 1
    st.release()
    assert st.version == v0 + 2
    # a refused acquire (queue full) is not a state change
    assert st.acquire(spec) and st.acquire(spec)
    v_full = st.version
    assert not st.acquire(spec)
    assert st.version == v_full


def test_book_version_bumps_on_reserve_cancel_prune():
    d, ts = _one_machine()
    v0 = ts.book_version
    r = ts.reserve("m0", "u", 0.0, 100.0, 0.0)
    assert ts.book_version == v0 + 1
    assert ts.cancel(r.reservation_id)
    assert ts.book_version == v0 + 2
    assert not ts.cancel(999_999)            # no-op cancel: no bump
    assert ts.book_version == v0 + 2
    ts.reserve("m0", "u", 0.0, 10.0, 0.0)
    ts._prune(50.0)                          # expiry pruning bumps
    assert ts.book_version == v0 + 4


def test_cached_price_tracks_utilization_and_reservations():
    """The broker-side memo must never serve a stale quote: demand
    pricing moves with the queue, reservations lock prices — both bump a
    stamp the cache keys on."""
    from repro.core.dispatcher import Dispatcher, SimulatedExecutor
    from repro.core.economy import UserRequirements
    from repro.core.parametric import NimrodG
    from repro.core.jobs import JobSpec

    d, ts = _one_machine()
    sim = Simulator()
    disp = Dispatcher(SimulatedExecutor(sim, d), d)
    req = UserRequirements(deadline=10 * HOUR, budget=1e6, user="u")
    eng = NimrodG("cache", [JobSpec(job_id="j0", experiment="cache",
                                    point={}, steps=())],
                  req, d, ts, disp, sim=sim)
    p_idle = eng._price("m0")
    assert p_idle == ts.effective_price("m0", "u", sim.now)
    # rival grabs a slot: utilization 0 -> 1/2, demand premium kicks in
    d.status("m0").acquire(d.spec("m0"))
    p_half = eng._price("m0")
    assert p_half > p_idle
    assert p_half == ts.effective_price("m0", "u", sim.now)
    # a locked reservation beats the spot quote through the same cache
    ts.reserve("m0", "u", sim.now, sim.now + HOUR, sim.now,
               locked_price=0.25)
    assert eng._price("m0") == 0.25
    # cache hit path: same t, same stamps -> identical object back
    assert eng._price("m0") == 0.25
