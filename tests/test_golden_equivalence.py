"""Golden same-seed equivalence: the indexed broker hot path must be
*behavior-preserving* — byte-identical reports and journals versus the
pre-index implementation (PR 1-3 lineage).

The hashes below were captured by running these exact scenarios on the
pre-refactor code (full job-table rescans, attempts-log walks, uncached
quotes, no timer cancellation).  Each scenario was chosen to cross every
index update point:

* ``contention``  — slot races lost (SLOT_LOST requeue, attempt handed
  back) across 4 posted-price brokers with failures;
* ``auction``     — negotiated contracts (reservation book mutations,
  locked-vs-spot dispatch pricing) in a mixed market;
* ``churn``       — whole-site departures: in-flight evictions, burned
  dispatches on stale GIS views, fault requeues without attempt burn;
* ``journal``     — a single journaled engine with a tight straggler
  factor (duplicate racing + kill settlement), hashed event-by-event.

If an intentional behavior change lands, regenerate with
``python tests/test_golden_equivalence.py`` and update the constants in
the same commit — silently drifting schedules are the bug this guards.
"""
import hashlib
import os

import pytest

from repro.core import (Dispatcher, Journal, NimrodG, PriceSchedule,
                        ResourceDirectory, SchedulerConfig,
                        SimulatedExecutor, Simulator, TradeServer,
                        UserRequirements, gusto_like_testbed,
                        mixed_auction_market, parse_plan, standard_market)

HOUR = 3600.0

GOLDEN = {
    "contention":
        "465719d24255b82f39413e350d298ae1550dfa82e39d5ad2a6a301f0776e2e07",
    "auction":
        "1bf2b420da6859e0f20ee575beba4665d4737ae2fa05acc8d61732e78b2e5b44",
    "churn":
        "b84fbebd806c6e2146ed58b8df37835299383539b3992ebf22715a8163c44430",
    "journal":
        "2fffca3c43ec2cff3477444e2ffdca0ba92cbabf900173bfb1ddf9b87f4c1672",
    "journal_report":
        "99321471481ed18410849eb7b41991d823489f04efe9c55fa706d2444961f1ab",
}

#: captured on the pre-registry scheduler (hard-coded if/elif strategy
#: dispatch, PR 1-5 lineage) — the extracted Strategy classes must
#: reproduce every legacy policy byte-for-byte at default knobs
LEGACY_GOLDEN = {
    "cost":
        "c3df808d91e11428e91126e051a5aea1658367a78e6de4b37da40a69dd47fa37",
    "time":
        "8f91481d991f7689df455c954114b54a2c2dc3bb2859d53ac0479744405acd0d",
    "conservative":
        "0e709b6604e6fd75926541ad7da182e2f3826e817e3764edca718bf604d2d810",
}


def _sha(s: str) -> str:
    return hashlib.sha256(s.encode()).hexdigest()


def _canonical_report(rep) -> str:
    """Process-stable serialization of an ExperimentReport: plain
    ``repr`` leaks set iteration order (hash-randomized per process),
    so resources are sorted and every float rendered exactly."""
    return (f"{rep.experiment}|{rep.strategy}|{rep.n_done}/{rep.n_jobs}"
            f"|failed={rep.n_failed_final}|t={rep.completion_time!r}"
            f"|cost={rep.total_cost!r}|met={rep.met_deadline}"
            f"|within={rep.within_budget}"
            f"|res={sorted(rep.resources_used)!r}"
            f"|peak={rep.peak_allocation}|dups={rep.duplicates_launched}"
            f"|rq={rep.requeues}|races={rep.slot_races_lost}"
            f"|rl={rep.resource_losses}|stall={rep.stall_reason}"
            f"|timeline={rep.timeline!r}")


def _contention_market():
    return standard_market(4, n_machines=8, seed=7, n_jobs=12,
                           demand_elasticity=1.0)


def _churn_market():
    return standard_market(4, n_machines=12, seed=5, n_jobs=10,
                           gis_ttl=900.0, churn_mean_uptime_h=3.0,
                           churn_mean_downtime_h=1.0)


def _journal_engine(tmpdir: str):
    directory = ResourceDirectory()
    for spec in gusto_like_testbed(12, seed=9):
        directory.register(spec)
    schedules = {n: PriceSchedule(directory.spec(n), spot_amplitude=0.1,
                                  demand_elasticity=0.5)
                 for n in directory.all_names()}
    trade = TradeServer(directory, schedules)
    sim = Simulator()
    disp = Dispatcher(SimulatedExecutor(sim, directory, seed=2,
                                        dispatch_latency=1.0), directory)
    plan = parse_plan("""
parameter alpha float range from 0.1 to 1.8 step 0.1
task main
    execute sim --alpha $alpha
endtask
""")
    req = UserRequirements(deadline=6 * HOUR, budget=9_000.0,
                           strategy="cost")
    jpath = os.path.join(tmpdir, "golden.jsonl")
    eng = NimrodG.from_plan("golden", plan, req, directory, trade, disp,
                            est_seconds=lambda p: 1500.0, sim=sim,
                            journal=Journal(jpath, fsync=False),
                            sched_cfg=SchedulerConfig(straggler_factor=1.2))
    return eng, jpath


def _legacy_market(strategy: str):
    return standard_market(3, n_machines=8, seed=13, n_jobs=8,
                           strategies=(strategy,))


@pytest.mark.parametrize("strategy", sorted(LEGACY_GOLDEN))
def test_golden_legacy_strategy_reproduces_pre_registry_bytes(strategy):
    rep = _legacy_market(strategy).run()
    assert _sha(rep.stable_repr()) == LEGACY_GOLDEN[strategy]


def test_golden_contention_market_reproduces_pre_index_bytes():
    rep = _contention_market().run(failures=True)
    assert rep.slot_races_lost > 0          # the scenario still bites
    assert _sha(rep.stable_repr()) == GOLDEN["contention"]


def test_golden_auction_market_reproduces_pre_index_bytes():
    rep = mixed_auction_market(6, n_machines=10, seed=3, n_jobs=10).run()
    assert rep.contracts_struck > 0
    assert _sha(rep.stable_repr()) == GOLDEN["auction"]


def test_golden_churn_market_reproduces_pre_index_bytes():
    rep = _churn_market().run(failures=True, churn=True)
    assert rep.evictions > 0 and rep.resource_losses > 0
    assert len(rep.churn_trace) > 0
    assert _sha(rep.stable_repr()) == GOLDEN["churn"]


def test_golden_journaled_engine_reproduces_pre_index_journal(tmp_path):
    eng, jpath = _journal_engine(str(tmp_path))
    rep = eng.run_simulated(failures=True)
    eng.journal.close()
    assert rep.duplicates_launched > 0      # straggler race exercised
    with open(jpath) as f:
        assert _sha(f.read()) == GOLDEN["journal"]
    assert _sha(_canonical_report(rep)) == GOLDEN["journal_report"]


def test_index_invariants_after_run():
    """After a run every index agrees with a from-scratch recount —
    the invariant _reindex() maintains transition by transition."""
    from repro.core.jobs import JobStatus
    market = _contention_market()
    market.run(failures=True)
    for eng in market.engines:
        done = {j.job_id for j in eng.jobs.values()
                if j.status is JobStatus.DONE}
        pending = {j.job_id for j in eng.jobs.values()
                   if j.status in (JobStatus.PENDING, JobStatus.FAILED)
                   and j.attempt < eng.cfg.max_attempts}
        active = {j.job_id for j in eng.jobs.values()
                  if j.status in (JobStatus.STAGED, JobStatus.RUNNING)}
        assert eng._done_ids == done
        assert eng._pending_ids == pending
        # the sorted list may carry tombstones; its live view must agree
        assert {jid for _, jid in eng._pending_live()} == pending
        assert eng._pending_dead == (len(eng._pending_sorted)
                                     - len(eng._pending_live()))
        assert eng._active_ids == active
        assert eng._remaining() == sum(
            1 for j in eng.jobs.values() if j.status != JobStatus.DONE)


if __name__ == "__main__":
    # regeneration helper: prints the hashes to paste into GOLDEN
    import tempfile
    out = {}
    out["contention"] = _sha(
        _contention_market().run(failures=True).stable_repr())
    out["auction"] = _sha(
        mixed_auction_market(6, n_machines=10, seed=3,
                             n_jobs=10).run().stable_repr())
    out["churn"] = _sha(
        _churn_market().run(failures=True, churn=True).stable_repr())
    with tempfile.TemporaryDirectory() as td:
        eng, jpath = _journal_engine(td)
        rep = eng.run_simulated(failures=True)
        eng.journal.close()
        with open(jpath) as f:
            out["journal"] = _sha(f.read())
        out["journal_report"] = _sha(_canonical_report(rep))
    for k, v in out.items():
        print(f'    "{k}":\n        "{v}",')
    print("LEGACY_GOLDEN:")
    for strat in sorted(LEGACY_GOLDEN):
        h = _sha(_legacy_market(strat).run().stable_repr())
        print(f'    "{strat}":\n        "{h}",')
